"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED same-family config runs one forward + one train step on CPU with
correct output shapes and no NaNs; decode families also run a decode step.
The FULL configs are exercised only by the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, smoke_variant
from repro.configs.registry import all_lm_archs, get_config
from repro.launch.steps import make_train_fn
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig, adamw_init

ARCHS = all_lm_archs()


def _smoke_batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    fam = cfg.family
    if fam in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"):
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab,
                                             jnp.int32)
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if fam == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_frames, cfg.d_frontend or cfg.d_model))
    if fam == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, cfg.d_frontend or cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_variant(get_config(arch))
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    logits = model_api.prefill_fn(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = smoke_variant(get_config(arch)).with_(microbatch_steps=1)
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    state = {"params": params,
             "opt": adamw_init(params, AdamWConfig(low_mem=False)),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_fn(cfg))
    state2, metrics = step(state, _smoke_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    # params actually moved (sum of |delta| over every leaf)
    deltas = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(b.astype(jnp.float32)
                                   - a.astype(jnp.float32)).sum()),
        state["params"], state2["params"])
    assert sum(jax.tree_util.tree_leaves(deltas)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a != "whisper-medium"])
def test_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    if not model_api.supports_decode(cfg):
        pytest.skip("no decode for this family")
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    shapes, _ = model_api.cache_axes_spec(cfg, batch=2, seq_len=64)
    cache = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = model_api.decode_fn(params, cache, toks, jnp.int32(0),
                                         cfg)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # cache must be written (some leaf changed)
    diffs = [float(jnp.abs(cache2[k].astype(jnp.float32)
                           - jnp.zeros_like(cache2[k], jnp.float32)).max())
             for k in cache2]
    assert max(diffs) > 0


def test_whisper_decode_step():
    """Whisper decode needs the cross-KV cache prefilled from the encoder."""
    from repro.models import encdec as ed_mod
    cfg = smoke_variant(get_config("whisper-medium"))
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    shapes, _ = model_api.cache_axes_spec(cfg, batch=2, seq_len=64)
    cache = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (2, cfg.enc_frames,
                                cfg.d_frontend or cfg.d_model))
    enc_out = ed_mod.encode(params, frames, cfg)
    assert enc_out.shape == (2, cfg.enc_frames, cfg.d_model)
    logits, _ = model_api.decode_fn(params, cache, jnp.zeros((2, 1),
                                                             jnp.int32),
                                    jnp.int32(0), cfg)
    assert logits.shape == (2, cfg.vocab)


@pytest.mark.parametrize("variant", ["tiny", "base"])
def test_opto_vit_smoke(variant):
    cfg = smoke_variant(get_config(f"opto-vit-{variant}"))
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, cfg.img_size, cfg.img_size, 3))
    from repro.models.vit import forward_vit
    logits, kept = forward_vit(params, imgs, cfg)
    assert logits.shape[0] == 2
    assert not bool(jnp.isnan(logits).any())


def test_param_counts_sane():
    """Analytic param counts (roofline MODEL_FLOPS source) are the right
    order of magnitude for the headline archs."""
    checks = {
        "llama3-405b": (3.5e11, 4.7e11),
        "qwen2-1.5b": (1.0e9, 2.2e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.4e12),
        "mamba2-780m": (5e8, 1.1e9),
        "stablelm-12b": (0.9e13 / 1000, 1.5e10),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
    # MoE active < total
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.1 * kimi.param_count()
