"""Model substrate: composable JAX model definitions for all families."""
