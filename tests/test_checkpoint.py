"""Checkpointing: roundtrip, atomicity/corruption, retention, async."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (CheckpointManager, async_save,
                                         latest_step, restore, save)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.float32(3.5)},
            "opt": {"m": jnp.zeros((4, 8), jnp.bfloat16)}}


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path / "ck"), t, step=7)
    t2, step = restore(str(tmp_path / "ck"), t)
    assert step == 7
    _assert_tree_equal(t, t2)


def test_restore_preserves_dtype(tmp_path):
    t = _tree()
    save(str(tmp_path / "ck"), t)
    t2, _ = restore(str(tmp_path / "ck"), t)
    assert t2["opt"]["m"].dtype == jnp.bfloat16


def test_checksum_detects_corruption(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save(path, t, step=1)
    # corrupt one leaf file
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, fn))
    np.save(os.path.join(path, fn), arr + 1)
    with pytest.raises(IOError, match="checksum"):
        restore(path, t)


def test_atomic_overwrite(tmp_path):
    path = str(tmp_path / "ck")
    save(path, _tree(0), step=1)
    save(path, _tree(1), step=2)
    t2, step = restore(path, _tree(0))
    assert step == 2
    _assert_tree_equal(t2, _tree(1))


def test_async_save_joinable(tmp_path):
    t = _tree()
    th = async_save(str(tmp_path / "ck"), t, step=3)
    th.join()
    t2, step = restore(str(tmp_path / "ck"), t)
    assert step == 3
    _assert_tree_equal(t, t2)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, t)
    mgr.wait()
    mgr._gc()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_manager_respects_interval(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=10, keep=5)
    t = _tree()
    for s in range(1, 25):
        mgr.maybe_save(s, t)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [10, 20]


def test_restore_latest_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    restored, step = mgr.restore_latest(_tree())
    assert restored is None and step == 0


def test_elastic_remesh_restore(tmp_path):
    """Restore with a ShardingCtx re-places leaves under new rules — the
    elastic re-mesh path (single host device degenerates to placement,
    but exercises the full code path)."""
    from repro.distributed.sharding import ShardingCtx, DEFAULT_RULES
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh, DEFAULT_RULES)
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
    axes = {"w": ("p_embed", "p_mlp")}
    save(str(tmp_path / "ck"), t, step=5)
    t2, step = restore(str(tmp_path / "ck"), t, ctx, axes)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(t["w"]))
    assert t2["w"].committed          # explicitly placed by device_put
