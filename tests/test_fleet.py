"""Fleet front-end tests (serving/fleet.py): placement, migration, drain,
aggregated warnings. In-process workers, tiny bf16 configs — the forced
multi-device scaling/parity gates live in benchmarks/fleet_bench.py."""

import warnings

import pytest

from repro.data.pipeline import video_fleet
from repro.serving.engine import ServingEngine, _smoke_cfg
from repro.serving.fleet import _SID_STRIDE, FleetRouter
from repro.serving.server import ServerConfig
from repro.serving.session import ServingConfig


def _cfg():
    return _smoke_cfg("bf16")


def _sc(**kw):
    return ServerConfig.from_serving(
        ServingConfig(microbatch=4, chunk=8), warm_start=False, **kw)


def _solo(cfg, streams, n_frames=16):
    return [ServingEngine(cfg, ServingConfig(microbatch=4, chunk=8),
                          n_classes=8, seed=0).run(st, n_frames=n_frames)
            for st in streams]


# -- construction / placement ---------------------------------------------


def test_bad_args_raise():
    with pytest.raises(ValueError, match="at least one"):
        FleetRouter(_cfg(), _sc(), workers=0, price_per_frame=1.0)
    with pytest.raises(ValueError, match="placement"):
        FleetRouter(_cfg(), _sc(), workers=2, placement="random",
                    price_per_frame=1.0)


def test_workers_get_disjoint_sid_ranges():
    r = FleetRouter(_cfg(), _sc(), workers=3, n_classes=8,
                    price_per_frame=1.0)
    assert [w._next_sid for w in r.workers] == [0, _SID_STRIDE,
                                               2 * _SID_STRIDE]


def test_cost_placement_beats_round_robin_assignment():
    """On a skewed mix, cost placement spreads predicted seconds while rr
    stacks the heavies; price_per_frame=1.0 makes cost == frame count."""
    streams = video_fleet(4, img_size=32, patch=8, seed=0, cut_every=16)
    frames = [30, 10, 10, 10]

    cost = FleetRouter(_cfg(), _sc(), workers=2, n_classes=8,
                       price_per_frame=1.0)
    for st, nf in zip(streams, frames):
        cost.add_job(st, n_frames=nf)
    # job 0 (30) -> w0, everything else piles onto the colder w1
    assert [j.worker for j in cost.jobs.values()] == [0, 1, 1, 1]
    assert cost.queued_seconds(0) == 30.0
    assert cost.queued_seconds(1) == 30.0
    assert cost.queued_frames(0) == 30

    rr = FleetRouter(_cfg(), _sc(), workers=2, n_classes=8,
                     placement="rr", price_per_frame=1.0)
    for st, nf in zip(streams, frames):
        rr.add_job(st, n_frames=nf)
    assert [j.worker for j in rr.jobs.values()] == [0, 1, 0, 1]
    assert rr.queued_seconds(0) == 40.0      # the rr hot spot

    # cost placement's max queue is strictly lower
    assert (max(cost.queued_seconds(i) for i in range(2)) <
            max(rr.queued_seconds(i) for i in range(2)))


# -- serving --------------------------------------------------------------


@pytest.mark.filterwarnings("ignore:fleet dead buckets")
def test_serve_matches_solo_engine():
    """Fleet-served predictions are identical to per-stream solo engine
    runs (micro-batches are session-pure) and jobs are marked done."""
    cfg = _cfg()
    streams = video_fleet(2, img_size=32, patch=8, seed=3, cut_every=16)
    solo = _solo(cfg, streams)
    r = FleetRouter(cfg, _sc(), workers=2, n_classes=8, price_per_frame=1.0)
    jobs = [r.add_job(st, n_frames=16) for st in streams]
    res = r.serve()
    assert {jobs[0].worker, jobs[1].worker} == {0, 1}
    for i, j in enumerate(jobs):
        assert j.done and res[j.job_id].frames == 16
        assert res[j.job_id].predictions == solo[i].predictions
    assert r.aggregate_fps > 0
    assert len(r.last_walls) == 2


# -- migration / rebalance / drain ----------------------------------------


@pytest.mark.filterwarnings("ignore:fleet dead buckets")
def test_migrate_preserves_predictions():
    cfg = _cfg()
    streams = video_fleet(2, img_size=32, patch=8, seed=3, cut_every=16)
    solo = _solo(cfg, streams)
    r = FleetRouter(cfg, _sc(), workers=2, n_classes=8, price_per_frame=1.0)
    jobs = [r.add_job(st, n_frames=16) for st in streams]
    moved = r.migrate(jobs[0].job_id, 1)     # both now on worker 1
    assert moved.worker == 1
    assert r.migrate(jobs[1].job_id, jobs[1].worker) is jobs[1]   # no-op
    res = r.serve()
    for i, j in enumerate(jobs):
        assert j.worker == 1
        assert res[j.job_id].predictions == solo[i].predictions
    with pytest.raises(ValueError, match="already served"):
        r.migrate(jobs[0].job_id, 0)


def test_rebalance_moves_smallest_improving_job():
    streams = video_fleet(4, img_size=32, patch=8, seed=0, cut_every=16)
    r = FleetRouter(_cfg(), _sc(), workers=2, n_classes=8,
                    placement="rr", price_per_frame=1.0)
    jobs = [r.add_job(st, n_frames=nf)
            for st, nf in zip(streams, [30, 10, 10, 10])]
    # rr: w0 = {30, 10} = 40s, w1 = {10, 10} = 20s; gap 20 -> moving the
    # 10s job equalizes (|20 - 2*10| = 0), after which no move improves
    moved = r.rebalance()
    assert moved == [jobs[2].job_id]
    assert r.queued_seconds(0) == r.queued_seconds(1) == 30.0
    assert r.rebalance() == []               # already balanced


@pytest.mark.filterwarnings("ignore:fleet dead buckets")
def test_drain_preserves_predictions(tmp_path):
    cfg = _cfg()
    streams = video_fleet(2, img_size=32, patch=8, seed=3, cut_every=16)
    solo = _solo(cfg, streams)
    r = FleetRouter(cfg, _sc(checkpoint_dir=str(tmp_path)), workers=2,
                    n_classes=8, price_per_frame=1.0)
    jobs = [r.add_job(st, n_frames=16) for st in streams]
    old = r.workers[0]
    repl = r.drain(0, root=str(tmp_path))
    assert r.workers[0] is repl and repl is not old
    res = r.serve()
    for i, j in enumerate(jobs):
        assert res[j.job_id].predictions == solo[i].predictions


# -- aggregated dead-bucket warning ---------------------------------------


def test_dead_bucket_warning_aggregated():
    """Workers serve with per-session warnings muted; the router emits ONE
    UserWarning naming every (worker, dead buckets) pair."""
    cfg = _cfg()
    sc = ServerConfig.from_serving(
        ServingConfig(microbatch=4, chunk=8, force_bucket=0.5),
        warm_start=False)
    r = FleetRouter(cfg, sc, workers=2, n_classes=8, price_per_frame=1.0)
    for st in video_fleet(2, img_size=32, patch=8, seed=0, cut_every=16):
        r.add_job(st, n_frames=16)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r.serve()
    dead = [w for w in rec if "fleet dead buckets" in str(w.message)]
    assert len(dead) == 1
    assert "worker 0" in str(dead[0].message)
    assert "worker 1" in str(dead[0].message)


# -- spawn-mode guards ----------------------------------------------------


def test_spawn_mode_guards_shared_state_surfaces():
    """Spawn workers share no address space: migrate/rebalance/drain must
    raise instead of silently corrupting, and pricing falls back to frame
    counts (no in-process worker 0 to compile a cost model on)."""
    r = FleetRouter(_cfg(), _sc(), workers=2, n_classes=8, spawn=True)
    assert r.workers == []                   # built in the children
    assert r.price_per_frame() == 1.0
    for call in (lambda: r.migrate(0, 1), r.rebalance,
                 lambda: r.drain(0)):
        with pytest.raises(ValueError, match="in-process"):
            call()
