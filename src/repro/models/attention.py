"""Attention: GQA blockwise-flash (train/prefill) + cache decode.

Design notes
------------
* ``blockwise_attention`` is a pure-XLA flash attention: it scans KV blocks
  with a running (max, sum, acc) accumulator so the (S, S) score matrix is
  never materialized — required for the 32k prefill shapes. A Pallas TPU
  kernel with the same contract lives in kernels/flash_attention.py; the
  XLA version is what the CPU dry-run lowers (kernels cannot compile for
  the TPU target on this host) and doubles as the oracle.
* Causal masking over a KV-block scan wastes ~2x score FLOPs (fully-masked
  blocks are still computed). With ``causal_block_skip`` the scan switches
  to a q-block x kv-block double scan whose body skips fully-masked blocks
  via lax.cond — a roofline hillclimb knob (see EXPERIMENTS.md §Perf).
* Decode attention runs over a seq-sharded KV cache (logical axis "kv_seq"
  -> mesh "model"); the softmax over the sharded axis lowers to the
  flash-decoding partial-merge collectives under GSPMD (verified in the
  dry-run HLO: KB-scale all-reduces, no cache all-gather).
* ``decomposed=True`` applies paper Eq. 2: scores = (Q W_K^T/sqrt(d)) X^T.
  Blockwise structure is unchanged — "K" becomes X and Q is pre-multiplied
  by W_K^T (exact-equivalence tested in tests/test_decomposition.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

__all__ = ["blockwise_attention", "full_attention", "decode_attention",
           "update_kv_cache"]

NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, causal: bool, window: int) -> jnp.ndarray:
    """(…q, …kv) additive mask bias in f32. window>0 = local attention."""
    m = jnp.ones(q_pos.shape + kv_pos.shape, jnp.bool_)
    if causal:
        m &= q_pos[..., None] >= kv_pos[None, ...]
    if window > 0:
        m &= q_pos[..., None] - kv_pos[None, ...] < window
    return jnp.where(m, 0.0, NEG_INF)


def full_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Reference attention materializing scores. q: (B,Sq,H,D); k/v:
    (B,Skv,Hkv,D). GQA by head-group broadcast. Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(skv)
    s = s + _mask_bias(q_pos, kv_pos, causal, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _flash_scan_kv(q, k, v, q_pos, causal, window, block_kv,
                   p_bf16=False, qk_bf16=False):
    """Inner flash loop: scan over KV blocks, vectorized over all Q.

    q: (B, Sq, Hkv, G, D) pre-scaled; k/v: (B, Skv, Hkv, D).
    Returns (B, Sq, Hkv, G, D) f32 accumulator output (unnormalized merge
    already applied)."""
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    nkv = skv // block_kv
    kb = k.reshape(b, nkv, block_kv, hkv, d)
    vb = v.reshape(b, nkv, block_kv, hkv, d)

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, kv_base = xs
        kv_pos = kv_base + jnp.arange(block_kv)
        if qk_bf16:
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.bfloat16),
                           kblk.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
        else:
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q,
                           kblk.astype(jnp.float32))
        bias = _mask_bias(q_pos, kv_pos, causal, window)      # (Sq, bkv)
        s = s + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        if p_bf16:
            # probs+V in bf16 for the PV matmul; running stats stay f32.
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(jnp.bfloat16),
                            vblk.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p,
                            vblk.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    kv_bases = jnp.arange(nkv) * block_kv
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_bases))
    return acc / jnp.maximum(l[..., None], 1e-30)


def _flash_double_scan(q, k, v, q_offset, causal, window, block_q,
                       block_kv, p_bf16=False, qk_bf16=False):
    """Double scan (q-blocks outer, kv-blocks inner) with lax.cond skip of
    fully-masked causal blocks — halves score FLOPs at long seq."""
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    nq, nkv = sq // block_q, skv // block_kv
    qb = jnp.moveaxis(q.reshape(b, nq, block_q, hkv, g, d), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nkv, block_kv, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, block_kv, hkv, d), 1, 0)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)
        m0 = jnp.full((b, block_q, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, block_q, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, block_q, hkv, g, d), jnp.float32)

        def kv_step(carry, kj_blk):
            kj, kblk, vblk = kj_blk
            kv_lo = kj * block_kv

            def compute(c):
                m, l, acc = c
                kv_pos = kv_lo + jnp.arange(block_kv)
                if qk_bf16:
                    s = jnp.einsum("bqhgd,bkhd->bqhgk",
                                   qblk.astype(jnp.bfloat16),
                                   kblk.astype(jnp.bfloat16),
                                   preferred_element_type=jnp.float32)
                else:
                    s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk,
                                   kblk.astype(jnp.float32))
                bias = _mask_bias(q_pos, kv_pos, causal, window)
                s = s + bias[None, :, None, None, :]
                m_new = jnp.maximum(m, s.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                if p_bf16:
                    pv = jnp.einsum("bqhgk,bkhd->bqhgd",
                                    p.astype(jnp.bfloat16),
                                    vblk.astype(jnp.bfloat16),
                                    preferred_element_type=jnp.float32)
                else:
                    pv = jnp.einsum("bqhgk,bkhd->bqhgd", p,
                                    vblk.astype(jnp.float32))
                return (m_new, l * alpha + p.sum(-1),
                        acc * alpha[..., None] + pv)

            # skip iff every kv position in the block is masked for every q
            # position of this q block (causal: kv_lo > last q pos; window:
            # kv block entirely left of the window).
            live = jnp.asarray(True)
            if causal:
                live &= kv_lo <= q_pos[-1]
            if window > 0:
                live &= (kv_lo + block_kv - 1) > (q_pos[0] - window)
            return jax.lax.cond(live, compute, lambda c: c, carry), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        return None, acc / jnp.maximum(l[..., None], 1e-30)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, hkv, g, d)


def blockwise_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        block_q=512, block_kv=1024, block_skip=False,
                        p_bf16=False, qk_bf16=False):
    """Flash attention (XLA). q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D) -> (B,Sq,H,D).

    Falls back to ``full_attention`` when the sequence is shorter than one
    block (smoke-test shapes). The whole region is wrapped in a
    ``named_scope`` so the roofline analyzer can attribute its HBM traffic
    (the fused Pallas kernel keeps these tensors in VMEM on real TPU)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    if sq % block_q or skv % block_kv or skv <= block_kv:
        with jax.named_scope("full_attn"):
            return full_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    g = h // hkv
    with jax.named_scope("flash_attn"):
        qs = (q.reshape(b, sq, hkv, g, d).astype(jnp.float32) / math.sqrt(d))
        if block_skip:
            out = _flash_double_scan(qs, k, v, q_offset, causal, window,
                                     block_q, block_kv, p_bf16=p_bf16,
                                     qk_bf16=qk_bf16)
        else:
            q_pos = q_offset + jnp.arange(sq)
            out = _flash_scan_kv(qs, k, v, q_pos, causal, window, block_kv,
                                 p_bf16=p_bf16, qk_bf16=qk_bf16)
        return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, window=0,
                     bf16_compute=False):
    """One-token attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, D); k/v_cache: (B, S, Hkv, D); length: scalar count of valid
    cache entries (the new token's K/V must already be written at
    ``length - 1``). Softmax/max/sum over the sharded S axis lower to the
    flash-decoding merge collectives under GSPMD.

    bf16_compute: read the cache in its storage dtype with f32 dot
    accumulation. Without it the operand f32 casts make XLA materialize
    an f32 copy of the WHOLE cache inside the layer loop (verified in the
    dry-run HLO — 2x footprint + full-cache convert traffic per layer).
    """
    b, _, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = h // hkv
    with jax.named_scope("decode_attn"):
        if bf16_compute:
            qb = (q.reshape(b, hkv, g, d) / math.sqrt(d)).astype(
                k_cache.dtype)
            scores = jnp.einsum("bhgd,bshd->bhgs", qb, k_cache,
                                preferred_element_type=jnp.float32)
        else:
            qf = q.reshape(b, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
            scores = jnp.einsum("bhgd,bshd->bhgs", qf,
                                k_cache.astype(jnp.float32))
        pos = jnp.arange(s)
        valid = pos < length
        if window > 0:
            valid &= pos >= length - window
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        # explicit stable softmax (keeps the sharded-axis reductions obvious)
        m = scores.max(axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        l = p.sum(axis=-1, keepdims=True)
        if bf16_compute:
            o = jnp.einsum("bhgs,bshd->bhgd", p.astype(k_cache.dtype),
                           v_cache, preferred_element_type=jnp.float32)
            o = o / l[..., 0, None]
        else:
            o = jnp.einsum("bhgs,bshd->bhgd",
                           p, v_cache.astype(jnp.float32)) / l[..., 0, None]
        return o.reshape(b, 1, h, d).astype(q.dtype)


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos):
    """Write the new token's K/V at ``pos``. Caches (B,S,Hkv,D); new
    (B,1,Hkv,D). GSPMD turns the dynamic-update-slice on a sharded S axis
    into a masked local write."""
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache
