"""Deliverable (g): the full per-(arch x shape x mesh) roofline table,
read from the dry-run artifacts under experiments/dryrun/."""

from __future__ import annotations

import glob
import json
import os

from repro.roofline.hlo_analysis import Cost
from repro.roofline.report import make_row, render_table

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_rows(mesh: str | None = None, variant: str = "baseline"):
    rows, skips, fails = [], [], []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(fn))
        if r.get("variant", "baseline") != variant:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            skips.append(r)
            continue
        if r["status"] != "ok":
            fails.append(r)
            continue
        cost = Cost(r["parsed"]["flops"], r["parsed"]["bytes"],
                    r["parsed"]["coll_bytes"], r["parsed"]["coll_by_op"])
        rows.append(make_row(r["arch"], r["shape"], r["mesh"], cost,
                             r["roofline"], r.get("bytes_per_device")))
    return rows, skips, fails


def run() -> list[dict]:
    print("\n== Roofline table (single-pod 16x16, baselines) ==")
    rows, skips, fails = load_rows(mesh="pod")
    if not rows:
        print(f"  (no dry-run artifacts under {DRYRUN_DIR} — run "
              "`python -m repro.launch.dryrun --all` first)")
        return []
    print(render_table(rows))
    print(f"\n{len(rows)} cells ok, {len(skips)} documented skips, "
          f"{len(fails)} failures")
    for s in skips:
        print(f"  SKIP {s['arch']} {s['shape']}: {s['reason'][:70]}")
    mrows, _, mfails = load_rows(mesh="multipod")
    print(f"multipod: {len(mrows)} cells ok, {len(mfails)} failures")
    assert not fails and not mfails, "dry-run failures present"
    return rows
