"""Pallas flash-attention kernel vs dense oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import fused_attention
from repro.kernels.ref import flash_attention_ref


def _qkv(key, b, h, hkv, sq, skv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, sq, d), dtype)
    k = jax.random.normal(k2, (b, hkv, skv, d), dtype)
    v = jax.random.normal(k3, (b, hkv, skv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 4, 4, 128, 32),       # MHA
    (2, 4, 2, 128, 32),       # GQA 2x
    (1, 8, 1, 256, 16),       # MQA
])
def test_causal_matches_ref(b, h, hkv, s, d):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, hkv, s, s, d)
    out = flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_local_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 128, 128, 16)
    out = flash_attention(q, k, v, causal=True, window=window, bq=32,
                          bkv=32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_non_causal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 64, 128, 32)
    out = flash_attention(q, k, v, causal=False, bq=32, bkv=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_invariance(bq, bkv):
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 4, 2, 128, 128, 32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bf16_io():
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 64, 64, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32)
    ref = flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_fused_attention_models_layout():
    """(B, S, H, D) wrapper == models/attention layout oracle."""
    from repro.models.attention import full_attention
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 128, 4, 32))
    k = jax.random.normal(k2, (2, 128, 2, 32))
    v = jax.random.normal(k3, (2, 128, 2, 32))
    out = fused_attention(q, k, v, causal=True, bq=64, bkv=64)
    ref = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
