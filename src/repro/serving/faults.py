"""Deterministic fault injection for the serving line.

The north-star deployment is a long-lived server multiplexing many camera
streams; at that scale faults are routine, not exceptional — transient
device errors mid-flush, sensors hiccuping mid-ingest, checkpoint volumes
going away, thermal stalls, whole-process preemptions. Light-Bound
Transformers (PAPERS.md) makes the same point for SiPh vision systems:
they must be *engineered for* faults, not just evaluated clean. This
module is the controlled way to produce those faults, so the server's
isolation/retry/migration machinery can be gated in CI instead of trusted.

Design mirrors ``core/noise.py``'s ``NoiseSpec``:

  * ``FaultSpec`` is a frozen, seeded, hashable operating point. No spec
    -> no injector object at all: the serving loop's fault seams are
    ``if injector is not None`` checks, so a fault-free server runs the
    exact pre-fault-harness instruction stream (pinned bitwise by
    tests/test_serving_faults.py on every backend combo).
  * Every injection decision is a pure function of ``(seed, site)`` where
    the *site* names the logical event (bucket + first frame of a flush,
    session + chunk of an ingest, checkpoint step, scheduling round) —
    never of wall time or call order. Two runs with the same spec inject
    the same faults at the same frames, and a retried attempt of the same
    site replays its own fate: a transient site fails its first
    ``transient_failures`` attempts, then succeeds. That is what makes
    "all sessions complete bitwise-identically under 10% flush faults"
    a *testable* claim (benchmarks/fault_bench.py).

Fault classes (see README "Failure semantics & fault injection"):

  ``TransientFault``   retryable device/ingest error — the server retries
                       the same work with bounded exponential backoff;
  ``FatalFault``       unrecoverable for the owning session(s) only;
  ``CheckpointFault``  checkpoint I/O failure — serving must continue on
                       the last good snapshot;
  ``ServerCrash``      whole-process loss (preemption) — the
                       ``serve_with_restarts`` restore path's trigger.
"""

from __future__ import annotations

import zlib
from collections import Counter
from dataclasses import dataclass

import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "InjectedFault", "TransientFault",
           "FatalFault", "CheckpointFault", "ServerCrash", "SessionFailure",
           "ServeError", "serve_with_restarts"]


class InjectedFault(RuntimeError):
    """Base of every injector-raised failure (all are ``RuntimeError``\\ s
    so un-instrumented code treats them like real faults)."""


class TransientFault(InjectedFault):
    """Retryable: the same work succeeds on a later attempt."""


class FatalFault(InjectedFault):
    """Unrecoverable for the session(s) that own the failing work."""


class CheckpointFault(InjectedFault):
    """Checkpoint I/O failed; the previous snapshot is still good."""


class ServerCrash(InjectedFault):
    """The whole serve loop dies (simulated preemption / process loss)."""


class SessionFailure(RuntimeError):
    """Internal control flow: ``sids`` must be terminated for ``reason``
    while every other session keeps serving (raised by the flush path,
    handled by the scheduling loop — never escapes ``serve()``)."""

    def __init__(self, sids: tuple, reason: str):
        super().__init__(f"session(s) {list(sids)}: {reason}")
        self.sids = tuple(sids)
        self.reason = reason


class ServeError(RuntimeError):
    """An *attributed* mid-serve failure: carries the failing session ids /
    bucket / flush context and partial ``StreamResult``\\ s for every
    session that had already fully drained when the loop died (their
    state is complete — abandoning them would discard finished work)."""

    def __init__(self, message: str, context: dict | None = None,
                 partial_results: dict | None = None):
        super().__init__(message)
        self.context = dict(context or {})
        self.partial_results = dict(partial_results or {})


@dataclass(frozen=True)
class FaultSpec:
    """Seeded, replayable fault operating point (all rates in [0, 1])."""

    flush_fault_rate: float = 0.0    # transient device error per flush site
    flush_fatal_rate: float = 0.0    # unrecoverable device error per flush
    ingest_fault_rate: float = 0.0   # transient sensor error per chunk
    checkpoint_fault_rate: float = 0.0  # checkpoint I/O failure per save
    stall_rate: float = 0.0          # slow-flush (straggler) per flush site
    stall_s: float = 0.05            # seconds a stalled flush hangs
    transient_failures: int = 1      # attempts a transient site fails
    #                                  before it clears (retry succeeds)
    hard_fail_session: int = -1      # >= 0: this sid hard-fails...
    hard_fail_at_chunk: int = 0      # ...at this ingest chunk (FatalFault)
    crash_at_round: int = -1         # >= 0: ServerCrash once at this
    #                                  scheduling round (kill-and-restore)
    seed: int = 0


def _tok(x) -> int:
    if isinstance(x, str):
        return zlib.crc32(x.encode())
    return int(x) & 0xFFFFFFFF


class FaultInjector:
    """Raises the spec'd faults at the serving seams, deterministically.

    Each decision hashes ``(seed, site)`` through its own
    ``np.random.SeedSequence`` — no shared RNG stream is consumed, so
    injections are independent of call order and interleaving, and a
    zero-rate spec draws nothing at all (the hygiene contract:
    ``FaultSpec()`` serving is bitwise identical to no spec)."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.injected: Counter = Counter()
        self._crashed = False

    def _u01(self, *site) -> float:
        ss = np.random.SeedSequence([_tok(self.spec.seed)]
                                    + [_tok(t) for t in site])
        return float(np.random.default_rng(ss).random())

    def _hit(self, rate: float, *site) -> bool:
        return rate > 0.0 and self._u01(*site) < rate

    # -- seams -------------------------------------------------------------

    def ingest(self, sid: int, chunk: int, attempt: int = 0) -> None:
        """Before a session pulls ingest chunk ``chunk``."""
        sp = self.spec
        if sp.hard_fail_session == sid and chunk >= sp.hard_fail_at_chunk:
            self.injected["ingest_fatal"] += 1
            raise FatalFault(f"injected hard sensor failure (session {sid},"
                             f" chunk {chunk})")
        if (attempt < sp.transient_failures
                and self._hit(sp.ingest_fault_rate, "ingest", sid, chunk)):
            self.injected["ingest_transient"] += 1
            raise TransientFault(f"injected transient ingest error "
                                 f"(session {sid}, chunk {chunk}, "
                                 f"attempt {attempt})")

    def flush(self, bucket: int, tag: tuple, attempt: int = 0) -> None:
        """Before a flush's encode launches; ``tag`` is the flush's first
        ``(sid, frame_idx)`` pair — the stable site identity a retry of
        the same flush replays."""
        sp = self.spec
        sid, fidx = int(tag[0]), int(tag[1])
        if self._hit(sp.flush_fatal_rate, "flush_fatal", bucket, sid, fidx):
            self.injected["flush_fatal"] += 1
            raise FatalFault(f"injected fatal device error (bucket "
                             f"k={bucket}, frame {sid}:{fidx})")
        if (attempt < sp.transient_failures
                and self._hit(sp.flush_fault_rate, "flush", bucket, sid,
                              fidx)):
            self.injected["flush_transient"] += 1
            raise TransientFault(f"injected transient device error (bucket "
                                 f"k={bucket}, frame {sid}:{fidx}, attempt "
                                 f"{attempt})")

    def stall_s(self, bucket: int, tag: tuple) -> float:
        """Seconds this flush should hang (0.0 = no stall) — the slow-
        device scenario the straggler watchdog must flag."""
        sp = self.spec
        if self._hit(sp.stall_rate, "stall", bucket, int(tag[0]),
                     int(tag[1])):
            self.injected["stall"] += 1
            return sp.stall_s
        return 0.0

    def checkpoint_io(self, step: int) -> None:
        """Before a checkpoint write."""
        if self._hit(self.spec.checkpoint_fault_rate, "ckpt", step):
            self.injected["checkpoint"] += 1
            raise CheckpointFault(f"injected checkpoint I/O failure "
                                  f"(step {step})")

    def round_tick(self, rnd: int) -> None:
        """End of every scheduling round; fires the (one-shot) crash."""
        sp = self.spec
        if sp.crash_at_round >= 0 and rnd >= sp.crash_at_round \
                and not self._crashed:
            self._crashed = True
            self.injected["crash"] += 1
            raise ServerCrash(f"injected server crash (round {rnd})")

    def report(self) -> str:
        if not self.injected:
            return "no faults injected"
        return ", ".join(f"{k}={v}" for k, v in sorted(self.injected.items()))


# ---------------------------------------------------------------------------
# serving-side run_with_restarts
# ---------------------------------------------------------------------------

def serve_with_restarts(make_server, register, root: str,
                        max_restarts: int = 3, streams: dict | None = None,
                        verbose: bool = False, on_restart=None):
    """Serve to completion across server crashes — the serving analogue of
    ``distributed.fault_tolerance.run_with_restarts``.

    ``make_server(attempt)`` builds a fresh ``StreamServer`` whose
    ``ServerConfig`` checkpoints into ``root`` (``checkpoint_dir`` /
    ``checkpoint_every``); ``register(server)`` registers the fleet's
    sessions for a cold start. On every attempt: if ``root`` holds a
    checkpoint, the live sessions are **restored** from the latest
    snapshot (``register`` is not called — the snapshot carries each
    stream's spec, or pass ``streams={sid: stream}`` for non-serializable
    sources); otherwise ``register`` seeds them fresh. A crash restarts
    the loop from the last snapshot with the ingest cursor, mask caches,
    accounting, queued micro-batches and DriftState restored bitwise, so
    the final predictions equal an uninterrupted run's (gated by
    benchmarks/fault_bench.py). Returns ``(results, restarts, server)``.
    """
    from repro.checkpoint.checkpoint import latest_step

    restarts = 0
    while True:
        server = make_server(restarts)
        if latest_step(root) is None:
            register(server)
        else:
            server.restore_checkpoint(root, streams=streams)
        try:
            return server.serve(verbose=verbose), restarts, server
        except ServeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts)
