"""Pallas TPU kernel: fused int8 photonic GELU-MLP (w1 + bias + GELU +
requant + w2 in one kernel).

The encoder FFN is ~2/3 of ViT FLOPs (Opto-ViT Sec. IV), and on the
composed path it runs as two independent ``photonic_matmul_prequant``
dispatches with a float GELU round-trip between them: the ``(B*S, d_ff)``
hidden activation is dequantized to float, written to HBM, read back,
activated, reduced for a fresh absmax scale, requantized and written again
before the second matmul ever starts. Once the MACs are optical that
inter-op traffic — not the matmuls — is the serving bottleneck
(Lightening-Transformer's fused DPTC dataflow makes the same argument).

This kernel keeps the hidden state in VMEM end to end:

  * grid = (2, M/bm): a **two-phase walk** over row blocks. Phase 0
    computes each block's w1-matmul + bias + GELU entirely in VMEM and
    folds its |hidden| maximum into an SMEM running scalar — after the
    phase-0 sweep that scalar *is* the per-tensor absmax the composed
    path computes on the HBM-resident hidden tensor (max is exact, so
    the block-max-of-maxes is bit-identical to the global reduction).
  * phase 1 recomputes the block (activations stream from VMEM-resident
    x; nothing is re-read from HBM), requantizes it with the now-final
    scale — the same ``core.quant.quantize`` arithmetic — and feeds the
    int8 codes straight into the w2 int32 accumulate. Only the final
    (bm, d_out) f32 block is written out.

  Parity contract: the integer accumulates are exact, but the kernel body
  compiles as one unit, so the compiler may contract the dequant multiply
  and bias add into an FMA — a last-ulp freedom on the GELU input that
  the requantization can amplify into a +-1 code flip at a rounding
  boundary. Kernel-vs-twin parity is therefore held to a one-quant-step
  tolerance (the same policy as the flash attention kernel vs its
  oracle); the **XLA twin** is the bit-pinned lowering — identical to the
  composed two-linear dispatch in every execution context
  (tests/test_fused_ffn.py).

  The recompute doubles the w1 MACs but removes 2 x M x d_ff x 4 bytes of
  HBM hidden traffic per call; on the photonic core (and on TPU at serving
  M) the dataflow is bandwidth-bound, so the trade goes the right way.
  Both weight banks ride along whole (int8 codes + per-out-channel scales
  — the quantize-once cache's tuned MR state), which bounds supported
  widths to VMEM: d_ff * (d_in + d_out) int8 + (bm, d_ff) f32 x2 — every
  ViT variant in this repo fits; larger d_ff would need an N-tiled phase 0.

Packed RoI skip: ``live_rows`` (the one-shape serving layout — kept
tokens are a static prefix of the score order) drops fully-pruned token
rows *before the grid is built*, the row-space analogue of the masked
flash kernel skipping pruned KV blocks: dead rows cost zero FLOPs in
both matmuls, the GELU and the absmax, and come back as exact zeros.
Activation scales then reduce over live rows only — identical to running
the composed path on the live slice (the parity contract
tests/test_fused_ffn.py pins).

``fused_ffn_xla`` lowers the same contract for CPU hosts (the Pallas
interpreter is a correctness emulator, not a perf path — same policy as
kernels/flash_attention.py): identical quantize / int32-accumulate /
dequant / GELU / requant ops in one jit, with the same static live-row
slicing. ``fused_ffn`` picks per host.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant
from repro.kernels.flash_attention import _pad_axis

__all__ = ["fused_ffn_kernel", "fused_ffn_int8", "fused_ffn_xla",
           "fused_ffn", "fused_ffn_sharded"]


def _bits_pair(bits) -> tuple[int, int]:
    """Static (w1 width, w2 width) from an int or pair — mixed-precision
    bit plans may cache the two banks at different widths. The input
    activation quantizes at w1's width (its matmul's operand precision)
    and the hidden state requantizes at w2's, exactly the widths the
    composed two-``linear`` dispatch would use."""
    if isinstance(bits, (tuple, list)):
        b1, b2 = (int(b) for b in bits)
    else:
        b1 = b2 = int(bits)
    if not (2 <= b1 <= 8 and 2 <= b2 <= 8):
        raise ValueError(f"fused FFN bit widths {bits!r} outside [2, 8]")
    return b1, b2


def fused_ffn_kernel(xq_ref, sx_ref, w1_ref, sw1_ref, b1_ref,
                     w2_ref, sw2_ref, o_ref, amax_ref, *,
                     bm: int, m_eff: int, bits: int, dt):
    """One (phase, row-block) step of the fused FFN walk.

    Grid (2, M/bm). xq (bm, K1) int8; sx (1, 1) f32 per-tensor activation
    scale; w1 (K1, dff) int8 + sw1 (1, dff) f32 + b1 (1, dff) dt;
    w2 (dff, dout) int8 + sw2 (1, dout) f32; o (bm, dout) f32;
    amax (1, 1) f32 SMEM — the running hidden-absmax, alive across the
    whole sequential grid. ``m_eff`` masks padded rows out of the absmax
    (their x rows are zero, but bias + GELU would still leak a nonzero
    |gelu(b1)| into the scale); ``dt`` is the caller's activation dtype so
    every cast lands exactly where the composed path casts. ``bits`` is
    the *hidden requant* width — w2's cached width under a mixed plan;
    the incoming xq codes were already quantized at w1's width outside.
    """
    phase = pl.program_id(0)
    mi = pl.program_id(1)
    row0 = mi * bm
    _, qmax = quant.quant_range(bits)
    inv_qmax = jnp.float32(1.0 / qmax)

    def hidden():
        # w1 int32 accumulate + dequant epilogue + bias + GELU, all in
        # VMEM — op-for-op the composed linear -> gelu prologue.
        acc = jax.lax.dot_general(xq_ref[...], w1_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        h = (acc.astype(jnp.float32) * sx_ref[0, 0]
             * sw1_ref[0, :][None, :]).astype(dt)
        h = h + b1_ref[0, :][None, :]
        return jax.nn.gelu(h.astype(jnp.float32)).astype(dt)

    @pl.when(jnp.logical_and(phase == 0, mi == 0))
    def _init():
        amax_ref[0, 0] = 0.0

    @pl.when(jnp.logical_and(phase == 0, row0 < m_eff))
    def _scan_absmax():
        g = hidden()
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, g.shape, 0)
        live = jnp.where(rows < m_eff, jnp.abs(g).astype(jnp.float32), 0.0)
        amax_ref[0, 0] = jnp.maximum(amax_ref[0, 0], jnp.max(live))

    @pl.when(jnp.logical_and(phase == 1, row0 < m_eff))
    def _requant_matmul2():
        g = hidden()                                   # VMEM recompute
        scale2 = jnp.maximum(amax_ref[0, 0], 1e-8) * inv_qmax
        hq = jnp.clip(jnp.round(g.astype(jnp.float32) / scale2),
                      -qmax, qmax).astype(jnp.int8)
        acc2 = jax.lax.dot_general(hq, w2_ref[...],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
        o_ref[...] = (acc2.astype(jnp.float32) * scale2
                      * sw2_ref[0, :][None, :])

    @pl.when(jnp.logical_and(phase == 1, row0 >= m_eff))
    def _dead_block():
        o_ref[...] = jnp.zeros_like(o_ref)


def _slice_live(x: jax.Array, live_rows: int | None) -> tuple[jax.Array, int]:
    """Static packed-skip: drop the dead token tail (axis -2) before any
    FLOP is spent — rows are the one-shape score order, so kept rows are a
    prefix. Returns (live slice, live count)."""
    n = x.shape[-2]
    if live_rows is None:
        return x, n
    lv = max(0, min(n, int(live_rows)))
    return x[..., :lv, :], lv


def _restore_dead(y: jax.Array, n: int) -> jax.Array:
    """Zero-fill the dead tail back to the caller's row count: pruned
    rows come back as exact zeros (the residual add then leaves their
    stream state untouched — they are never read as attention keys)."""
    if y.shape[-2] == n:
        return y
    pad = [(0, 0)] * y.ndim
    pad[-2] = (0, n - y.shape[-2])
    return jnp.pad(y, pad)


def fused_ffn_int8(x: jax.Array, w1q: jax.Array, sw1: jax.Array,
                   b1: jax.Array, w2q: jax.Array, sw2: jax.Array,
                   b2: jax.Array, *, bits=8,
                   live_rows: int | None = None, bm: int = 128,
                   interpret: bool = True) -> jax.Array:
    """The Pallas lowering. x (..., n, d_in) float; w1q (d_in, d_ff) int8 +
    sw1 (d_ff,) f32 + b1 (d_ff,); w2q (d_ff, d_out) int8 + sw2 (d_out,)
    f32 + b2 (d_out,). Returns (..., n, d_out) in x.dtype. ``bits`` is an
    int or a (w1, w2) pair (mixed-precision plans — see ``_bits_pair``).
    ``live_rows`` statically prunes the token axis (see module docstring);
    shapes need not be block multiples — operands are padded to the
    128-aligned grid and the result sliced back.
    """
    bits1, bits2 = _bits_pair(bits)
    n_tokens = x.shape[-2]
    xl, lv = _slice_live(x, live_rows)
    if lv == 0:
        return jnp.zeros(x.shape[:-1] + (w2q.shape[1],), x.dtype)
    lead = xl.shape[:-1]
    k1, dff = w1q.shape
    dff2, dout = w2q.shape
    assert xl.shape[-1] == k1 and dff == dff2, (x.shape, w1q.shape, w2q.shape)

    x2 = xl.reshape(-1, k1).astype(jnp.float32)
    m = x2.shape[0]
    sx = quant.absmax_scale(x2, bits=bits1)
    xq = quant.quantize(x2, sx, bits=bits1)

    xq = _pad_axis(_pad_axis(xq, 0, bm), 1, 128)
    w1p = _pad_axis(_pad_axis(w1q, 0, 128), 1, 128)
    w2p = _pad_axis(_pad_axis(w2q, 0, 128), 1, 128)
    sw1p = _pad_axis(sw1.reshape(1, -1), 1, 128)
    sw2p = _pad_axis(sw2.reshape(1, -1), 1, 128)
    b1p = _pad_axis(b1.reshape(1, -1), 1, 128)
    k1p, dffp = w1p.shape
    doutp = w2p.shape[1]

    grid = (2, xq.shape[0] // bm)
    kern = functools.partial(fused_ffn_kernel, bm=bm, m_eff=m, bits=bits2,
                             dt=x.dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k1p), lambda p, i: (i, 0)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((k1p, dffp), lambda p, i: (0, 0)),
            pl.BlockSpec((1, dffp), lambda p, i: (0, 0)),
            pl.BlockSpec((1, dffp), lambda p, i: (0, 0)),
            pl.BlockSpec((dffp, doutp), lambda p, i: (0, 0)),
            pl.BlockSpec((1, doutp), lambda p, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, doutp), lambda p, i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xq.shape[0], doutp), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xq, sx.reshape(1, 1), w1p, sw1p, b1p, w2p, sw2p)
    y = out[:m, :dout].astype(x.dtype) + b2
    return _restore_dead(y.reshape(*lead, dout), n_tokens)


def _dequant_epilogue_kernel(acc_ref, sx_ref, sw_ref, o_ref):
    """Per-tensor x per-out-channel dequant of an int32 accumulate block —
    the exact epilogue of kernels/photonic_matmul.py, as its own kernel."""
    o_ref[...] = (acc_ref[...].astype(jnp.float32) * sx_ref[0, 0]
                  * sw_ref[0, :][None, :])


def _dequant_epilogue(acc: jax.Array, sx: jax.Array,
                      sw: jax.Array) -> jax.Array:
    """Dequantize (M, N) int32 -> f32 through a two-block Pallas walk.

    Running the epilogue as a (gridded) kernel is a numerics requirement,
    not a flourish: the composed reference dequantizes *inside*
    ``photonic_matmul_int8``'s grid loop, so the caller's bias add can
    never contract with the final scale multiply. Inlined into one flat
    XLA graph the CPU backend emits an FMA for that multiply-add (it even
    deletes an ``optimization_barrier`` placed between them) — a 1-ulp
    divergence the downstream requantization amplifies into code flips.
    The grid loop is the same fusion boundary the reference has; two row
    blocks keep it a loop at every M (a single-step grid lowers to
    straight-line HLO that XLA sees through).
    """
    m, n = acc.shape
    bm = -(-m // 2)
    accp = _pad_axis(acc, 0, 2 * bm)
    out = pl.pallas_call(
        _dequant_epilogue_kernel,
        grid=(2,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((2 * bm, n), jnp.float32),
        interpret=True,
    )(accp, sx.reshape(1, 1), sw.reshape(1, -1))
    return out[:m]


def _int8_linear_xla(x2: jax.Array, wq: jax.Array, sw: jax.Array, *,
                     bits: int) -> jax.Array:
    """quantize -> int32 accumulate -> dequant, op-for-op the dataflow of
    ``photonic_matmul_prequant`` with the matmul lowered to an XLA integer
    dot (the CPU perf path) and the dequant as the Pallas epilogue kernel
    (the bit-parity anchor — see ``_dequant_epilogue``)."""
    sx = quant.absmax_scale(x2, bits=bits)
    xq = quant.quantize(x2, sx, bits=bits)
    acc = jax.lax.dot_general(xq.astype(jnp.int32), wq.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return _dequant_epilogue(acc, sx, sw)


def fused_ffn_xla(x: jax.Array, w1q: jax.Array, sw1: jax.Array,
                  b1: jax.Array, w2q: jax.Array, sw2: jax.Array,
                  b2: jax.Array, *, bits=8,
                  live_rows: int | None = None) -> jax.Array:
    """XLA lowering of ``fused_ffn_int8`` (same shapes/semantics/codes).

    One jit, no dispatch boundary between the matmuls: XLA fuses the
    dequant -> bias -> GELU -> requant chain element-wise between the two
    integer dots, so the hidden tensor never round-trips through a
    dispatch edge. The kernel's grid-level row skip shows up as the same
    **static packed skip** the masked-attention XLA twin uses: a
    Python-int ``live_rows`` slices the dead token tail away before any
    FLOP — both matmuls, the GELU and both absmax reductions see only
    live rows. Bit-identical to the composed two-linear photonic path on
    the live slice (tests/test_fused_ffn.py) — per matmul at its own
    width when ``bits`` is a (w1, w2) pair.
    """
    bits1, bits2 = _bits_pair(bits)
    n_tokens = x.shape[-2]
    xl, lv = _slice_live(x, live_rows)
    if lv == 0:
        return jnp.zeros(x.shape[:-1] + (w2q.shape[1],), x.dtype)
    lead = xl.shape[:-1]
    dout = w2q.shape[1]
    x2 = xl.reshape(-1, x.shape[-1]).astype(jnp.float32)
    h = _int8_linear_xla(x2, w1q, sw1, bits=bits1).astype(x.dtype) + b1
    g = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = _int8_linear_xla(g.astype(jnp.float32), w2q, sw2,
                         bits=bits2).astype(x.dtype) + b2
    return _restore_dead(y.reshape(*lead, dout), n_tokens)


def _int8_linear_sharded(x2: jax.Array, wq: jax.Array, sw: jax.Array, *,
                         bits: int, scale_axes,
                         psum_axis: str | None = None) -> jax.Array:
    """``_int8_linear_xla`` for use *inside* ``shard_map``: the activation
    absmax scale is pmax'd over ``scale_axes`` (so every shard quantizes
    with the scale the unsharded launch would compute — the bitwise-parity
    anchor), and an optional ``psum_axis`` reduces row-sharded partial
    accumulates exactly in int32 before the dequant epilogue. With wq
    column-sharded (no psum) the output holds this shard's columns of the
    full result; with wq row-sharded + psum it holds the full contraction,
    replicated over the model axis — either way bit-identical to the
    corresponding slice of the unsharded ``_int8_linear_xla``."""
    from repro.distributed.collectives import (exact_int_psum,
                                               replicated_absmax_scale)
    sx = replicated_absmax_scale(x2, bits, scale_axes)
    xq = quant.quantize(x2, sx, bits=bits)
    acc = jax.lax.dot_general(xq.astype(jnp.int32), wq.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    if psum_axis is not None:
        acc = exact_int_psum(acc, psum_axis)
    return _dequant_epilogue(acc, sx, sw)


def fused_ffn_sharded(x: jax.Array, w1q: jax.Array, sw1: jax.Array,
                      b1: jax.Array, w2q: jax.Array, sw2: jax.Array,
                      b2: jax.Array, *, bits=8,
                      live_rows: int | None = None,
                      model_axis: str = "model",
                      scale_axes=("data", "model")) -> jax.Array:
    """``fused_ffn_xla`` under ``shard_map`` over the d_ff (model) axis.

    Per-shard operands: w1q (d_in, d_ff/M) columns + sw1/b1 (d_ff/M,),
    w2q (d_ff/M, d_out) rows + *full* sw2 (d_out,) / b2 (d_out,). The
    hidden activation lives column-sharded (each shard runs its GELU on
    its own d_ff slice); the only cross-shard traffic is two scalar pmaxes
    (activation absmax scopes stay global — ``replicated_absmax_scale``)
    and one int32 psum of the w2 partial accumulates (exact). Every float
    op then sees bit-identical inputs to the unsharded twin, including
    the Pallas dequant epilogue (the FMA fusion boundary), so the result
    is bitwise-equal to ``fused_ffn_xla`` on the gathered operands.
    ``scale_axes`` must name every mesh axis the token rows are split
    over *plus* the model axis (batch-sharded callers pass both)."""
    bits1, bits2 = _bits_pair(bits)
    n_tokens = x.shape[-2]
    xl, lv = _slice_live(x, live_rows)
    if lv == 0:
        return jnp.zeros(x.shape[:-1] + (w2q.shape[1],), x.dtype)
    lead = xl.shape[:-1]
    dout = w2q.shape[1]
    x2 = xl.reshape(-1, x.shape[-1]).astype(jnp.float32)
    h = _int8_linear_sharded(x2, w1q, sw1, bits=bits1,
                             scale_axes=scale_axes).astype(x.dtype) + b1
    g = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = _int8_linear_sharded(g.astype(jnp.float32), w2q, sw2, bits=bits2,
                             scale_axes=scale_axes,
                             psum_axis=model_axis).astype(x.dtype) + b2
    return _restore_dead(y.reshape(*lead, dout), n_tokens)


def fused_ffn(x: jax.Array, w1q: jax.Array, sw1: jax.Array, b1: jax.Array,
              w2q: jax.Array, sw2: jax.Array, b2: jax.Array, *,
              bits=8, live_rows: int | None = None, bm: int = 128,
              interpret: bool = True) -> jax.Array:
    """The fused int8 FFN, lowered for the host it runs on: the Pallas
    kernel when compiling for TPU (``interpret=False``), the XLA twin on
    CPU hosts (the serving hot path's FFN entry point, dispatched by
    ``core.backend.ffn``). Deliberately *not* jitted here: the hot path
    always runs under its caller's jit (the single-jit encoder step in
    models/vit.py or the serving engine's encode), and an extra nested
    jit would only change fusion boundaries against the composed
    reference."""
    if interpret:
        return fused_ffn_xla(x, w1q, sw1, b1, w2q, sw2, b2, bits=bits,
                             live_rows=live_rows)
    return fused_ffn_int8(x, w1q, sw1, b1, w2q, sw2, b2, bits=bits,
                          live_rows=live_rows, bm=bm, interpret=False)
