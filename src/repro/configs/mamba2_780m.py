"""mamba2-780m [ssm]: 48L d_model=1536, attn-free SSD, vocab=50280,
ssm_state=128 (arXiv:2405.21060). Paper technique applicability: photonic
w8a8 linears apply to all projections; Eq. 2 decomposition inapplicable
(no QK^T) — see DESIGN.md §Arch-applicability."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=24, kv_heads=24,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, conv_kernel=4,
        ssm_chunk=256,
        microbatch_steps=1,
    )
