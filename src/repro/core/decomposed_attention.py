"""Matrix-decomposition attention dataflow (paper Eq. 2).

Standard attention computes scores as

    S = Q @ K^T,   Q = X @ W_Q,  K = X @ W_K.

On the photonic core one operand of every MatMul must be *tuned* onto MR
banks — a slow operation — so computing S requires waiting for K, re-tuning a
core with K^T, and buffering K meanwhile. The paper removes the bubble by
re-associating (ReTransformer [21] decomposition):

    Q @ K^T = Q @ (X @ W_K)^T = (Q @ W_K^T) @ X^T            (Eq. 2)

Now everything that must be tuned (W_Q, W_K^T, X^T, later softmax(S) and W_V)
is known at step start, enabling the pipelined 5-core schedule of Fig. 5. The
1/sqrt(d_k) scale is folded into the tuned W_K^T (no extra division pass).

On TPU the decomposition is still meaningful:
  * it removes K from HBM residency (one fewer (n, d_k) intermediate per
    head) — visible in the roofline bytes term;
  * it changes the FLOP profile: standard = 2*n*dm*dk (K proj) + 2*n^2*dk
    (scores); decomposed = 2*n*dk*dm (Q @ W_K^T, a (n,dk)x(dk,dm) matmul)
    + 2*n^2*dm (scores against X^T). Since dm = h*dk > dk the decomposed
    form always spends 2*n^2*(dm - dk) EXTRA score FLOPs; the paper's win
    is the removed tuning bubble + intermediate buffering (a latency/
    memory trade, quantified in benchmarks/fig9_latency.py), not FLOPs.
    Numerics are identical up to fp reassociation (tests assert allclose).

Both orderings are exposed; models pick via ``attn_impl`` config. Whatever
the ordering, the score-softmax-PV core runs through ``core.backend.attend``
— one dispatch point over the attention backends (xla materialized scores |
fused RoI-masked flash Pallas kernel), selected by
``ArchConfig.attn_backend`` / ``ExecPolicy.attn_backend``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backend import ExecPolicy, QuantizedWeight, attend, linear

__all__ = ["attention_scores_standard", "attention_scores_decomposed",
           "mhsa_standard", "mhsa_decomposed", "decomposition_flops"]


def _as_array(w) -> jnp.ndarray:
    """Raw float weight from either representation. The decomposed path
    re-derives W_K^T slices (a *re-tuning* on hardware), so a cached
    QuantizedWeight is dequantized first."""
    return w.dequantize() if isinstance(w, QuantizedWeight) else w


def attention_scores_standard(x: jnp.ndarray, wq: jnp.ndarray, wk: jnp.ndarray,
                              scale: float) -> jnp.ndarray:
    """S = (X W_Q)(X W_K)^T * scale.  x: (..., n, dm); wq/wk: (dm, dk)."""
    q = x @ wq
    k = x @ wk
    return (q @ jnp.swapaxes(k, -1, -2)) * scale


def attention_scores_decomposed(x: jnp.ndarray, wq: jnp.ndarray, wk: jnp.ndarray,
                                scale: float) -> jnp.ndarray:
    """S = ((X W_Q) (W_K^T * scale)) X^T — Eq. 2 with the scale folded in.

    The fold into W_K^T matches the paper ("our weight MR bank is tuned by
    W_K^T / sqrt(d_k) directly").
    """
    q = x @ wq                                    # (..., n, dk)
    qwk = q @ (jnp.swapaxes(wk, -1, -2) * scale)  # (..., n, dm)
    return qwk @ jnp.swapaxes(x, -1, -2)          # (..., n, n)


def _heads_split(t: jnp.ndarray, h: int) -> jnp.ndarray:
    *lead, n, d = t.shape
    return t.reshape(*lead, n, h, d // h).swapaxes(-2, -3)  # (..., h, n, dh)


def _fused_prequant_ineligible_reason(params: dict,
                                      policy: ExecPolicy | None,
                                      x: jnp.ndarray) -> str | None:
    """None when the whole MHSA block can take the one-jit serving hot
    path (kernels/ops.py::fused_roi_attention_prequant): int8 Pallas
    matmul backend + flash attention core + quantize-once cached QKV at
    (possibly different — mixed-precision plans) <= 8-bit widths. Else a
    human-readable reason for the composed fallback."""
    p = policy or ExecPolicy()
    if p.noise is not None:
        return ("calibrated device noise is active (ExecPolicy.noise) — "
                "the fused prequant kernel is the clean digital contract; "
                "noisy execution runs the composed analog dispatch")
    if p.resolve_attn_backend() != "flash":
        return (f"attention backend is {p.resolve_attn_backend()!r}, "
                f"fused prequant needs 'flash'")
    if p.resolve_backend() != "photonic_pallas":
        return (f"matmul backend is {p.resolve_backend()!r}, fused "
                f"prequant needs 'photonic_pallas'")
    if x.ndim != 3:
        return f"x.ndim == {x.ndim}, fused prequant needs (B, n, dm)"
    if not all(isinstance(params[n], QuantizedWeight)
               for n in ("wq", "wk", "wv")):
        return "QKV not quantize-once cached (run prepare_params)"
    bits = tuple(params[n].bits for n in ("wq", "wk", "wv"))
    if not all(isinstance(b, int) and b <= 8 for b in bits):
        return (f"QKV bit widths {bits} not all single <= 8-bit widths "
                f"(stacked per-layer bits must be sliced first)")
    return None


def _fused_prequant_eligible(params: dict, policy: ExecPolicy | None,
                             x: jnp.ndarray) -> bool:
    return _fused_prequant_ineligible_reason(params, policy, x) is None


def mhsa_standard(x: jnp.ndarray, params: dict, heads: int,
                  policy: ExecPolicy | None = None,
                  mask: jnp.ndarray | None = None,
                  kv_len: int | None = None) -> jnp.ndarray:
    """Multi-head self-attention, standard dataflow.

    params: wq/wk/wv (dm, dm), wo (dm, dm) — per-head splits taken
    internally. The four weight projections route through the backend
    dispatch (``linear``); the score-softmax-PV core routes through the
    attention dispatch (``attend``: xla materialized scores or the fused
    RoI-masked flash kernel). ``mask`` (..., n) keep-mask removes tokens
    from the key axis (RoI mask mode: shapes stay static, dropped patches
    contribute nothing — and under the flash backend they cost no score
    FLOPs either); ``kv_len`` is the packed alternative (one-shape serving
    mode: keys >= kv_len pruned, static skip on the flash backend). With
    the int8 Pallas backend + flash attention + cached weights the
    projections and kernel fuse into a single jit entry point (the serving
    hot path); it computes the exact same numbers.
    """
    dm = x.shape[-1]
    p = policy or ExecPolicy()
    reason = _fused_prequant_ineligible_reason(params, policy, x)
    if reason is None:
        from repro.kernels import ops as kernel_ops   # lazy: pulls in pallas
        if mask is not None:
            # same lead-dim-elided masks the composed dispatch accepts
            mask = jnp.broadcast_to(mask, x.shape[:2])
        o = kernel_ops.fused_roi_attention_prequant(
            x, params["wq"].wq, params["wq"].scale.reshape(-1),
            params["wk"].wq, params["wk"].scale.reshape(-1),
            params["wv"].wq, params["wv"].scale.reshape(-1),
            mask, heads=heads, kv_len=kv_len,
            bits=tuple(params[n].bits for n in ("wq", "wk", "wv")),
            interpret=p.interpret)
        return linear(o, params["wo"], policy=policy)
    if (p.resolve_attn_backend() == "flash"
            and p.resolve_backend() == "photonic_pallas"):
        # the policy asked for the fused serving combination — say why
        # it degraded to per-projection dispatch (one-time per cause)
        from repro.core.backend import warn_fused_fallback
        warn_fused_fallback("attention-prequant", p, reason)
    q = _heads_split(linear(x, params["wq"], policy=policy), heads)
    k = _heads_split(linear(x, params["wk"], policy=policy), heads)
    v = _heads_split(linear(x, params["wv"], policy=policy), heads)
    o = attend(q, k, v, policy, mask=mask, kv_len=kv_len)  # (..., h, n, dh)
    o = o.swapaxes(-2, -3).reshape(*x.shape[:-1], dm)
    return linear(o, params["wo"], policy=policy)


def mhsa_decomposed(x: jnp.ndarray, params: dict, heads: int,
                    policy: ExecPolicy | None = None,
                    mask: jnp.ndarray | None = None,
                    kv_len: int | None = None) -> jnp.ndarray:
    """Multi-head self-attention with Eq. 2 score dataflow (per head).

    Per head h: S_h = (X Wq_h) (Wk_h^T/sqrt(dh)) X^T. Mathematically equal to
    the standard path; only the association order differs. The Q/V/O
    projections and the per-head (Q_h @ Wk_h^T) weight matmul all route
    through the backend dispatch — W_K^T/sqrt(dh) is tuned as its own weight
    (the paper folds the scale into the MR bank directly), so it is passed
    raw and quantized at that fold point rather than reusing W_K's cache.
    The score core routes through ``attend`` with X itself as the
    (head-shared, MQA-style) key operand and the scale pre-folded — so the
    Eq. 2 dataflow runs on either attention backend, including the fused
    RoI-masked flash kernel (which supports D_qk != D_v).
    """
    dm = x.shape[-1]
    dh = dm // heads
    scale = 1.0 / jnp.sqrt(dh)
    wk = _as_array(params["wk"]).reshape(dm, heads, dh)
    q = _heads_split(linear(x, params["wq"], policy=policy), heads)
    # (Q_h @ (Wk_h^T * scale)) per head: (..., h, n, dm). On quantizing
    # backends each head's transposed-scaled W_K slice is a distinct tuned
    # weight, so it routes through ``linear`` head-by-head; on the plain
    # float path a single fused einsum is numerically identical and avoids
    # `heads` separate dots.
    if (policy or ExecPolicy()).resolve_backend() == "bf16":
        qwk = jnp.einsum("...hnk,dhk->...hnd", q, wk) * scale
    else:
        qwk = jnp.stack(
            [linear(q[..., h, :, :], wk[:, h, :].T * scale, policy=policy)
             for h in range(heads)], axis=-3)
    v = _heads_split(linear(x, params["wv"], policy=policy), heads)
    o = attend(qwk, x[..., None, :, :], v, policy, mask=mask, kv_len=kv_len,
               scale=1.0)
    o = o.swapaxes(-2, -3).reshape(*x.shape[:-1], dm)
    return linear(o, params["wo"], policy=policy)


def decomposition_flops(n: int, dm: int, dk: int) -> dict:
    """Analytic FLOP comparison of the two score dataflows (per head).

    standard:   K proj 2*n*dm*dk + scores 2*n^2*dk
    decomposed: QWk^T  2*n*dk*dm + scores 2*n^2*dm
    (Q projection and softmax(S)@V are common to both.)
    """
    std = 2 * n * dm * dk + 2 * n * n * dk
    dec = 2 * n * dk * dm + 2 * n * n * dm
    return {"standard": std, "decomposed": dec, "ratio": dec / std}
