"""Generate the EXPERIMENTS.md dry-run + roofline tables from artifacts.

    PYTHONPATH=src python experiments/make_report.py > /tmp/tables.md
"""

import glob
import json
import os
import sys

HW_PEAK, HW_HBM, HW_LINK = 197e12, 819e9, 50e9


def load(dirname, variant=None):
    out = {}
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(fn))
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        out[(r["mesh"], r["arch"], r["shape"], r.get("variant",
                                                     "baseline"))] = r
    return out


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.3g}us"
    if x < 1:
        return f"{x * 1e3:.3g}ms"
    return f"{x:.3g}s"


def gb(x):
    return f"{(x or 0) / 2**30:.1f}"


def dryrun_table(recs, mesh):
    lines = ["| arch | shape | status | mem/dev GiB | HLO flops/dev | "
             "HBM bytes/dev | wire bytes/dev | collectives | compile s |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (m, a, s, v), r in sorted(recs.items()):
        if m != mesh or v != "baseline":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | SKIP (sub-quadratic rule) | — | — "
                         "| — | — | — | — |")
            continue
        p = r["parsed"]
        co = ", ".join(f"{k.split('-')[-1][:6]}={v1/1e9:.0f}G"
                       for k, v1 in sorted(p["coll_by_op"].items())
                       if v1 > 1e8)
        lines.append(
            f"| {a} | {s} | ok | {gb(r['bytes_per_device'])} "
            f"| {p['flops']:.2e} | {p['bytes']:.2e} | {p['coll_bytes']:.2e} "
            f"| {co or '—'} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(recs, mesh):
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO flops | roofline frac | one-line fix |",
             "|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        "compute": "more chips / int8 MXU path (w8a8 mode)",
        "memory": "fused Pallas attention (VMEM scores) + bf16 dot outputs",
        "collective": "local MoE combine + bf16 ARs + fewer microbatches",
    }
    for (m, a, s, v), r in sorted(recs.items()):
        if m != mesh or v != "baseline" or r["status"] != "ok":
            continue
        t = r["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])}"
            f" | {fmt_s(t['collective_s'])} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.4f} "
            f"| {fixes[t['dominant']]} |")
    return "\n".join(lines)


def perf_table(perf_dir):
    recs = load(perf_dir)
    by_cell = {}
    for (m, a, s, v), r in sorted(recs.items()):
        by_cell.setdefault((a, s), []).append((v, r))
    out = []
    for (a, s), runs in by_cell.items():
        out.append(f"\n### {a} x {s}\n")
        out.append("| variant | compute | memory | collective | dominant | "
                   "bound (step floor) | mem/dev GiB | Δ bound vs prev |")
        out.append("|---|---|---|---|---|---|---|---|")
        prev = None
        for v, r in sorted(runs):
            if r["status"] != "ok":
                out.append(f"| {v} | ERROR: {r.get('error', '?')[:60]} | | "
                           "| | | | |")
                continue
            t = r["roofline"]
            bound = t["step_s_lower_bound"]
            delta = "" if prev is None else f"{(1 - bound / prev) * 100:+.1f}%"
            prev = bound
            out.append(
                f"| {v} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
                f"| {fmt_s(t['collective_s'])} | {t['dominant']} "
                f"| {fmt_s(bound)} | {gb(r['bytes_per_device'])} "
                f"| {delta} |")
    return "\n".join(out)


if __name__ == "__main__":
    base = os.path.dirname(__file__)
    recs = load(os.path.join(base, "dryrun"))
    print("## Dry-run table — single-pod (16,16) = 256 chips\n")
    print(dryrun_table(recs, "pod"))
    print("\n## Dry-run table — multi-pod (2,16,16) = 512 chips\n")
    print(dryrun_table(recs, "multipod"))
    print("\n## Roofline — single-pod baselines\n")
    print(roofline_table(recs, "pod"))
    if os.path.isdir(os.path.join(base, "perf")):
        print("\n## Perf iterations\n")
        print(perf_table(os.path.join(base, "perf")))
