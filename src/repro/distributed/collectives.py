"""Distributed-optimization utilities: compressed all-reduce, straggler
tolerance primitives.

``compressed_psum``: int8-quantized gradient all-reduce (quantize ->
psum int32 -> dequantize) under shard_map — 4x wire-bytes reduction vs f32
(2x vs bf16) at the cost of one extra max-allreduce for the shared scale.
Used by the ``grad_compression`` train-step variant and measured in the
roofline collective term (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["compressed_psum", "compressed_allreduce_tree"]


def compressed_psum(x: jnp.ndarray, axis_name: str, bits: int = 8):
    """int-quantized psum for use *inside* shard_map.

    scale = global absmax / qmax (one scalar psum-max), codes int8 are
    summed exactly in int32 (no saturation: sum of n devices' int8 fits
    int32 for n < 2^23), then dequantized.
    """
    qmax = 2 ** (bits - 1) - 1
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def compressed_allreduce_tree(partial_grads: Any, mesh: Mesh,
                              axis: str = "data", bits: int = 8) -> Any:
    """Compressed all-reduce-MEAN of per-device partial gradients.

    Each leaf has a leading device axis of size mesh.shape[axis] holding
    that device's partial gradient (manual-DP layout); returns the
    compressed mean, replicated. This is the explicit-DP path that makes
    gradient compression real (under GSPMD the grad psum is implicit and
    uncompressible from user code).
    """
    n = mesh.shape[axis]

    def per_leaf(g):
        assert g.shape[0] == n, (g.shape, n)

        def body(gl):                     # gl: (1, ...) local partial
            return compressed_psum(gl[0], axis, bits) / n

        return jax.shard_map(
            body, mesh=mesh,
            in_specs=P(axis, *([None] * (g.ndim - 1))),
            out_specs=P(*([None] * (g.ndim - 1))))(g)

    return jax.tree_util.tree_map(per_leaf, partial_grads)
