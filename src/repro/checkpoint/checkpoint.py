"""Sharding-aware checkpointing: save/restore, async, elastic re-mesh.

Layout: a checkpoint directory holds
    meta.json            - step, tree structure, shapes/dtypes, rules hash
    <leaf-path>.npy      - one file per pytree leaf (gathered host arrays)

Design points for the 1000+-node posture (DESIGN.md §4):
  * restore takes a ShardingCtx + logical-axes tree and device_puts each
    leaf with its target NamedSharding -> restoring onto a *different*
    mesh shape is the elastic re-mesh path (tests/test_checkpoint.py).
  * ``async_save`` snapshots to host memory synchronously (cheap) and
    writes to disk on a background thread — training continues.
  * saves are atomic (tmp dir + rename) and carry a content checksum so a
    torn write from a preemption is detected at restore.
  * on a real multi-host pod each host would write only its addressable
    shards; the single-process degenerate case writes full arrays.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx, named_sharding

__all__ = ["save", "restore", "async_save", "load_meta", "restore_flat",
           "latest_step", "CheckpointManager"]


def _flatten(tree, is_leaf=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree,
                                                         is_leaf=is_leaf)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _flatten_axes(axes):
    """Logical-axis trees have TUPLE leaves — stop flattening at tuples."""
    return _flatten(axes, is_leaf=lambda t: isinstance(t, tuple))


def _checksum(arrays: dict) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        a = arrays[k]
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(np.ascontiguousarray(a).tobytes()[:4096])  # prefix hash
    return h.hexdigest()


def save(path: str, tree: Any, step: int = 0, extra: dict | None = None):
    """Atomic synchronous save (unique tmp dir: concurrent saves of the
    same step cannot clobber each other's in-flight writes)."""
    tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    for k, v in host.items():
        fn = os.path.join(tmp, k.replace("/", "__") + ".npy")
        np.save(fn, v)
    meta = {"step": int(step),
            "keys": sorted(host.keys()),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "checksum": _checksum(host),
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    try:
        os.rename(tmp, path)
    except OSError:
        # lost the rename race to an identical concurrent save — fine
        shutil.rmtree(tmp, ignore_errors=True)


def _coerce_dtype(a: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load returns ml_dtypes arrays (bf16, fp8) as raw void dtypes —
    reinterpret with the dtype recorded in meta.json."""
    if str(a.dtype) == dtype_str:
        return a
    try:
        dt = np.dtype(dtype_str)
    except TypeError:
        import ml_dtypes
        dt = np.dtype(getattr(ml_dtypes, dtype_str))
    if a.dtype.itemsize == dt.itemsize:
        return a.view(dt) if a.ndim else a.reshape(1).view(dt).reshape(())
    return a.astype(dt)


def restore(path: str, like: Any, ctx: ShardingCtx | None = None,
            axes: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``. With (ctx, axes) the leaves
    are device_put with their logical shardings — pass a ctx built on a NEW
    mesh to re-shard elastically. Returns (tree, step)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat_like, treedef = _flatten(like)
    flat_axes, _ = _flatten_axes(axes) if axes is not None else ({}, None)
    host = {}
    for k in flat_like:
        fn = os.path.join(path, k.replace("/", "__") + ".npy")
        host[k] = _coerce_dtype(np.load(fn), meta["dtypes"].get(k, ""))
    if meta["checksum"] != _checksum(host):
        raise IOError(f"checkpoint {path} failed checksum (torn write?)")
    leaves = []
    for k, ref_leaf in flat_like.items():
        a = host[k].astype(ref_leaf.dtype if hasattr(ref_leaf, "dtype")
                           else host[k].dtype)
        if ctx is not None and k in flat_axes and flat_axes[k] is not None:
            sh = named_sharding(a.shape, flat_axes[k], ctx)
            leaves.append(jax.device_put(a, sh))
        else:
            leaves.append(jnp.asarray(a))
    # rebuild in treedef order
    keys_in_order = list(flat_like.keys())
    tree = jax.tree_util.tree_unflatten(
        treedef, [leaves[keys_in_order.index(k)] for k in flat_like])
    return tree, meta["step"]


def load_meta(path: str) -> dict:
    """The checkpoint's meta.json (step, keys, shapes/dtypes, extra) —
    enough to decide *what* a snapshot holds without loading any leaf."""
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


def restore_flat(path: str) -> tuple[dict, int, dict]:
    """Self-describing restore: rebuild the flat ``{key: np.ndarray}``
    dict straight from meta.json — no ``like`` template needed, which is
    what a serving checkpoint requires (its session set, deferred counts
    and queued-row shapes are only known to the snapshot itself).
    Checksum-verified like ``restore``; leaves stay host numpy. Returns
    ``(arrays, step, extra)``."""
    meta = load_meta(path)
    host = {}
    for k in meta["keys"]:
        fn = os.path.join(path, k.replace("/", "__") + ".npy")
        host[k] = _coerce_dtype(np.load(fn), meta["dtypes"].get(k, ""))
    if meta["checksum"] != _checksum(host):
        raise IOError(f"checkpoint {path} failed checksum (torn write?)")
    return host, meta["step"], meta.get("extra", {})


def async_save(path: str, tree: Any, step: int = 0,
               extra: dict | None = None) -> threading.Thread:
    """Snapshot to host memory now; write to disk in the background."""
    flat, treedef = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    snapshot = jax.tree_util.tree_unflatten(
        treedef, [host[k] for k in flat])
    t = threading.Thread(target=save, args=(path, snapshot, step, extra),
                         daemon=True)
    t.start()
    return t


def latest_step(root: str) -> int | None:
    """Highest step among ``<root>/step_<n>`` checkpoint dirs."""
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if (d.startswith("step_") and d[5:].isdigit()   # skip .tmp in-flight
                and os.path.exists(os.path.join(root, d, "meta.json"))):
            steps.append(int(d[5:]))
    return max(steps) if steps else None


class CheckpointManager:
    """Periodic + emergency checkpoints with retention."""

    def __init__(self, root: str, every: int = 100, keep: int = 3):
        self.root = root
        self.every = every
        self.keep = keep
        self._pending: list[threading.Thread] = []
        self._saved_steps: set[int] = set()
        os.makedirs(root, exist_ok=True)

    def maybe_save(self, step: int, tree: Any, extra: dict | None = None,
                   force: bool = False):
        if not force and (step == 0 or step % self.every):
            return
        if step in self._saved_steps:          # dedup force + periodic
            return
        self._saved_steps.add(step)
        path = os.path.join(self.root, f"step_{step}")
        self._pending.append(async_save(path, tree, step, extra))
        self._gc()

    def emergency_save(self, step: int, tree: Any):
        """Synchronous save for SIGTERM/preemption handlers."""
        save(os.path.join(self.root, f"step_{step}"), tree, step,
             {"emergency": True})

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _gc(self):
        all_steps = sorted(int(d[5:]) for d in os.listdir(self.root)
                           if d.startswith("step_") and d[5:].isdigit())
        for s in all_steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, ctx=None, axes=None):
        self.wait()
        s = latest_step(self.root)
        if s is None:
            return None, 0
        return restore(os.path.join(self.root, f"step_{s}"), like, ctx, axes)
