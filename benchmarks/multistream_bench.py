"""Multi-stream session server benchmark: fleet aggregate vs sequential.

The deployment question the server answers: given N cameras, is one
multiplexed ``StreamServer`` (shared prepared weights, one warm-started
per-bucket jit ladder, cross-stream scheduling) actually faster than the
status quo of N per-stream engine processes, each paying its own cold
start? Measurement, at the paper's controlled 50%-skip operating point
(``force_bucket=0.5``, the same point ``serving_bench`` gates):

  * **sequential**: N fresh single-session ``ServingEngine`` runs, one
    stream each — every run pays its own jit compiles, exactly what a
    process-per-stream deployment pays. Wall = sum of run walls.
  * **server**: one ``StreamServer``, N interleaved sessions. Wall =
    warm-start (charged — it is real startup cost) + the serve loop.

Gate: 4-stream aggregate fps >= 1.5x the sequential aggregate. The win is
structural — compiles paid once (after ``calibrate_trim`` shrinks the
warmed set to the buckets the operating point can hit) instead of N
times, and every encode launch stays jit-warm for whichever stream fills
it. Measured ~1.8x on this host class (``BENCH_serving.json``
``"multistream".speedup``); the margin scales with how compile-dominated
the cold runs are, so short streams gate most tightly.

    PYTHONPATH=src python -m benchmarks.multistream_bench           # gate
    PYTHONPATH=src python -m benchmarks.multistream_bench --smoke   # 2-stream
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs.opto_vit import get_config
from repro.data.pipeline import video_fleet
from repro.serving.engine import ServingEngine
from repro.serving.server import ServerConfig, StreamServer
from repro.serving.session import ServingConfig

STREAMS = 4
FRAMES = 48                       # per stream
SPEEDUP_GATE = 1.5
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _bench_cfgs(img_size: int):
    cfg = get_config("tiny", img_size=img_size, mgnet=True).with_(
        matmul_backend="bf16")
    sc = ServingConfig(microbatch=4, chunk=8, force_bucket=0.5)
    return cfg, sc


def run(smoke: bool = False) -> dict:
    n_streams = 2 if smoke else STREAMS
    frames = 16 if smoke else FRAMES
    img = 64 if smoke else 96
    print(f"\n== multi-stream session server: {n_streams} streams x "
          f"{frames} frames, tiny-{img}, 50% skip ==")

    cfg, sc = _bench_cfgs(img)
    fleet = video_fleet(n_streams, img_size=img, patch=16, cut_every=32)

    # -- sequential: N cold per-stream engines (process-per-stream model) --
    seq_results = []
    for i, st in enumerate(fleet):
        eng = ServingEngine(cfg, sc, n_classes=10)
        seq_results.append(eng.run(st, n_frames=frames, start=16 * i))
    seq_wall = sum(r.wall_s for r in seq_results)
    seq_frames = sum(r.frames for r in seq_results)
    seq_fps = seq_frames / seq_wall
    print(f"  sequential: {seq_frames} frames in {seq_wall:.2f}s "
          f"({n_streams} cold engines) -> {seq_fps:6.1f} frames/s")

    # -- server: one warm-started multiplexed StreamServer -----------------
    srv = StreamServer(cfg, ServerConfig.from_serving(sc, warm_start=False),
                       n_classes=10)
    sessions = [srv.add_session(st, n_frames=frames, start=16 * i)
                for i, st in enumerate(fleet)]
    # route-only calibration: at the pinned 50% operating point only one
    # bucket (plus the kept cap) can ever be hit — don't warm dead shapes
    trimmed = srv.calibrate_trim()
    srv.warm_start()
    print(f"  server ladder: trimmed {list(trimmed)} -> "
          f"{list(srv.ladder.sizes)} warmed in {srv.warm_s:.2f}s")
    results = srv.serve()
    serve_wall = results[sessions[0].sid].wall_s
    srv_wall = srv.warm_s + serve_wall
    srv_frames = sum(r.frames for r in results.values())
    srv_fps = srv_frames / srv_wall
    speedup = srv_fps / seq_fps
    print(f"  server:     {srv_frames} frames in {srv_wall:.2f}s "
          f"(warm {srv.warm_s:.2f}s + serve {serve_wall:.2f}s) -> "
          f"{srv_fps:6.1f} frames/s aggregate")
    print(f"  -> {speedup:.2f}x (gate {SPEEDUP_GATE}x; the jit ladder "
          f"compiles once instead of {n_streams}x)")

    # predictions stay per-stream identical under multiplexing (the parity
    # contract tests/test_multistream.py pins per backend combo)
    for i, s in enumerate(sessions):
        assert results[s.sid].predictions == seq_results[i].predictions, i

    payload = {
        "config": f"tiny-{img}", "streams": n_streams,
        "frames_per_stream": frames,
        "sequential_fps": seq_fps, "aggregate_fps": srv_fps,
        "speedup": speedup, "warm_s": srv.warm_s,
        "serve_wall_s": serve_wall,
        "launches": len(srv.flush_log),
    }
    if smoke:
        print("  (smoke mode: gate + BENCH json skipped)")
        return payload

    merged = {}
    if os.path.exists(OUT_JSON):           # merge: serving/attention/ffn
        with open(OUT_JSON) as f:          # benches share this file
            merged = json.load(f)
    merged["multistream"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON}")

    assert speedup >= SPEEDUP_GATE, (
        f"multiplexed {n_streams}-stream serving must beat {n_streams} "
        f"sequential cold runs by >= {SPEEDUP_GATE}x aggregate frames/s; "
        f"measured {speedup:.2f}x")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2-stream validity run: no gate, no BENCH json "
                         "(the fast-CI configuration)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
