"""Architecture + shape configuration system.

One ``ArchConfig`` dataclass describes every supported architecture family:
  dense   - decoder-only transformer (GQA, optional QKV bias)
  moe     - dense attention + top-k routed expert FFNs (optional shared)
  ssm     - Mamba-2 SSD (attention-free)
  hybrid  - RG-LRU recurrence + periodic local attention (RecurrentGemma)
  encdec  - encoder-decoder with cross-attention (Whisper; conv frontend stub)
  vlm     - decoder with periodic image cross-attention (Llama-3.2-Vision;
            vision tower stub supplies patch embeddings)
  vit     - vision transformer (the paper's own backbone, MGNet-aware)

``ShapeConfig`` describes one benchmark cell (seq_len x global_batch x kind).
Shape kinds: "train" lowers train_step; "prefill" lowers a forward pass;
"decode" lowers serve_step (one token against a KV/state cache of seq_len).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "smoke_variant"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | vit
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int

    # attention
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    attn_impl: str = "standard"          # standard | decomposed
    window: int = 0                      # local-attention window (hybrid)
    attn_every: int = 0                  # hybrid: attn layer every k-th layer
    attn_block_q: int = 512              # blockwise-attention tile sizes
    attn_block_kv: int = 1024
    causal_block_skip: bool = False      # skip fully-masked KV blocks (perf opt)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0          # e.g. Kimi-K2 keeps layer 0 dense
    moe_groups: int = 1                  # dispatch groups (= batch shards)

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 256

    # hybrid (RG-LRU)
    lru_width: int = 0                   # 0 -> d_model

    # enc-dec / vlm stubs
    enc_layers: int = 0
    enc_frames: int = 1500               # whisper frontend stub output length
    cross_every: int = 0                 # vlm: cross-attn every k-th layer
    n_img_tokens: int = 0
    d_frontend: int = 0                  # stub embedding dim (0 -> d_model)

    # vit / paper-specific
    img_size: int = 224
    patch: int = 16
    mgnet: bool = False
    mgnet_keep_ratio: float = 1.0
    mgnet_embed: int = 192        # paper: 192/3 classification, 384/6 det.
    mgnet_heads: int = 3

    # training & memory policy
    remat: bool = True
    scan_layers: bool = True
    microbatch_steps: int = 1            # gradient-accumulation steps
    use_fp32_master: bool = False        # 405B-scale keeps optimizer in bf16
    lr_warmup: int = 100                 # warmup steps (schedule knob)
    lr_total: int = 10000                # cosine-decay horizon

    # paper technique knobs
    quant_bits: int = 0                  # 0 = off; 8 = paper's QAT/photonic
    photonic: bool = False
    matmul_backend: str = ""             # "" = resolve from the flags above;
    #                                      explicit: bf16 | qat | photonic_sim
    #                                      | photonic_pallas (core/backend.py)
    pallas_interpret: bool = True        # run Pallas kernels in interpreter
    #                                      mode (CPU hosts); False on TPU
    attn_backend: str = ""               # attention-core dispatch: "" -> xla
    #                                      (materialized scores) | flash
    #                                      (fused RoI-masked Pallas kernel,
    #                                      core/backend.py ATTN_BACKENDS)
    ffn_backend: str = ""                # GELU-MLP dispatch: "" -> xla
    #                                      (composed two-linear) | fused
    #                                      (fused int8 photonic FFN kernel,
    #                                      core/backend.py FFN_BACKENDS)
    bit_plan: tuple = ()                 # per-layer bit widths (one per
    #                                      encoder block, core/bitalloc.py);
    #                                      () = uniform quant_bits. Feeds
    #                                      prepare_params(bit_plan=...) and
    #                                      ExecPolicy.bit_plan
    noise: object = None                 # calibrated device-noise operating
    #                                      point (core/noise.py NoiseSpec,
    #                                      a frozen/hashable dataclass) or
    #                                      None = clean. Feeds
    #                                      ExecPolicy.noise; typed loosely
    #                                      to keep configs import-light

    # perf-hillclimb knobs (EXPERIMENTS.md §Perf; all default to the
    # paper-faithful baseline behaviour)
    dot_out_native: bool = False   # dot outputs in operand dtype (bf16) —
    #                                halves TP-activation all-reduce + dot
    #                                output traffic (MXU still accumulates
    #                                f32 internally)
    attn_p_bf16: bool = False      # softmax probs + V in bf16 inside the
    #                                flash PV matmul (f32 running stats)
    attn_qk_bf16: bool = False     # QK score dot reads bf16 operands
    #                                (f32 accumulate) — halves Q/K traffic
    decode_attn_bf16: bool = False  # decode attention reads the KV cache
    #                                in bf16 (f32 accumulate/softmax) —
    #                                without it XLA materializes an f32
    #                                copy of the whole cache per layer
    grad_accum_dtype: str = "f32"  # microbatch grad accumulator ("bf16"
    #                                halves accumulator memory at 1T scale)
    moe_local_combine: bool = False  # reshard expert outputs to
    #                                group-local before the combine gather
    #                                (all-gather instead of GSPMD's masked
    #                                all-reduce fallback)
    moe_impl: str = "gspmd"        # "gspmd" | "shard_map" (explicit EP:
    #                                communication-free dispatch + partial
    #                                combine psum — the full §Perf fix)

    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:            # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        n = v * d                                     # token embedding
        if not self.tie_embeddings:
            n += v * d                                # lm head
        hd = self.head_dim

        def attn_params():
            return (d * self.n_heads * hd + 2 * d * self.kv_heads * hd
                    + self.n_heads * hd * d)

        def ffn_dense(dff):
            return 3 * d * dff                        # SwiGLU: w1, w3, w2

        if self.family == "ssm":
            di = self.d_inner
            per = (d * (2 * di + 2 * self.ssm_state * 1 + self.ssm_heads)  # in_proj(z,x,B,C,dt)
                   + di * d                            # out_proj
                   + self.conv_kernel * (di + 2 * self.ssm_state))
            n += L * per
        elif self.family == "hybrid":
            lru = self.lru_dim
            attn_layers = L // 3 if self.attn_every else 0
            rec_layers = L - attn_layers
            per_rec = d * lru * 2 + lru * d + 2 * lru + self.conv_kernel * lru
            n += rec_layers * per_rec + attn_layers * attn_params()
            n += L * ffn_dense(self.d_ff)
        elif self.family == "moe":
            dense_l = self.first_dense_layers
            moe_l = L - dense_l
            per_moe = (self.n_experts + self.shared_experts) * ffn_dense(self.d_ff) \
                + d * self.n_experts                  # router
            n += L * attn_params() + dense_l * ffn_dense(self.d_ff * self.n_experts
                                                         if False else self.d_ff)
            # dense layers in MoE models use a wide dense FFN comparable to
            # top_k * d_ff activated width
            n += moe_l * per_moe
        elif self.family == "encdec":
            n += self.enc_layers * (attn_params() + ffn_dense(self.d_ff))
            n += L * (2 * attn_params() + ffn_dense(self.d_ff))   # self + cross
        elif self.family == "vlm":
            cross_l = L // self.cross_every if self.cross_every else 0
            n += L * (attn_params() + ffn_dense(self.d_ff))
            n += cross_l * attn_params()
        else:  # dense / vit
            n += L * (attn_params() + ffn_dense(self.d_ff))
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses 6*N_active*D)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.kv_heads * hd + self.n_heads * hd * d
        ffn_active = (self.top_k + self.shared_experts) * 3 * d * self.d_ff
        moe_l = L - self.first_dense_layers
        n = 2 * self.vocab * d
        n += L * attn + self.first_dense_layers * 3 * d * self.d_ff
        n += moe_l * (ffn_active + d * self.n_experts)
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.family != "hybrid" else 3,
        d_model=64,
        n_heads=4,
        kv_heads=min(cfg.kv_heads, 2),
        d_ff=128,
        vocab=256,
        microbatch_steps=1,
        remat=False,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, shared_experts=min(cfg.shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1), d_ff=64)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(lru_width=64, window=16, attn_every=3)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, enc_frames=8, d_frontend=64)
    if cfg.family == "vlm":
        kw.update(cross_every=2, n_img_tokens=8, d_frontend=64)
    if cfg.family == "vit":
        kw.update(img_size=32, patch=8)
    return cfg.with_(**kw)
