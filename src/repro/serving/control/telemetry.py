"""Observed per-flush telemetry: a bounded ring buffer of wall timings.

Every timed encode flush lands here as one immutable ``FlushObs`` tagged
by bucket, batch fill and owning-stream count — the *measured* side the
controller's calibration fits against the cost model's *predicted* side.
The buffer is a fixed-size deque: a long-lived server never grows its
telemetry without bound, and the windowed view doubles as the controller's
recency horizon (stale observations from before a knob change age out on
their own).

Each observation also carries a monotonically increasing ``seq`` stamped
at record time, so the controller can tell observations recorded *after*
its last calibration from the ones the fit was trained on — the honest
held-out split behind ``Controller.median_rel_error``.

With a ``StragglerDetector`` attached (the server's ``watchdog`` knob),
the ring doubles as a flush watchdog: every recorded observation feeds
the detector's robust median+MAD estimate, and flushes that run
anomalously long (a stalling device, an injected stall fault) land in
``straggler_flags`` — graceful degradation's detection half.
"""

from __future__ import annotations

import statistics
from collections import deque
from dataclasses import dataclass

from repro.distributed.fault_tolerance import StragglerDetector

__all__ = ["FlushObs", "FlushTelemetry"]


@dataclass(frozen=True)
class FlushObs:
    """One timed encode flush."""

    bucket: int        # kept-patch count k
    n_real: int        # live rows in the flush (rest was zero padding)
    microbatch: int    # flush batch size (n_real <= microbatch)
    n_streams: int     # sessions whose frames rode in this launch
    wall_s: float      # host wall seconds, launch to blocked result
    round: int         # scheduling round the flush executed in
    seq: int           # global record order (calibration holdout split)

    @property
    def occupancy(self) -> float:
        return self.n_real / self.microbatch if self.microbatch else 0.0


class FlushTelemetry:
    """Ring buffer of ``FlushObs`` with per-bucket views."""

    def __init__(self, window: int = 256,
                 straggler: StragglerDetector | None = None):
        if window < 1:
            raise ValueError("telemetry window must be >= 1")
        self.window = window
        self._buf: deque = deque(maxlen=window)
        self._seq = 0
        self.total_recorded = 0
        self.straggler = straggler
        self.straggler_flags: list[FlushObs] = []

    def record(self, bucket: int, n_real: int, microbatch: int,
               n_streams: int, wall_s: float, rnd: int = 0) -> FlushObs:
        obs = FlushObs(int(bucket), int(n_real), int(microbatch),
                       int(n_streams), float(wall_s), int(rnd), self._seq)
        self._seq += 1
        self.total_recorded += 1
        self._buf.append(obs)
        if self.straggler is not None and self.straggler.record(obs.seq,
                                                                obs.wall_s):
            self.straggler_flags.append(obs)
        return obs

    # -- views -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    @property
    def seq(self) -> int:
        """Next sequence number (== observations recorded so far)."""
        return self._seq

    def by_bucket(self) -> dict[int, list]:
        out: dict[int, list] = {}
        for o in self._buf:
            out.setdefault(o.bucket, []).append(o)
        return out

    def latencies(self, bucket: int, min_seq: int = 0) -> list[float]:
        """Wall seconds of this bucket's flushes (record order), optionally
        only those recorded at or after ``min_seq``."""
        return [o.wall_s for o in self._buf
                if o.bucket == bucket and o.seq >= min_seq]

    def occupancy(self, bucket: int | None = None) -> float:
        """Mean batch fill (1.0 = every flush full), windowed; 0 when no
        matching observation exists."""
        occ = [o.occupancy for o in self._buf
               if bucket is None or o.bucket == bucket]
        return sum(occ) / len(occ) if occ else 0.0

    def mean_streams(self) -> float:
        ns = [o.n_streams for o in self._buf]
        return sum(ns) / len(ns) if ns else 0.0

    def median_latency(self, bucket: int, min_seq: int = 0) -> float | None:
        lat = self.latencies(bucket, min_seq)
        return statistics.median(lat) if lat else None

    def mean_latency(self, bucket: int, min_seq: int = 0) -> float | None:
        lat = self.latencies(bucket, min_seq)
        return sum(lat) / len(lat) if lat else None
