import os

if __name__ == "__main__":
    # Only when running AS the dry-run driver (python -m ...): jax locks
    # the host device count on first init, and this must land before the
    # jax import below. Guarded so merely importing this module (tests,
    # pytest collection) never leaks 512 fake devices into the process.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

In driver mode the XLA_FLAGS override above runs before any other import
(jax locks the host device count on first init); 512 placeholder CPU
devices let ``jax.make_mesh`` build the production meshes:

    single-pod : (16, 16)    ("data", "model")          256 chips
    multi-pod  : (2, 16, 16) ("pod", "data", "model")   512 chips

For every cell this driver:
  1. builds the jitted step (train_step / prefill / serve_step) with its
     in/out shardings (launch/steps.py),
  2. ``.lower()`` on ShapeDtypeStruct stand-ins (no allocation),
  3. ``.compile()`` — sharding mismatches / unsupported collectives fail
     here and are bugs in the system,
  4. records ``compiled.memory_analysis()`` + ``compiled.cost_analysis()``
     and the parsed per-device roofline Cost (roofline/hlo_analysis.py)
     into a JSON artifact under --out.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    python -m repro.launch.dryrun --all --mesh pod
    python -m repro.launch.dryrun --all --mesh multipod
    python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import all_lm_archs, get_config
from repro.distributed.sharding import use_sharding
from repro.launch.mesh import batch_shard_count, make_production_mesh
from repro.launch.steps import build_cell
from repro.models import api as model_api
from repro.roofline.hlo_analysis import analyze_module
from repro.roofline.report import make_row, render_table, roofline_terms


def cell_skip_reason(cfg, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and model_api.skips_long_context(cfg):
        return ("full-attention arch: 524k dense decode is quadratic; "
                "long_500k runs only for ssm/hybrid (DESIGN.md §5)")
    if shape.kind == "decode" and not model_api.supports_decode(cfg):
        return "no decode step for this family"
    return None


def prepare_cfg(cfg, shape: ShapeConfig, mesh):
    """Launch-time config resolution (mesh-dependent knobs)."""
    kw = {}
    if cfg.family == "moe":
        kw["moe_groups"] = batch_shard_count(mesh)
    if shape.kind != "train":
        kw["remat"] = False
    if shape.name == "long_500k" and cfg.family == "ssm":
        # decode path: chunk config irrelevant (single-token recurrence)
        pass
    return cfg.with_(**kw) if kw else cfg


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, hlo_dir: str | None = None,
             variant: str = "baseline",
             overrides: dict | None = None) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    shape = SHAPES[shape_name]
    cfg = get_config(arch_id)
    if overrides:
        cfg = cfg.with_(**overrides)
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant, "kind": shape.kind,
                 "overrides": overrides or {}}

    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cfg = prepare_cfg(cfg, shape, mesh)

    t0 = time.time()
    try:
        with mesh, use_sharding(mesh):
            jitted, arg_specs = build_cell(cfg, shape, mesh)
            lowered = jitted.lower(*arg_specs)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        _write(rec, out_dir)
        return rec

    mem = compiled.memory_analysis()
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        ca = {}
    hlo = compiled.as_text()
    cost = analyze_module(hlo)
    terms = roofline_terms(cost, cfg, shape, n_dev)

    mem_per_dev = None
    if mem is not None:
        mem_per_dev = (getattr(mem, "argument_size_in_bytes", 0)
                       + getattr(mem, "temp_size_in_bytes", 0)
                       + getattr(mem, "output_size_in_bytes", 0)
                       - getattr(mem, "alias_size_in_bytes", 0))

    rec.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory_analysis={
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes")} if mem else {},
        bytes_per_device=mem_per_dev,
        cost_analysis={k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))},
        parsed={"flops": cost.flops, "bytes": cost.bytes,
                "coll_bytes": cost.coll_bytes,
                "coll_by_op": cost.coll_by_op,
                "bytes_by_tag": cost.bytes_by_tag},
        roofline=terms,
        hlo_len=len(hlo),
    )
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        fn = os.path.join(
            hlo_dir,
            f"{mesh_name}__{arch_id}__{shape_name}{suffix}.hlo.txt")
        with open(fn, "w") as f:
            f.write(hlo)
        rec["hlo_path"] = fn
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str | None):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    var = rec.get("variant", "baseline")
    suffix = "" if var == "baseline" else f"__{var}"
    fn = os.path.join(
        out_dir, f"{rec['mesh']}__{rec['arch']}__{rec['shape']}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)


def summarize(rec: dict) -> str:
    if rec["status"] == "skipped":
        return f"SKIP  {rec['arch']:<22}{rec['shape']:<12}{rec['reason'][:60]}"
    if rec["status"] == "error":
        return f"FAIL  {rec['arch']:<22}{rec['shape']:<12}{rec['error'][:80]}"
    t = rec["roofline"]
    gb = (rec.get("bytes_per_device") or 0) / 2**30
    return (f"OK    {rec['arch']:<22}{rec['shape']:<12}"
            f"mem/dev={gb:7.2f}GiB  "
            f"c={t['compute_s']:.3g}s m={t['memory_s']:.3g}s "
            f"x={t['collective_s']:.3g}s dom={t['dominant']:<10}"
            f"compile={rec['compile_s']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (see --list)")
    ap.add_argument("--shape", choices=list(SHAPES), help="shape cell")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump compiled HLO text here")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="label for this run's artifacts (§Perf)")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ArchConfig override, e.g. --set attn_p_bf16=true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    if args.list:
        for a in all_lm_archs():
            print(a)
        return

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = all_lm_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    rows = []
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, out_dir=args.out, hlo_dir=args.hlo_dir,
                       variant=args.variant, overrides=overrides or None)
        print(summarize(rec), flush=True)
        if rec["status"] == "ok":
            from repro.roofline.hlo_analysis import Cost
            cost = Cost(rec["parsed"]["flops"], rec["parsed"]["bytes"],
                        rec["parsed"]["coll_bytes"],
                        rec["parsed"]["coll_by_op"])
            rows.append(make_row(a, s, rec["mesh"], cost, rec["roofline"],
                                 rec.get("bytes_per_device")))
    if rows:
        print()
        print(render_table(rows))


if __name__ == "__main__":
    main()
