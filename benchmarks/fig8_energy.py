"""Paper Fig. 8: energy breakdown per ViT variant x image size.

Reproduces: (i) energy decreases with smaller networks / images, (ii) the
Tiny-96x96 pie is ADC-dominated."""

from __future__ import annotations

from benchmarks.common import IMG_SIZES, VARIANTS, fmt_uj, frame_report


def run() -> list[dict]:
    rows = []
    print("\n== Fig. 8: energy breakdown (uJ/frame) ==")
    for v in VARIANTS:
        for img in IMG_SIZES:
            rep = frame_report(v, img)
            rows.append({"variant": v, "img": img, "total_uj": rep.total_uj,
                         "breakdown": rep.breakdown()})
            print(f"{v:>6}-{img:<4} total={rep.total_uj:9.2f}uJ  "
                  f"{fmt_uj(rep)}")
    tiny = rows[0]
    pie = tiny["breakdown"]
    dom = max(pie, key=pie.get)
    print(f"Tiny-96 pie: {({k: round(x, 3) for k, x in pie.items()})}")
    print(f"dominant component: {dom} "
          f"({'MATCHES' if dom == 'adc_uj' else 'DIFFERS FROM'} paper's "
          f"ADC-dominant finding)")
    # monotonicity checks (paper's 'clear trend of energy reduction')
    totals = {(r["variant"], r["img"]): r["total_uj"] for r in rows}
    assert totals[("tiny", 96)] < totals[("small", 96)] < \
        totals[("base", 96)] < totals[("large", 96)]
    assert all(totals[(v, 96)] < totals[(v, 224)] for v in VARIANTS)
    return rows
