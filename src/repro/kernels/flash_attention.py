"""Pallas TPU kernel: fused flash attention (GQA, causal/local).

Streaming-softmax attention with VMEM-resident running (max, sum, acc)
state — the (Sq, Skv) score matrix never reaches HBM. Grid layout:

    grid = (B * H, Sq/bq, Skv/bkv)

The innermost (KV) grid dimension accumulates into VMEM scratch; on the
last KV step the normalized block output is written. GQA is expressed in
the BlockSpec index maps: query row ``i`` reads KV row ``i // group`` —
no KV repetition materializes.

Causal + local-window masking is applied per element; fully-masked KV
blocks are skipped with ``pl.when`` (the kernel-level analogue of the
causal_block_skip hillclimb in the XLA path).

Validated in interpret mode against kernels/ref.py::flash_attention_ref
over shape/dtype sweeps (tests/test_kernels_flash.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                           *, scale: float, causal: bool, window: int,
                           bq: int, bkv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    kv_lo = ki * bkv

    # live = this KV block intersects the visible region of this Q block
    live = True
    if causal:
        live = kv_lo <= q_lo + bq - 1
    if window > 0:
        live = jnp.logical_and(live, kv_lo + bkv - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= q_pos >= kv_pos
        if window > 0:
            mask &= q_pos - kv_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B, H, Sq, D); k/v (B, Hkv, Skv, D) -> (B, H, Sq, D).

    H must be a multiple of Hkv (GQA group = H // Hkv); Sq % bq == 0,
    Skv % bkv == 0. D should be a multiple of 128 on real TPUs (lane
    alignment); interpret mode accepts any D.
    """
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0 and sq % bq == 0 and skv % bkv == 0, \
        (q.shape, k.shape, bq, bkv)
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(b * h, sq, dh)
    kf = k.reshape(b * hkv, skv, dh)
    vf = v.reshape(b * hkv, skv, dh)

    grid = (b * h, sq // bq, skv // bkv)
    kern = functools.partial(flash_attention_kernel, scale=scale,
                             causal=causal, window=window, bq=bq, bkv=bkv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bkv, dh), lambda i, qi, ki, g=g: (i // g, ki, 0)),
            pl.BlockSpec((1, bkv, dh), lambda i, qi, ki, g=g: (i // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, dh)
