"""Cross-layer energy/latency model tests (paper Figs. 8-11, Table IV)."""

import pytest

from repro.core.energy import (EnergyConstants, accumulate_matmuls,
                               energy_of_stats, kfps_per_watt,
                               latency_of_stats)
from repro.core.photonic import OpticalCoreConfig, matmul_stats
from repro.core.schedule import attention_schedule, simulate_pipeline, CoreTask


def test_headline_kfps_per_watt():
    """Calibration anchor: Tiny-96x96 -> ~100.4 KFPS/W (paper Table IV)."""
    from repro.configs.opto_vit import get_config
    from repro.models.vit import vit_matmul_shapes
    cfg = get_config("tiny", img_size=96)
    stats, tiles = accumulate_matmuls(vit_matmul_shapes(cfg))
    n = (96 // 16) ** 2 + 1
    nonlin = cfg.n_layers * (cfg.n_heads * n * n + n * cfg.d_ff)
    rep = energy_of_stats(stats, nonlin)
    kfps = kfps_per_watt(rep)
    assert abs(kfps - 100.4) / 100.4 < 0.05, kfps


def test_adc_dominant_pie():
    """Calibration anchor: ADC is the largest Tiny-96 energy component."""
    from repro.configs.opto_vit import get_config
    from repro.models.vit import vit_matmul_shapes
    cfg = get_config("tiny", img_size=96)
    stats, _ = accumulate_matmuls(vit_matmul_shapes(cfg))
    n = (96 // 16) ** 2 + 1
    nonlin = cfg.n_layers * (cfg.n_heads * n * n + n * cfg.d_ff)
    pie = energy_of_stats(stats, nonlin).breakdown()
    assert max(pie, key=pie.get) == "adc_uj", pie


def test_energy_scales_with_workload():
    s1 = matmul_stats(64, 256, 256, OpticalCoreConfig())
    s2 = matmul_stats(128, 256, 256, OpticalCoreConfig())
    e1 = energy_of_stats(s1).total_uj
    e2 = energy_of_stats(s2).total_uj
    assert e1 < e2 < 2 * e1       # tuning part is M-independent


def test_pipelined_tuning_hides_latency():
    s = matmul_stats(64, 1024, 1024, OpticalCoreConfig())
    tiles = (1024 // 32) * (1024 // 64)
    pipe = latency_of_stats(s, n_tiles=tiles, pipelined_tuning=True)
    serial = latency_of_stats(s, n_tiles=tiles, pipelined_tuning=False)
    assert serial.optical_us > pipe.optical_us


def test_fig5_decomposition_beats_naive():
    naive, _ = attention_schedule(1.0, 2.0, 0.3, decomposed=False)
    dec, _ = attention_schedule(1.0, 2.0, 0.3, decomposed=True)
    assert dec < naive
    # the win is exactly the serialized K->tune(K^T) bubble when tuning
    # dominates
    naive_big, _ = attention_schedule(0.5, 10.0, 0.1, decomposed=False)
    dec_big, _ = attention_schedule(0.5, 10.0, 0.1, decomposed=True)
    assert (naive_big - dec_big) > (naive - dec)


def test_pipeline_simulator_deadlock_detection():
    tasks = [CoreTask("a", 0, 1.0, 0.1, deps=("ghost",))]
    with pytest.raises(ValueError, match="deadlock"):
        simulate_pipeline(tasks)
