"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — critical because the dry-run
overrides the host device count via XLA_FLAGS *before* first jax init,
while tests/benches must keep seeing the single real CPU device.

Meshes (pinned by the assignment):
  single-pod : (16, 16)            axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)         axes ("pod", "data", "model") = 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_serving_mesh",
           "batch_shard_count"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the real host devices (examples / integration tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model: int = 1):
    """Serving mesh over every visible device, or None on a single device.

    ``model == 1`` (default): 1-D ("data",) mesh — the encode batch axis
    data-parallelizes (distributed.sharding.DATA_RULES), params replicate,
    each device encodes a slice of the micro-batch. None keeps the
    single-device path annotation-free (ShardingCtx is never installed).

    ``model > 1``: 2-D ("data", "model") mesh of shape (n // model,
    model) — attention heads and the FFN hidden dim shard over "model"
    (distributed.sharding.MODEL_RULES) so big ViT variants serve at all,
    batch still splits over "data". Raises when the device count cannot
    host the requested model axis (silent clamping would change which
    kernels run)."""
    n = len(jax.devices())
    if model > 1:
        if model > n:
            raise ValueError(f"model={model} shards need at least {model} "
                             f"devices, have {n}")
        if n % model != 0:
            raise ValueError(f"device count {n} is not divisible by "
                             f"model={model}")
        return jax.make_mesh((n // model, model), ("data", "model"))
    if n < 2:
        return None
    return jax.make_mesh((n,), ("data",))


def batch_shard_count(mesh) -> int:
    """Device count along the batch (DP) axes = pod x data."""
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
