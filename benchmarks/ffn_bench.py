"""Fused int8 FFN benchmark (the serving hot path's GELU-MLP core).

The composed path runs the encoder MLP as two independent
``photonic_matmul_prequant`` dispatches with a float GELU round-trip
between them; on the CPU host those matmuls execute through the Pallas
*interpreter* — a correctness emulator, not a perf path — and the
``(M, d_ff)`` hidden tensor crosses the dispatch boundary at float
precision twice. The fused FFN backend (kernels/fused_ffn.py) lowers the
same int8 contract as one XLA computation (integer dots + in-graph
requantization, the Pallas-epilogue dequant pinning the reference's
rounding) and — the serving lever this bench gates — takes the packed
``live_rows`` skip from ``--one-shape`` mode: fully-pruned token rows are
statically sliced out of both matmuls, the GELU and the absmax
reductions, the row-space analogue of the flash kernel skipping pruned KV
blocks.

Both paths are the *registered* FFN backends, timed exactly as
``core.backend.ffn`` dispatches them on this host — "xla" (composed, all
rows: the post-hoc reference never skips) vs "fused" with the static
packed kept-count at 50% skip (the one-shape serving operating point,
matching attention_bench's gate scenario).

Gates (tiny-224, 50% skip, batch = one serving micro-batch):
  1. fused packed >= 1.3x the *fused full-row* path — the pure FLOP-skip
     win, backend-implementation-neutral (measured ~2-3x);
  2. fused packed >= 1.3x the composed dispatch — the end-to-end serving
     claim for the registered hot path (measured far higher on this host,
     where composed pays the interpreter; on a real TPU both sides run
     Pallas kernels and the margin is the skip + fusion win).

Numerics first, wall second: the fused full-row output must be
bit-identical to the composed dispatch, and the packed output
bit-identical to the composed dispatch on the live slice.

Results merge into BENCH_serving.json under "ffn", next to the attention
and serving numbers they share a hot path with.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import interleaved_best as _interleaved_best
from repro.configs.opto_vit import get_config
from repro.core.backend import ExecPolicy, ffn, prepare_params
from repro.kernels.fused_ffn import fused_ffn_int8
from repro.models.ffn import init_mlp

BATCH = 16                      # serving_bench's tiny-224 micro-batch
SKIP = 0.5
SPEEDUP_GATE = 1.3
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


_COMPOSED = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                       training=False)                  # ffn_backend -> xla
_FUSED = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                    training=False, ffn_backend="fused")


def run() -> dict:
    print("\n== fused int8 FFN vs composed two-linear photonic dispatch ==")
    cfg = get_config("tiny", img_size=224)
    n_tokens = (cfg.img_size // cfg.patch) ** 2 + 1          # 197 incl [cls]
    kept = int(round((1.0 - SKIP) * n_tokens))
    d, dff = cfg.d_model, cfg.d_ff

    params = prepare_params(
        init_mlp(jax.random.PRNGKey(0), d, dff, jnp.float32), bits=8)
    w1, b1 = params["w1"], params["b1"]
    w2, b2 = params["w2"], params["b2"]
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, n_tokens, d))

    def _dispatch(policy, live):
        return jax.jit(lambda x: ffn(x, w1, b1, w2, b2, policy,
                                     live_rows=live))

    composed = _dispatch(_COMPOSED, None)
    fused_full = _dispatch(_FUSED, None)
    fused_packed = _dispatch(_FUSED, kept)

    # numerics first: the parity contract this module's wall claims stand on
    ref = composed(x)
    np.testing.assert_array_equal(
        np.asarray(fused_full(x)), np.asarray(ref),
        err_msg="fused full-row FFN must be bit-identical to the composed "
                "two-linear dispatch")
    ref_live = jax.jit(lambda x: ffn(x, w1, b1, w2, b2, _COMPOSED))(
        x[:, :kept])
    packed = np.asarray(fused_packed(x))
    np.testing.assert_array_equal(
        packed[:, :kept], np.asarray(ref_live),
        err_msg="fused packed FFN must match the composed dispatch on the "
                "live slice bit-for-bit")
    assert (packed[:, kept:] == 0).all(), "dead rows must return exact 0"

    t_comp, t_full, t_packed = _interleaved_best([
        (composed, (x,)),
        (fused_full, (x,)),
        (fused_packed, (x,)),
    ])
    skip_speedup = t_full / t_packed
    total_speedup = t_comp / t_packed
    print(f"  tiny-224, {SKIP:.0%} skip, batch {BATCH}: "
          f"composed {t_comp * 1e3:7.2f} ms | fused full "
          f"{t_full * 1e3:7.2f} ms | fused packed {t_packed * 1e3:7.2f} ms")
    print(f"  packed-skip win (fused full -> packed): {skip_speedup:.2f}x; "
          f"vs composed dispatch: {total_speedup:.2f}x "
          f"(composed pays the interpret emulator on this host)")

    # the TPU kernel through the interpret emulator — correctness-only;
    # held to the one-quant-step kernel tolerance (its body may FMA the
    # dequant+bias chain — kernels/fused_ffn.py "Parity contract")
    kern = jax.jit(lambda x: fused_ffn_int8(
        x, w1.wq, w1.scale.reshape(-1), b1, w2.wq, w2.scale.reshape(-1), b2,
        live_rows=kept, interpret=True))
    np.testing.assert_allclose(np.asarray(kern(x)), packed,
                               rtol=1e-2, atol=1e-2,
                               err_msg="Pallas fused-FFN kernel drifted "
                                       "off the XLA twin")
    (t_kern,) = _interleaved_best([(kern, (x,))])
    print(f"  pallas kernel (interpret emulator, not a perf path): "
          f"{t_kern * 1e3:7.2f} ms")

    payload = {
        "config": "tiny-224", "batch": BATCH, "skip": SKIP,
        "n_tokens": n_tokens, "kept": kept, "d": d, "d_ff": dff,
        "composed_ms": t_comp * 1e3,
        "fused_full_ms": t_full * 1e3,
        "fused_packed_ms": t_packed * 1e3,
        "pallas_interpret_ms": t_kern * 1e3,
        "skip_speedup": skip_speedup,
        "speedup": total_speedup,
    }
    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["ffn"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON} [ffn]")

    assert skip_speedup >= SPEEDUP_GATE, (
        f"fused FFN packed-skip must beat its own full-row path by "
        f">= {SPEEDUP_GATE}x at {SKIP:.0%} skip; measured {skip_speedup:.2f}x")
    assert total_speedup >= SPEEDUP_GATE, (
        f"fused FFN must beat the composed two-linear dispatch by "
        f">= {SPEEDUP_GATE}x at {SKIP:.0%} skip; measured {total_speedup:.2f}x")
    return payload


if __name__ == "__main__":
    run()
