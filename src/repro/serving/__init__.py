"""Streaming video serving engine (ingest -> RoI gate -> bucket -> encode
-> account). See ``repro.serving.engine`` for the pipeline and CLI."""

from repro.serving.accounting import StreamAccounting
from repro.serving.buckets import BucketHistogram, BucketLadder
from repro.serving.engine import (ServingConfig, ServingEngine, StreamResult,
                                  main)
from repro.serving.mask_cache import TemporalMaskCache
from repro.serving.scheduler import FrameBatch, MicroBatcher

__all__ = ["ServingEngine", "ServingConfig", "StreamResult", "BucketLadder",
           "BucketHistogram", "TemporalMaskCache", "MicroBatcher",
           "FrameBatch", "StreamAccounting", "main"]
