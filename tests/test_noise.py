"""MR device model tests (paper §IV "MR Resolution Analysis")."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import (MRConfig, crosstalk_matrix, noise_power,
                              required_q_factor, resolution_bits,
                              transmission_error, wavelength_grid)


def test_grid_centered():
    cfg = MRConfig()
    lam = wavelength_grid(cfg)
    assert lam.shape == (32,)
    np.testing.assert_allclose(float(lam.mean()), cfg.center_nm, atol=1e-3)


def test_crosstalk_matrix_properties():
    phi = crosstalk_matrix(MRConfig())
    p = np.asarray(phi)
    assert p.shape == (32, 32)
    assert np.all(np.diag(p) == 0)           # own channel is not noise
    assert np.all(p >= 0) and np.all(p < 1)
    # nearest neighbours dominate
    assert p[0, 1] > p[0, 2] > p[0, 3]


def test_noise_power_worst_case_at_full_power():
    cfg = MRConfig()
    pn_full = noise_power(cfg)
    pn_half = noise_power(cfg, jnp.full((32,), 0.5))
    assert float(pn_half.max()) < float(pn_full.max())


def test_resolution_monotone_in_q():
    bits = [resolution_bits(MRConfig(q_factor=q))
            for q in (1000, 3000, 5000, 10000)]
    assert bits == sorted(bits)


def test_paper_claim_8bit_needs_q5000():
    """Paper: 'achieving at least 8-bit resolution requires MRs with a
    Q-factor of about 5000' — the calibrated grid reproduces this."""
    assert resolution_bits(MRConfig(q_factor=5000.0)) >= 8.0
    assert resolution_bits(MRConfig(q_factor=2000.0)) < 8.0
    q_min = required_q_factor(8.0)
    assert 3000 < q_min < 5100, q_min


def test_transmission_error_mean_one():
    key = jax.random.PRNGKey(0)
    m = transmission_error(key, (4096,), MRConfig())
    assert abs(float(m.mean()) - 1.0) < 1e-2
    # bounded by the crosstalk floor
    floor = 2.0 ** (-resolution_bits(MRConfig()))
    assert float(jnp.abs(m - 1.0).max()) <= floor + 1e-6


def test_transmission_error_fpv_widens():
    key = jax.random.PRNGKey(0)
    base = transmission_error(key, (4096,), MRConfig())
    fpv = transmission_error(key, (4096,), MRConfig(), fpv_sigma=0.05)
    assert float(jnp.std(fpv)) > float(jnp.std(base))
