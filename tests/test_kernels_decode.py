"""Pallas flash-decode kernel vs the XLA decode_attention oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.models.attention import decode_attention, full_attention

pytestmark = pytest.mark.slow      # interpret-mode kernels -> CI slow job


def _setup(seed, b, s, h, hkv, d, cache_dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, 1, h, d))
    kc = jax.random.normal(k2, (b, s, hkv, d), cache_dtype)
    vc = jax.random.normal(k3, (b, s, hkv, d), cache_dtype)
    return q, kc, vc


@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (8, 1)])
def test_matches_decode_attention(h, hkv):
    q, kc, vc = _setup(0, 2, 128, h, hkv, 32)
    for length in (1, 63, 128):
        out = flash_decode(q, kc, vc, length, bs=32)
        ref = decode_attention(q, kc, vc, length)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    q, kc, vc = _setup(1, 1, 256, 4, 2, 16)
    ref = flash_decode(q, kc, vc, 200, bs=256)
    for bs in (32, 64, 128):
        out = flash_decode(q, kc, vc, 200, bs=bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_bf16_cache():
    q, kc, vc = _setup(2, 2, 64, 4, 2, 32, cache_dtype=jnp.bfloat16)
    out = flash_decode(q, kc, vc, 50, bs=32)
    ref = decode_attention(q, kc, vc, 50)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_equals_full_attention_row():
    """flash_decode(q_t, cache filled to t) == row t of causal attention."""
    b, s, h, hkv, d = 1, 64, 4, 2, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q_all = jax.random.normal(k1, (b, s, h, d))
    k_all = jax.random.normal(k2, (b, s, hkv, d))
    v_all = jax.random.normal(k3, (b, s, hkv, d))
    full = full_attention(q_all, k_all, v_all, causal=True)
    t = 41
    out = flash_decode(q_all[:, t:t + 1], k_all, v_all, t + 1, bs=16)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, t]),
                               rtol=2e-5, atol=2e-5)


def test_traced_length():
    """length may be a traced scalar (decode loops carry it)."""
    q, kc, vc = _setup(4, 1, 64, 2, 2, 16)

    @jax.jit
    def f(length):
        return flash_decode(q, kc, vc, length, bs=32)

    out = f(jnp.int32(40))
    ref = decode_attention(q, kc, vc, 40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
