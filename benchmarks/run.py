"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig8 table4

Each module's ``run()`` prints its table and ASSERTS the paper's
qualitative claims (orderings, dominances, calibrated headline) so the
harness doubles as a reproduction gate."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks import (attention_bench, bench_backend_cache,
                        controller_bench, fault_bench, ffn_bench,
                        fig8_energy, fig9_latency, fig10_11_mgnet,
                        fleet_bench, mixed_precision_bench,
                        multistream_bench, robustness_bench, roofline_table,
                        serving_bench, table1_qat, table4_kfps)

ALL = {
    "fig8": fig8_energy.run,
    "fig9": fig9_latency.run,
    "fig10_11": fig10_11_mgnet.run,
    "table1": table1_qat.run,
    "table4": table4_kfps.run,
    "roofline": roofline_table.run,
    "cache": bench_backend_cache.run,
    "serving": serving_bench.run,
    "attention": attention_bench.run,
    # the fused-FFN gate merges into BENCH_serving.json under "ffn" (same
    # pattern as attention_bench) so the perf trajectory stays in one file
    "ffn": ffn_bench.run,
    # multi-stream session server vs sequential cold engines ("multistream"
    # key in BENCH_serving.json)
    "multistream": multistream_bench.run,
    # per-layer bit plans on the fused path: speedup / energy / agreement
    # gates ("mixed_precision" key in BENCH_serving.json)
    "mixed_precision": mixed_precision_bench.run,
    # serving control plane: calibration medrelerr + autotune fps gates
    # ("controller" key in BENCH_serving.json)
    "controller": controller_bench.run,
    # clean-vs-noisy agreement, accuracy-under-drift, drift-triggered
    # recalibration ("robustness" key in BENCH_serving.json)
    "robustness": robustness_bench.run,
    # chaos gates: transient-fault bitwise transparency + fps floor,
    # per-session quarantine isolation, crash-and-restore exactness
    # ("faults" key in BENCH_serving.json)
    "faults": fault_bench.run,
    # fleet front-end: 1 -> W worker scaling, cost-vs-rr placement, and
    # model-sharded fused-encode bitwise parity on a forced 4-device host
    # ("fleet" key in BENCH_serving.json)
    "fleet": fleet_bench.run,
}

HISTORY = os.environ.get("BENCH_HISTORY_JSONL", "BENCH_history.jsonl")
HISTORY_KEEP = 200


def _git_sha() -> str | None:
    """Short HEAD SHA, or None outside a repo / without git."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _append_history(names, failed, dt: float) -> None:
    """One JSONL row per harness run: when, which commit, what ran, what
    failed, and the merged BENCH_serving.json snapshot — the perf
    trajectory over PRs. The file is rotated to the newest HISTORY_KEEP
    rows so a long-lived checkout's log stays bounded."""
    snapshot = None
    if os.path.exists(mixed_precision_bench.OUT_JSON):
        try:
            with open(mixed_precision_bench.OUT_JSON) as f:
                snapshot = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    row = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
           "sha": _git_sha(),
           "names": list(names), "failed": [n for n, _ in failed],
           "elapsed_s": round(dt, 1), "serving": snapshot}
    rows = []
    if os.path.exists(HISTORY):
        with open(HISTORY) as f:
            rows = [ln for ln in f.read().splitlines() if ln.strip()]
    rows.append(json.dumps(row))
    with open(HISTORY, "w") as f:
        f.write("\n".join(rows[-HISTORY_KEEP:]) + "\n")


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    t0 = time.time()
    failed = []
    for n in names:
        try:
            ALL[n]()
        except AssertionError as e:
            failed.append((n, str(e)))
            print(f"!! {n} reproduction assertion failed: {e}")
    dt = time.time() - t0
    _append_history(names, failed, dt)
    print(f"\n== benchmarks done in {dt:.1f}s: "
          f"{len(names) - len(failed)}/{len(names)} reproduction gates pass")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
