"""Serving subsystem tests: bucket ladder, temporal mask cache, micro-batch
scheduler, stream accounting, VideoStream determinism, and the engine end to
end (incl. the Pallas serving path in interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.energy import EnergyReport, aggregate_reports
from repro.data.pipeline import VideoStream, prefetch_to_device
from repro.serving.accounting import StreamAccounting
from repro.serving.buckets import BucketHistogram, BucketLadder
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.mask_cache import TemporalMaskCache
from repro.serving.scheduler import MicroBatcher


# --------------------------------------------------------------------------
# bucket ladder
# --------------------------------------------------------------------------

def test_ladder_from_fractions():
    lad = BucketLadder.from_fractions(36, (0.25, 0.5, 0.75, 1.0))
    assert lad.sizes == (9, 18, 27, 36)
    assert lad.cap == 36


def test_ladder_routes_to_smallest_covering_bucket():
    lad = BucketLadder((9, 18, 27, 36))
    assert lad.route(0) == 9
    assert lad.route(9) == 9
    assert lad.route(10) == 18
    assert lad.route(28) == 36
    assert lad.route(99) == 36          # over-budget clips to the cap
    np.testing.assert_array_equal(
        lad.route_many([0, 9, 10, 28, 99]), [9, 9, 18, 36, 36])


def test_ladder_rejects_bad_shapes():
    with pytest.raises(ValueError):
        BucketLadder(())
    with pytest.raises(ValueError):
        BucketLadder((9, 9, 18))
    with pytest.raises(ValueError):
        BucketLadder((18, 9))


def test_ladder_budget_edges():
    """Budget 0 (MGNet found nothing — still encode the smallest bucket,
    the [cls] path needs tokens) and budget == N (dense fallback, no
    over-routing past the cap)."""
    n = 36
    lad = BucketLadder.from_fractions(n, (0.25, 0.5, 0.75, 1.0))
    assert lad.route(0) == lad.sizes[0]
    assert lad.route(n) == n == lad.cap
    np.testing.assert_array_equal(lad.route_many([0, n]), [lad.sizes[0], n])
    # a single-bucket ladder sends every budget to that bucket
    one = BucketLadder((n,))
    assert one.route(0) == one.route(n) == one.route(n + 99) == n
    # fractions below 1/N clamp to one patch, never zero
    tiny = BucketLadder.from_fractions(n, (0.001, 1.0))
    assert tiny.sizes[0] == 1


def test_histogram_counts():
    lad = BucketLadder((4, 8))
    h = BucketHistogram(lad)
    h.add(4)
    h.add(8, 3)
    assert h.as_dict() == {4: 1, 8: 3}
    assert h.total == 4


# --------------------------------------------------------------------------
# temporal mask cache
# --------------------------------------------------------------------------

def _static_frames(n, h=8, val=0.0):
    return np.full((n, h, h, 3), val, np.float32)


def test_mask_cache_reuses_on_static_scene():
    cache = TemporalMaskCache(refresh=100, delta_threshold=0.5)
    calls = []

    def score_fn(f):
        calls.append(f.shape[0])
        return np.zeros((f.shape[0], 4), np.float32)

    scores, n = cache.gate(_static_frames(6), np.arange(6), score_fn)
    assert scores.shape == (6, 4)
    assert n == 1                        # only the very first frame scored
    assert cache.reused_frames == 5
    # identical follow-up chunk: full reuse, no scoring call at all
    _, n2 = cache.gate(_static_frames(6), np.arange(6, 12), score_fn)
    assert n2 == 0
    assert cache.reuse_rate == pytest.approx(11 / 12)


def test_mask_cache_refresh_period_bounds_staleness():
    cache = TemporalMaskCache(refresh=4, delta_threshold=1e9)
    scored = []

    def score_fn(f):
        scored.append(f.shape[0])
        return np.zeros((f.shape[0], 4), np.float32)

    _, n = cache.gate(_static_frames(8), np.arange(8), score_fn)
    assert n == 2                        # frames 0 and 4 (every 4th)


def test_mask_cache_delta_trigger_fires_on_scene_change():
    cache = TemporalMaskCache(refresh=1000, delta_threshold=0.3)

    def score_fn(f):
        # score = per-frame mean brightness, so the output tells us which
        # frame each returned score row came from
        per_frame = f.mean(axis=(1, 2, 3)).astype(np.float32)
        return np.repeat(per_frame[:, None], 4, axis=1)

    frames = _static_frames(6)
    frames[3:] = 1.0                     # scene cut at frame 3
    scores, n = cache.gate(frames, np.arange(6), score_fn)
    assert n == 2                        # frame 0 + the cut frame
    assert scores[2].mean() == pytest.approx(0.0)
    assert scores[3].mean() == pytest.approx(1.0)
    assert scores[5].mean() == pytest.approx(1.0)   # reused post-cut mask


def test_mask_cache_refresh_boundary_is_inclusive():
    """idx - ref_idx == refresh must re-score (staleness bound is >=, not
    >): with refresh=4 a frame exactly 4 after the reference is scored."""
    def score_fn(f):
        return np.zeros((f.shape[0], 4), np.float32)

    # frames 0..4 in one chunk: 0 scores (cold), 4 is exactly refresh away
    cache = TemporalMaskCache(refresh=4, delta_threshold=1e9)
    _, n = cache.gate(_static_frames(5), np.arange(5), score_fn)
    assert n == 2                            # frames 0 and 4, not 3
    # one frame short of the boundary: only the cold score
    short = TemporalMaskCache(refresh=4, delta_threshold=1e9)
    _, n2 = short.gate(_static_frames(4), np.arange(4), score_fn)
    assert n2 == 1


def test_mask_cache_delta_exactly_at_threshold_reuses():
    """The delta trigger is strict (delta > threshold): a frame whose mean
    abs delta equals the threshold exactly reuses the cached mask."""
    from repro.core.mgnet import frame_delta
    thr_frames = _static_frames(2)
    thr_frames[1] = 0.25                      # uniform delta of exactly 0.25
    delta = float(frame_delta(thr_frames[1:2], thr_frames[0])[0])
    assert delta == pytest.approx(0.25)

    def score_fn(f):
        return np.zeros((f.shape[0], 4), np.float32)

    at = TemporalMaskCache(refresh=1000, delta_threshold=delta)
    _, n_at = at.gate(thr_frames, np.arange(2), score_fn)
    assert n_at == 1                          # == threshold -> reuse
    below = TemporalMaskCache(refresh=1000,
                              delta_threshold=delta - 1e-6)
    _, n_below = below.gate(thr_frames, np.arange(2), score_fn)
    assert n_below == 2                       # just past it -> re-score


def test_mask_cache_static_score_shape():
    """score_fn must always see the full chunk shape (jit-retrace guard)."""
    cache = TemporalMaskCache(refresh=4, delta_threshold=1e9)
    shapes = set()

    def score_fn(f):
        shapes.add(f.shape)
        return np.zeros((f.shape[0], 4), np.float32)

    for c in range(4):
        cache.gate(_static_frames(8), np.arange(8 * c, 8 * c + 8), score_fn)
    assert shapes == {(8, 8, 8, 3)}


# --------------------------------------------------------------------------
# micro-batch scheduler
# --------------------------------------------------------------------------

def test_microbatcher_flushes_at_capacity():
    mb = MicroBatcher(microbatch=4)
    toks = jnp.arange(4 * 2 * 3, dtype=jnp.float32).reshape(4, 2, 3)
    assert mb.push_many(8, toks[:3], [0, 1, 2]) == []
    assert mb.pending == 3
    out = mb.push_many(8, toks[3:], [3])
    assert len(out) == 1
    fb = out[0]
    assert fb.bucket == 8 and fb.n_real == 4 and fb.frame_idx == [0, 1, 2, 3]
    np.testing.assert_array_equal(np.asarray(fb.tokens), np.asarray(toks))
    assert mb.pending == 0


def test_microbatcher_splits_oversized_groups():
    mb = MicroBatcher(microbatch=2)
    toks = jnp.arange(5 * 1 * 1, dtype=jnp.float32).reshape(5, 1, 1)
    out = mb.push_many(4, toks, [0, 1, 2, 3, 4])
    assert [f.frame_idx for f in out] == [[0, 1], [2, 3]]
    assert mb.pending == 1
    (tail,) = mb.drain()
    assert tail.frame_idx == [4] and tail.n_real == 1
    assert tail.tokens.shape == (2, 1, 1)            # zero-padded to mb
    assert float(tail.tokens[1].sum()) == 0.0


def test_microbatcher_keeps_buckets_separate():
    mb = MicroBatcher(microbatch=2)
    a = jnp.ones((1, 2, 2))
    b = jnp.ones((1, 4, 2))
    assert mb.push(2, a[0], 0) == []
    assert mb.push(4, b[0], 1) == []
    out = mb.push(2, a[0], 2)
    assert len(out) == 1 and out[0].bucket == 2
    assert mb.pending == 1               # bucket-4 frame still queued


# --------------------------------------------------------------------------
# accounting
# --------------------------------------------------------------------------

def test_energy_report_aggregation():
    a = EnergyReport(adc_uj=1.0, optical_us=2.0)
    b = EnergyReport(adc_uj=3.0, dac_uj=1.0)
    s = aggregate_reports([a, b])
    assert s.adc_uj == pytest.approx(4.0)
    assert s.dac_uj == pytest.approx(1.0)
    assert s.optical_us == pytest.approx(2.0)
    half = s.scaled(0.5)
    assert half.adc_uj == pytest.approx(2.0)
    a += b
    assert a.adc_uj == pytest.approx(4.0)


def test_stream_accounting_empty_flushes():
    """Zero-frame flushes (fully-padded micro-batches, idle streams) must
    not perturb the aggregate: no frames, no energy, KFPS/W stays 0 and
    the mean-frame report divides by nothing."""
    cfg = get_config("tiny", img_size=96, mgnet=True)
    acct = StreamAccounting(cfg)
    acct.add_encode(18, 0)
    acct.add_mgnet(0)
    assert acct.frames == 0 and acct.scored_frames == 0
    assert acct.kfps_per_watt == 0.0
    assert acct.mean_frame.total_uj == 0.0
    assert acct.total.total_uj == pytest.approx(0.0)
    # real frames after empty flushes aggregate exactly as if alone
    acct.add_encode(18, 3)
    fresh = StreamAccounting(cfg)
    fresh.add_encode(18, 3)
    assert acct.frames == fresh.frames == 3
    assert acct.mean_frame.total_uj == pytest.approx(
        fresh.mean_frame.total_uj)
    assert acct.kfps_per_watt == pytest.approx(fresh.kfps_per_watt)


def test_accounting_summary_reports_hits_and_launches():
    cfg = get_config("tiny", img_size=96, mgnet=True)
    acct = StreamAccounting(cfg, ladder_sizes=(9, 18, 27, 36))
    acct.add_encode(18, 4)
    acct.add_encode(18, 2)
    acct.add_encode(27, 4)
    with pytest.warns(UserWarning, match="dead ladder buckets"):
        s = acct.summary()
    assert "k=18: 6 hits/2 launches" in s
    assert "k=27: 4 hits/1 launches" in s
    assert "k=9: 0 hits/0 launches" in s
    assert "[dead: k=9, k=36]" in s
    assert acct.dead_buckets() == (9, 36)


def test_accounting_summary_no_dead_buckets_no_warning():
    import warnings as _w
    cfg = get_config("tiny", img_size=96, mgnet=True)
    acct = StreamAccounting(cfg, ladder_sizes=(9, 18))
    acct.add_encode(9, 1)
    acct.add_encode(18, 1)
    with _w.catch_warnings():
        _w.simplefilter("error")             # any warning -> test failure
        s = acct.summary()
    assert acct.dead_buckets() == ()
    assert "dead" not in s


def test_accounting_summary_without_ladder():
    """No registered ladder (the dense driver): summary reports whatever
    buckets were hit and never warns — a dense run has no ladder to tune."""
    import warnings as _w
    cfg = get_config("tiny", img_size=96, mgnet=True)
    acct = StreamAccounting(cfg)
    acct.add_encode(36, 5)
    with _w.catch_warnings():
        _w.simplefilter("error")
        s = acct.summary()
    assert "k=36: 5 hits/1 launches" in s


def test_stream_accounting_tracks_buckets_and_mgnet():
    cfg = get_config("tiny", img_size=96, mgnet=True)
    acct = StreamAccounting(cfg)
    acct.add_encode(18, 4)
    acct.add_mgnet(2)
    assert acct.frames == 4 and acct.scored_frames == 2
    e_small = acct.mean_frame.total_uj
    dense = StreamAccounting(cfg)
    dense.add_encode(36, 4)
    dense.add_mgnet(2)
    # fewer kept patches -> strictly less energy -> more KFPS/W
    assert e_small < dense.mean_frame.total_uj
    assert acct.kfps_per_watt > dense.kfps_per_watt
    # a gated stream must beat its own dense baseline
    assert acct.kfps_per_watt > acct.dense_baseline_kfps_per_watt()


# --------------------------------------------------------------------------
# video stream
# --------------------------------------------------------------------------

def test_video_stream_deterministic_and_coherent():
    vs = VideoStream(img_size=32, patch=8, seed=0, cut_every=8)
    a = vs.frames_at(0, 12)
    b = vs.frames_at(4, 4)
    np.testing.assert_array_equal(np.asarray(a["frames"][4:8]),
                                  np.asarray(b["frames"]))
    assert a["patch_mask"].shape == (12, 16)
    assert float(a["patch_mask"].sum(-1).min()) >= 1.0   # box always visible
    # consecutive frames are closer than frames across a scene cut
    f = np.asarray(a["frames"])
    d_in = np.abs(f[1] - f[0]).mean()
    d_cut = np.abs(f[8] - f[7]).mean()
    assert d_in < d_cut


def test_prefetch_preserves_order():
    vs = VideoStream(img_size=16, patch=8, seed=1)
    it = prefetch_to_device(vs.chunks(2), depth=3)
    seen = [int(next(it)["frame_idx"][0]) for _ in range(4)]
    assert seen == [0, 2, 4, 6]


# --------------------------------------------------------------------------
# engine end to end
# --------------------------------------------------------------------------

def _smoke_engine(backend: str, attn_backend: str = "",
                  ffn_backend: str = "", **serve_kw) -> ServingEngine:
    cfg = smoke_variant(get_config("tiny")).with_(
        mgnet=True, mgnet_embed=32, mgnet_heads=2, matmul_backend=backend,
        attn_backend=attn_backend, ffn_backend=ffn_backend)
    sc = ServingConfig(microbatch=4, chunk=8, mask_refresh=8, **serve_kw)
    return ServingEngine(cfg, sc, n_classes=8, seed=0)


def test_engine_streams_end_to_end():
    eng = _smoke_engine("photonic_sim")
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res = eng.run(stream, n_frames=32)
    assert res.frames >= 32
    assert sorted(res.predictions) == list(range(res.frames))
    assert sum(res.bucket_hits.values()) == res.frames
    assert 0 < res.scored_frames < res.frames        # mask reuse happened
    assert res.kfps_per_watt > 0 and res.mean_frame_uj > 0
    assert res.fps > 0


def test_engine_is_deterministic_across_runs():
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    r1 = _smoke_engine("photonic_sim").run(stream, n_frames=24)
    r2 = _smoke_engine("photonic_sim").run(stream, n_frames=24)
    assert r1.predictions == r2.predictions
    assert r1.bucket_hits == r2.bucket_hits
    assert r1.scored_frames == r2.scored_frames


def test_engine_pallas_serving_path():
    """The acceptance path: streaming on the int8 Pallas kernel backend."""
    eng = _smoke_engine("photonic_pallas")
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res = eng.run(stream, n_frames=16)
    assert res.frames >= 16
    assert sorted(res.predictions) == list(range(res.frames))


def test_engine_fused_flash_serving_path():
    """The tentpole path: int8 Pallas matmul backend + fused RoI-masked
    flash attention core, streaming end to end — predicting (nearly) the
    same classes as the xla attention core. The two dataflows agree only
    to reassociation noise, so a near-tied frame may legitimately flip:
    require >= 90% class agreement, not bitwise equality."""
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res_f = _smoke_engine("photonic_pallas", attn_backend="flash").run(
        stream, n_frames=16)
    assert res_f.frames >= 16
    assert sorted(res_f.predictions) == list(range(res_f.frames))
    res_x = _smoke_engine("photonic_pallas").run(stream, n_frames=16)
    agree = sum(res_f.predictions[i] == res_x.predictions[i]
                for i in res_f.predictions) / len(res_f.predictions)
    assert agree >= 0.9, (agree, res_f.predictions, res_x.predictions)


def test_engine_fully_fused_serving_path():
    """The PR's tentpole path: int8 Pallas matmuls + fused flash attention
    + fused FFN, the whole encoder one cached jit. Bucketed encodes carry
    no kv_len, so the fused FFN is bit-identical to the composed dispatch
    — predictions must match the composed engine exactly."""
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res_f = _smoke_engine("photonic_pallas", attn_backend="flash",
                          ffn_backend="fused").run(stream, n_frames=16)
    assert res_f.frames >= 16
    assert sorted(res_f.predictions) == list(range(res_f.frames))
    res_c = _smoke_engine("photonic_pallas", attn_backend="flash").run(
        stream, n_frames=16)
    assert res_f.predictions == res_c.predictions
    assert res_f.bucket_hits == res_c.bucket_hits
    # per-bucket launch telemetry rides along in the result
    assert sum(res_f.bucket_launches.values()) > 0
    assert set(res_f.bucket_launches) <= set(res_f.bucket_hits)


def test_engine_one_shape_fused_ffn_path():
    """One-shape mode on the fully-fused stack: the static per-bucket
    kv_len prunes FFN rows too (the packed skip), which legitimately
    changes w8a8 activation scale sets — class agreement >= 90%, same
    contract as the other cross-dataflow engine comparisons."""
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res_o = _smoke_engine("photonic_pallas", attn_backend="flash",
                          ffn_backend="fused", one_shape=True).run(
        stream, n_frames=16)
    assert res_o.frames >= 16
    assert sorted(res_o.predictions) == list(range(res_o.frames))
    res_g = _smoke_engine("photonic_pallas", attn_backend="flash",
                          ffn_backend="fused").run(stream, n_frames=16)
    agree = sum(res_o.predictions[i] == res_g.predictions[i]
                for i in res_g.predictions) / len(res_g.predictions)
    assert agree >= 0.9, (agree, res_o.predictions, res_g.predictions)
    assert res_o.mean_frame_uj == pytest.approx(res_g.mean_frame_uj)


def test_engine_one_shape_mode_matches_bucketed():
    """Fixed-sensor-buffer (one-shape) serving: every encode at the ladder
    cap with a static packed kept-count. Gating stats and bucket routing
    are identical to the gathered mode; predictions agree to the
    masked-vs-gathered parity contract (>= 90% on a float backend)."""
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res_g = _smoke_engine("bf16").run(stream, n_frames=16)
    res_o = _smoke_engine("bf16", one_shape=True).run(stream, n_frames=16)
    assert res_o.frames == res_g.frames
    assert res_o.bucket_hits == res_g.bucket_hits
    assert res_o.scored_frames == res_g.scored_frames
    assert sorted(res_o.predictions) == list(range(res_o.frames))
    agree = sum(res_o.predictions[i] == res_g.predictions[i]
                for i in res_g.predictions) / len(res_g.predictions)
    assert agree >= 0.9, (agree, res_o.predictions, res_g.predictions)
    # accelerator-model energy is identical: the packed prefix lets the
    # static schedule stream only the k live rows, exactly like a gather
    # (the cap-size host FFN is a functional-sim artifact)
    assert res_o.mean_frame_uj == pytest.approx(res_g.mean_frame_uj)
    assert res_o.kfps_per_watt == pytest.approx(res_g.kfps_per_watt)


def test_engine_force_bucket_pins_routing():
    eng = _smoke_engine("bf16", force_bucket=0.5)
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res = eng.run(stream, n_frames=16)
    n = eng.n_patches
    pinned = eng.ladder.route(n // 2)
    assert res.bucket_hits[pinned] == res.frames
    assert all(v == 0 for k, v in res.bucket_hits.items() if k != pinned)


def test_engine_dense_baseline_covers_stream():
    """The mask-mode dense path serves the same frames with the same gating
    stats, at strictly higher modeled energy per frame (compute not
    reduced). Logit-level agreement between the two paths is the bucketed-
    pruning parity contract — tests/test_bucket_parity.py."""
    eng = _smoke_engine("bf16")
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res_b = eng.run(stream, n_frames=16)
    res_d = eng.run_dense(stream, n_frames=16)
    assert res_b.frames == res_d.frames
    assert sorted(res_b.predictions) == sorted(res_d.predictions)  # coverage
    assert res_b.scored_frames == res_d.scored_frames  # identical gating
    assert res_b.mean_frame_uj < res_d.mean_frame_uj


def test_engine_serves_exact_frame_count():
    """n_frames that is not a chunk multiple: trailing frames of the last
    ingest chunk are gated but never routed, encoded or accounted."""
    eng = _smoke_engine("bf16")
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    res = eng.run(stream, n_frames=13)          # chunk=8 -> partial tail
    assert res.frames == 13
    assert sorted(res.predictions) == list(range(13))
    assert sum(res.bucket_hits.values()) == 13
    d = eng.run_dense(stream, n_frames=13)
    assert d.frames == 13
    assert sorted(d.predictions) == list(range(13))
    # trailing frames of the last chunk must not be scored or accounted:
    # a 1-frame run can have scored at most that one frame
    one = _smoke_engine("bf16").run(stream, n_frames=1)
    assert one.frames == 1
    assert one.scored_frames == 1 and one.reused_frames == 0


def test_engine_gather_matches_select_topk():
    """The engine's shared-order gather must select exactly what the public
    select_topk_patches API selects, for every ladder bucket."""
    from repro.core.mgnet import select_topk_patches
    from repro.serving.engine import _gather_topk_rows
    scores = jax.random.normal(jax.random.PRNGKey(0), (3, 16))
    scores = scores.at[:, 7].set(scores[:, 2])       # exact tie
    toks = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 5))
    order = jnp.argsort(scores, axis=-1, stable=True, descending=True)
    for k in (4, 8, 12, 16):
        via_engine = _gather_topk_rows(toks, order, k)
        via_api, _ = select_topk_patches(scores, toks, k)
        np.testing.assert_array_equal(np.asarray(via_engine),
                                      np.asarray(via_api))


def test_engine_requires_mgnet():
    cfg = smoke_variant(get_config("tiny"))          # mgnet=False
    with pytest.raises(ValueError):
        ServingEngine(cfg, ServingConfig())
