"""Per-layer / per-tensor bit plans + the sensitivity-driven allocator.

Opto-ViT's energy story is quantization co-designed with the photonic
substrate, and the per-layer allocation literature (ENLighten; the ViT
quantization survey in PAPERS.md) puts most of the edge energy win in
*non-uniform* width assignment: early/late layers keep 8 bits, the
insensitive middle drops to 6 or 4, and every dropped bit scales the
dominant SAR-ADC/DAC/SRAM energy terms roughly linearly (core/energy.py).
This module makes that a first-class serving input:

  * a **bit plan** is either a per-layer sequence (one width per encoder
    block, applied to all of that block's matmul weights) or a dict with
    optional ``"layers"`` / ``"default"`` keys plus per-tensor overrides
    keyed by param-path suffix (``"attn/wq"``, ``"ffn/w2"``, ...) whose
    values are an int or a per-layer sequence;
  * ``normalize_bit_plan`` canonicalizes any of those forms (and
    ``parse_bit_plan`` the CLI string forms: ``"8,6,4,8"`` or a JSON
    file path / literal); ``plan_key`` is the hashable identity that
    ``ExecPolicy.fingerprint()`` folds into jit-cache keys;
  * ``resolve_bits`` answers "what width does this param-tree leaf get"
    for ``core.backend.prepare_params`` — per-tensor overrides beat the
    per-layer assignment, which beats the default; non-block weights
    (patch embed, head, MGNet) stay at the default width;
  * ``calibrate_bit_plan`` is the allocator: per-layer perturbation
    scoring on a calibration batch (requantize one layer at a candidate
    width, measure that layer's output MSE against the uniform-8
    baseline), then greedy downgrades — always the cheapest sensitivity
    per saved bit — until the plan's mean width meets ``target_mean_bits``.

Widths are bounded to [2, 8]: 8 bits is the MR resolution limit of the
photonic core (core/noise.py), ``quant_range`` rejects anything below 2.
"""

from __future__ import annotations

import json
import os
from typing import Mapping

import jax
import jax.numpy as jnp

__all__ = ["normalize_bit_plan", "parse_bit_plan", "plan_key",
           "resolve_bits", "plan_layer_bits", "plan_mean_bits",
           "calibrate_bit_plan"]

_MAX_BITS = 8        # MR resolution limit (paper Sec. IV / core/noise.py)
_MIN_BITS = 2


def _check_bits(b) -> int:
    b = int(b)
    if not _MIN_BITS <= b <= _MAX_BITS:
        raise ValueError(f"bit width {b} outside the photonic core's "
                         f"supported [{_MIN_BITS}, {_MAX_BITS}] range")
    return b


def _as_layers(v, n_layers: int) -> tuple:
    seq = tuple(_check_bits(b) for b in v)
    if len(seq) != n_layers:
        raise ValueError(f"per-layer bit sequence has {len(seq)} entries "
                         f"for {n_layers} layers")
    return seq


def normalize_bit_plan(plan, n_layers: int, default: int = 8):
    """Canonicalize a bit plan to ``{"default", "layers", "tensors"}``.

    ``plan`` is a per-layer sequence, a dict (``"layers"`` / ``"default"``
    keys + per-tensor path-suffix overrides), or an already-normalized
    plan. Returns None for an empty/None plan (uniform quantization).
    """
    if plan is None:
        return None
    if isinstance(plan, Mapping):
        layers = plan.get("layers")
        out = {
            "default": _check_bits(plan.get("default", default)),
            "layers": (None if layers is None
                       else _as_layers(layers, n_layers)),
            "tensors": {},
        }
        for key, v in plan.items():
            if key in ("layers", "default"):
                continue
            out["tensors"][str(key)] = (
                _check_bits(v) if isinstance(v, (int, float, str))
                else _as_layers(v, n_layers))
        return out
    seq = tuple(plan)
    if not seq:
        return None
    return {"default": _check_bits(default),
            "layers": _as_layers(seq, n_layers), "tensors": {}}


def parse_bit_plan(spec: str):
    """CLI form -> plan: ``"8,6,4,8"`` (per-layer), a JSON literal, or a
    path to a JSON file holding the dict form."""
    spec = spec.strip()
    if not spec:
        return None
    if os.path.exists(spec):
        with open(spec) as f:
            return json.load(f)
    if spec.lstrip().startswith(("{", "[")):
        return json.loads(spec)
    return tuple(int(b) for b in spec.split(","))


def plan_key(plan) -> tuple | None:
    """Hashable identity of a normalized plan (jit-cache key material)."""
    if plan is None:
        return None
    return (plan["default"], plan["layers"],
            tuple(sorted(plan["tensors"].items())))


def _suffix_match(pattern: str, path_names: tuple) -> bool:
    parts = tuple(p for p in pattern.split("/") if p)
    return len(parts) <= len(path_names) and \
        tuple(path_names[-len(parts):]) == parts


def resolve_bits(plan, path_names: tuple):
    """Width for the leaf at ``path_names`` (tuple of str components).

    Per-tensor overrides (longest matching path suffix) beat the
    per-layer assignment, which applies only inside the scan-stacked
    ``blocks`` subtree; everything else gets the default. Returns an int
    or — for stacked block weights under a per-layer assignment — the
    per-layer tuple.
    """
    if plan is None:
        return None
    best = None
    for pattern, bits in plan["tensors"].items():
        if _suffix_match(pattern, path_names):
            if best is None or len(pattern.split("/")) > len(best[0].split("/")):
                best = (pattern, bits)
    if best is not None:
        return best[1]
    if "blocks" in path_names and plan["layers"] is not None:
        return plan["layers"]
    return plan["default"]


def plan_layer_bits(plan, n_layers: int) -> tuple:
    """Per-layer effective widths (the energy-accounting view): the
    per-layer assignment where given, else the default everywhere."""
    if plan is None:
        return (8,) * n_layers
    if plan["layers"] is not None:
        return plan["layers"]
    return (plan["default"],) * n_layers


def plan_mean_bits(plan, n_layers: int) -> float:
    lb = plan_layer_bits(plan, n_layers)
    return sum(lb) / len(lb)


# --------------------------------------------------------------------------
# sensitivity-driven allocation (the calibrator behind --bit-budget)
# --------------------------------------------------------------------------

def _slice_layer(tree, i: int):
    from repro.core.backend import QuantizedWeight
    return jax.tree_util.tree_map(
        lambda a: (QuantizedWeight(a.wq[i], a.scale[i], a.layer_bits(i))
                   if isinstance(a, QuantizedWeight) else a[i]),
        tree, is_leaf=lambda a: isinstance(a, QuantizedWeight))


def calibrate_bit_plan(params, tokens, cfg, policy,
                       target_mean_bits: float,
                       candidates: tuple = (6, 4),
                       default: int = 8) -> tuple:
    """Emit a per-layer bit plan meeting ``target_mean_bits``.

    ``params`` are the *raw* (un-prepared) weights; ``tokens`` a
    position-embedded calibration batch (B, k, d) — what ``embed_patches``
    hands the encoder. For every layer and every candidate width the
    layer's matmul weights are requantized alone and that single layer is
    re-run on its captured baseline input; the sensitivity score is the
    relative MSE of its output against the uniform-``default`` baseline.
    A greedy pass then downgrades whichever (layer, width) move costs the
    least added sensitivity per saved bit until the plan's mean width is
    <= ``target_mean_bits``. Returns the per-layer tuple (feed it to
    ``prepare_params(..., bit_plan=plan)``).

    Scoring runs the *composed* dispatch layer-by-layer under the given
    policy — the same numerics the fused path is bit-identical to, so the
    ranking transfers to the serving hot path.
    """
    from repro.core.backend import ExecPolicy, prepare_params
    from repro.models.vit import encoder_layer_step

    # scoring policy: defer widths to the cache (quant_bits=0) so probing
    # a layer at a candidate width is not flagged as a stale cache by
    # ``_weight_bits`` — the deliberate-divergence contract
    policy = ExecPolicy(quant_bits=0, photonic=policy.photonic,
                        training=False,
                        dot_out_native=policy.dot_out_native,
                        backend=policy.resolve_backend(),
                        interpret=policy.interpret,
                        attn_backend=policy.attn_backend,
                        ffn_backend=policy.ffn_backend)
    n_layers = cfg.n_layers
    candidates = tuple(sorted({_check_bits(b) for b in candidates},
                              reverse=True))
    if not candidates or target_mean_bits >= default:
        return (default,) * n_layers

    base = prepare_params(params, bits=default)
    b, _, d = tokens.shape
    cls = jnp.broadcast_to(base["cls"], (b, 1, d)) + base["pos"][:, :1]
    x = jnp.concatenate([cls.astype(tokens.dtype), tokens], axis=1)
    ins, outs = [], []
    for i in range(n_layers):
        ins.append(x)
        x = encoder_layer_step(x, _slice_layer(base["blocks"], i), cfg,
                               policy, None, None, None)
        outs.append(x)

    # sensitivity[(layer, bits)]: relative output MSE of requantizing just
    # that layer at that width
    raw_blocks = params["blocks"]
    sens: dict = {}
    for i in range(n_layers):
        ref = jnp.asarray(outs[i], jnp.float32)
        denom = float(jnp.mean(ref * ref)) + 1e-12
        raw_i = _slice_layer(raw_blocks, i)
        for cb in candidates:
            lp = prepare_params(raw_i, bits=cb)
            out = encoder_layer_step(ins[i], lp, cfg, policy, None, None,
                                     None)
            err = jnp.asarray(out, jnp.float32) - ref
            sens[(i, cb)] = float(jnp.mean(err * err)) / denom

    plan = [default] * n_layers

    def mean_bits():
        return sum(plan) / n_layers

    while mean_bits() > target_mean_bits:
        best = None
        for i in range(n_layers):
            lower = [cb for cb in candidates if cb < plan[i]]
            if not lower:
                continue
            nb = lower[0]                       # one step down at a time
            cur = sens.get((i, plan[i]), 0.0)   # default level costs 0
            cost = (sens[(i, nb)] - cur) / (plan[i] - nb)
            if best is None or cost < best[0]:
                best = (cost, i, nb)
        if best is None:                        # every layer at the floor
            break
        plan[best[1]] = best[2]
    return tuple(plan)
