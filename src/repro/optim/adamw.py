"""AdamW + SGD optimizers (pure-function, pytree state) + LR schedules.

Built from scratch (no optax in this environment). Two state-precision
modes:
  * fp32 (default): m, v in f32 — standard.
  * bf16 ("low_mem"): m, v stored bf16 — the 405B-scale memory trick
    (4 bytes/param optimizer state instead of 8; DESIGN.md §4). Update
    math still runs in f32; only storage is rounded.

Optimizer state inherits each parameter's sharding automatically under
jit (states are elementwise images of params).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "sgd_init",
           "sgd_update", "warmup_cosine", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    low_mem: bool = False          # bf16 m/v storage


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.low_mem else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state). All math f32; storage per cfg."""
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    store_dt = jnp.bfloat16 if cfg.low_mem else jnp.float32

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(store_dt), vf.astype(store_dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def sgd_init(params, momentum: float = 0.9):
    return {"mom": jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_update(grads, state, params, lr: float, momentum: float = 0.9):
    def upd(g, mo, p):
        mo = momentum * mo + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * mo).astype(p.dtype), mo
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mom"])
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    return (tdef.unflatten([o[0] for o in out]),
            {"mom": tdef.unflatten([o[1] for o in out])})


def warmup_cosine(step, *, peak_lr_scale: float = 1.0, warmup: int = 100,
                  total: int = 10000, floor: float = 0.1):
    """LR multiplier: linear warmup then cosine decay to floor*peak."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr_scale * jnp.where(s < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float = 1.0):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn
