"""Roofline HLO analyzer tests: trip counts, dot FLOPs, collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analysis import (Cost, analyze_module,
                                         parse_hlo, parse_shape, type_bytes)
from repro.roofline.report import HW, model_flops, roofline_terms
from repro.configs.base import SHAPES
from repro.configs.registry import get_config


class TestShapeParsing:
    def test_simple(self):
        assert parse_shape("f32[4,16,64]{2,1,0}") == ("f32", (4, 16, 64))
        assert parse_shape("bf16[8]") == ("bf16", (8,))
        assert parse_shape("s32[]") == ("s32", ())

    def test_tuple(self):
        t = parse_shape("(s32[], bf16[4,16]{1,0})")
        assert t == [("s32", ()), ("bf16", (4, 16))]

    def test_bytes(self):
        assert type_bytes("f32[4,4]") == 64
        assert type_bytes("bf16[10]") == 20
        assert type_bytes("(s32[], f32[2])") == 12


def _lower_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = _lower_text(lambda x, y: x @ y, a, b)
    cost = analyze_module(txt)
    assert cost.flops == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplies():
    """A scan of length 7 over a matmul must count 7x the dot FLOPs —
    the while-body trip multiplier (cost_analysis counts it once)."""
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, jnp.arange(7))
        return c

    cost = analyze_module(_lower_text(f, w, x))
    assert cost.flops == 7 * 2 * 8 * 32 * 32


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c2, None
        c, _ = jax.lax.scan(outer, x, jnp.arange(5))
        return c

    cost = analyze_module(_lower_text(f, w, x))
    assert cost.flops == 5 * 3 * 2 * 4 * 16 * 16


def test_bytes_positive_and_sane():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = _lower_text(lambda x: jnp.tanh(x) + 1.0, a)
    cost = analyze_module(txt)
    # at least read input + write output once; at most a few copies
    assert 2 * 256 * 256 * 4 <= cost.bytes <= 8 * 256 * 256 * 4


def test_collective_bytes_from_synthetic_hlo():
    """Hand-written module exercises the replica-group parse + per-op
    wire-bytes model without needing multiple devices."""
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[1024]{0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ag), source_target_pairs={{0,1}}, replica_groups=[2,4]<=[8]
}
"""
    cost = analyze_module(hlo)
    nb = 1024 * 4
    assert cost.coll_by_op["all-reduce"] == pytest.approx(2 * 0.75 * nb)
    assert cost.coll_by_op["all-gather"] == pytest.approx(0.75 * nb)
    assert cost.coll_by_op["collective-permute"] == pytest.approx(nb)


def test_while_trip_count_fallback_from_cond_constant():
    """A while whose backend_config lost ``known_trip_count`` must recover
    the bound from the cond computation's compare-against-constant — the
    parsed constant carries its literal as the sole *operand*."""
    hlo = """
HloModule wtest

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  ROOT %w = (s32[], f32[8,8]) while(%arg), condition=%cond, body=%body
}
"""
    cost = analyze_module(hlo)
    assert cost.flops == 5 * 2 * 8 * 8 * 8


def test_synthetic_conditional_exact_half():
    """branch_computations={compute, identity} must average to exactly
    half the dot's FLOPs."""
    hlo = """
HloModule ctest

%btrue (x: f32[16,16]) -> f32[16,16] {
  %x = f32[16,16]{1,0} parameter(0)
  ROOT %d = f32[16,16]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%bfalse (y: f32[16,16]) -> f32[16,16] {
  ROOT %y = f32[16,16]{1,0} parameter(0)
}

ENTRY %main (b: s32[], x: f32[16,16]) -> f32[16,16] {
  %b = s32[] parameter(0)
  %x = f32[16,16]{1,0} parameter(1)
  ROOT %c = f32[16,16]{1,0} conditional(%b, %x, %x), branch_computations={%btrue, %bfalse}
}
"""
    cost = analyze_module(hlo)
    assert cost.flops == 0.5 * 2 * 16 * 16 * 16


def test_real_vit_encode_flops_and_bytes_bracket_analytic():
    """The serving control plane prices encode buckets from this analyzer:
    on a real lowered tiny-ViT token encode the parsed FLOPs must bracket
    the analytic 2*sum(M*K*N) event count (within the slack XLA's extra
    dots — classifier head, fused epilogues — can add), HBM bytes must at
    least read the encoder weights once and stay bounded, and an f32
    lowering must report zero int8 FLOPs."""
    from repro.models.vit import (forward_vit_tokens, init_vit,
                                  vit_matmul_shapes)
    from repro.configs.opto_vit import get_config as vit_config
    from repro.configs.base import smoke_variant

    cfg = smoke_variant(vit_config("tiny"))
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=10)
    n_patches = (cfg.img_size // cfg.patch) ** 2
    k, batch = max(1, n_patches // 2), 2
    toks = jax.ShapeDtypeStruct((batch, k, cfg.d_model), jnp.float32)
    cost = analyze_module(_lower_text(
        lambda p, t: forward_vit_tokens(p, t, cfg)[0], params, toks))

    # per-frame analytic dots, encoder only (entry 0 is the patch embed,
    # which happened upstream of the token forward)
    analytic = batch * sum(2 * m * kk * n for m, kk, n
                           in vit_matmul_shapes(cfg, kept_patches=k)[1:])
    assert analytic <= cost.flops <= 3 * analytic
    d, dff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    weight_bytes = L * (4 * d * d + 2 * d * dff) * 4      # f32 encoder
    assert weight_bytes <= cost.bytes <= 50 * weight_bytes
    assert cost.int8_flops == 0


def test_conditional_branches_averaged():
    """lax.cond branches average — the causal block-skip accounting."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        return jax.lax.cond(x[0, 0] > 0, lambda: x @ w, lambda: x)

    cost = analyze_module(_lower_text(f, x, w))
    full = 2 * 64 * 64 * 64
    assert 0.25 * full <= cost.flops <= 0.75 * full


class TestReport:
    def test_model_flops_train(self):
        cfg = get_config("qwen2-1.5b")
        sh = SHAPES["train_4k"]
        mf = model_flops(cfg, sh)
        assert mf == pytest.approx(6 * cfg.param_count() * sh.tokens)

    def test_model_flops_moe_uses_active(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        sh = SHAPES["train_4k"]
        assert model_flops(cfg, sh) < 6 * cfg.param_count() * sh.tokens

    def test_terms_and_dominance(self):
        cfg = get_config("qwen2-1.5b")
        sh = SHAPES["train_4k"]
        cost = Cost(flops=1e15, bytes=1e12, coll_bytes=1e10)
        t = roofline_terms(cost, cfg, sh, 256)
        assert t["compute_s"] == pytest.approx(1e15 / 197e12)
        assert t["memory_s"] == pytest.approx(1e12 / 819e9)
        assert t["collective_s"] == pytest.approx(1e10 / 50e9)
        assert t["dominant"] == "compute"
        assert 0 < t["roofline_frac"] <= 1.0
