"""Shared neural-net building blocks (pure JAX, pytree params).

Every matmul in the framework funnels through ``linear`` so the paper's
execution modes apply uniformly:
  * quant_bits=8   -> QAT fake-quant (training) / w8a8 integer path (inference)
  * photonic=True  -> route through the optical-core simulator (bit-faithful
    chunked w8a8 MatMul, optional MR noise) — used by the ViT benchmarks.
Default (0/False) is the plain bf16 TPU path used by the LM dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.photonic import OpticalCoreConfig, photonic_matmul_exact
from repro.distributed.sharding import shard

__all__ = ["linear", "rmsnorm", "layernorm", "rope", "apply_rope",
           "embedding_lookup", "causal_conv1d", "he_init", "lecun_init",
           "ExecPolicy"]


class ExecPolicy:
    """Execution-mode knobs threaded from ArchConfig into every layer."""

    __slots__ = ("quant_bits", "photonic", "training", "dot_out_native")

    def __init__(self, quant_bits: int = 0, photonic: bool = False,
                 training: bool = True, dot_out_native: bool = False):
        self.quant_bits = quant_bits
        self.photonic = photonic
        self.training = training
        self.dot_out_native = dot_out_native

    @staticmethod
    def from_cfg(cfg, training: bool = True) -> "ExecPolicy":
        return ExecPolicy(getattr(cfg, "quant_bits", 0),
                          getattr(cfg, "photonic", False), training,
                          getattr(cfg, "dot_out_native", False))


_DEFAULT = ExecPolicy()


def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
           policy: ExecPolicy | None = None) -> jnp.ndarray:
    """y = x @ w (+ b) under the active execution policy.

    x: (..., d_in), w: (d_in, d_out). Contraction in the input dtype with
    f32 accumulation via preferred_element_type (MXU semantics).
    """
    p = policy or _DEFAULT
    if p.photonic:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = photonic_matmul_exact(x2.astype(jnp.float32), w.astype(jnp.float32))
        y = y.reshape(*lead, w.shape[-1]).astype(x.dtype)
    elif p.quant_bits:
        # QAT: fake-quant weights per-out-channel + activations per-tensor,
        # STE in training so gradients flow (paper §IV Accuracy Analysis).
        fq = quant.fake_quant_ste if p.training else quant.fake_quant
        wq = fq(w, bits=p.quant_bits, axis=tuple(range(w.ndim - 1)))
        xq = fq(x, bits=p.quant_bits, axis=None)
        y = jax.lax.dot_general(xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
    elif p.dot_out_native:
        # operand-dtype output: the MXU still accumulates f32 internally
        # for bf16 operands, but no f32 result materializes in HBM and the
        # TP all-reduce (when this matmul is row-parallel) moves bf16 —
        # §Perf hillclimb knob (halves dominant activation-AR wire bytes).
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))
    else:
        y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b
    return y


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g + b


def rope(positions: jnp.ndarray, head_dim: int,
         theta: float = 500000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rotary embedding tables. positions: (..., seq). Returns cos/sin of
    shape (..., seq, head_dim/2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]   # broadcast over heads
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Gather rows; with a vocab-sharded table XLA turns this into a
    one-hot-free dynamic-gather + collective."""
    return jnp.take(table, ids, axis=0)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C).

    Training/prefill: returns (y, final_state) where final_state is the last
    K-1 inputs (for handoff to decode). Decode (S==1 with state): uses the
    rolling state. This is the Mamba/Griffin short conv.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)            # (B, S+K-1, C)
    y = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(k))
    new_state = xp[..., -(k - 1):, :]
    return y.astype(x.dtype), new_state


def he_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def lecun_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(1.0 / fan_in)).astype(dtype)
