"""Micro-batch scheduler: group same-bucket frames into one encode launch.

Frames routed to the same bucket size k are queued until ``microbatch`` of
them are waiting, then flushed as one (microbatch, k, d) ``forward_vit_tokens``
call — a single warm-jit launch per flush. Frames arrive as *groups* (all
same-bucket frames of one ingest chunk come in one (m, k, d) gather output),
and the queue stores groups, so the flush is at most one concatenate — not
per-frame slicing + stacking, which at serving rates costs more dispatches
than the encode itself. Single frames (``push``) are stored as bare rows and
only expanded to group rank at flush time, so a stream of per-frame pushes
never materializes a ``[None]``-copy per frame. End-of-stream partials are
padded with zero frames up to the micro-batch size so the encode shape set
stays exactly |ladder| (no trailing-shape recompiles); padded rows are
discarded and never accounted.

The multi-stream server (``repro.serving.server``) keys one shared batcher
with ``(bucket, session)`` tuples — queue keys are opaque here — and drives
two extra scheduler surfaces:

  * ``push``/``push_many`` accept a monotonic ``now`` tick stamped on each
    queued group;
  * ``flush_stale(deadline)`` pad-flushes every queue whose *oldest* entry
    was queued at or before ``deadline`` — the server's max-wait bound on
    how long a partially-filled micro-batch may hold frames hostage,
    without the caller ever reaching into ``_queues``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Hashable

import jax.numpy as jnp

__all__ = ["FrameBatch", "MicroBatcher"]


@dataclass
class FrameBatch:
    """One flushed encode workload: ``tokens[:n_real]`` are live frames."""

    bucket: Hashable            # queue key: kept-patch count k (or the
    #                             server's (k, session) tuple)
    tokens: jnp.ndarray         # (microbatch, k, d) — zero-padded past n_real
    frame_idx: list             # len n_real, stream positions of live rows
    #                             (ints, or the server's (sid, idx) pairs)
    n_real: int


class MicroBatcher:
    """Per-bucket group queues with flush-at-``microbatch`` semantics."""

    def __init__(self, microbatch: int = 4):
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        self.microbatch = microbatch
        # key -> [(tokens, [frame_idx], now, is_row)] where tokens is a
        # (m, k, d) group (is_row=False) or a bare (k, d) row (is_row=True)
        self._queues: dict[Hashable, list] = {}
        self.flushes = 0

    def push(self, bucket: Hashable, tokens, frame_idx, now: int = 0
             ) -> list[FrameBatch]:
        """Queue a single frame. The bare (k, d) row is stored as-is in the
        same group storage ``push_many`` uses and expanded to group rank
        only when its flush assembles — no per-frame ``[None]`` copy."""
        q = self._queues.setdefault(bucket, [])
        q.append((tokens, [frame_idx], now, True))
        return self._collect(bucket)

    def push_many(self, bucket: Hashable, tokens, frame_idx: list,
                  now: int = 0) -> list[FrameBatch]:
        """Queue a group of same-bucket frames; returns every FrameBatch
        that became ready (possibly several if the group overfills)."""
        if tokens.shape[0] != len(frame_idx):
            raise ValueError("tokens/frame_idx length mismatch")
        q = self._queues.setdefault(bucket, [])
        q.append((tokens, list(frame_idx), now, False))
        return self._collect(bucket)

    def _collect(self, bucket: Hashable) -> list[FrameBatch]:
        out = []
        while self._rows(bucket) >= self.microbatch:
            out.append(self._take(bucket))
        return out

    def _rows(self, bucket: Hashable) -> int:
        return sum(len(it[1]) for it in self._queues.get(bucket, ()))

    def _take(self, bucket: Hashable, pad: bool = False) -> FrameBatch:
        """Pop exactly ``microbatch`` rows (splitting an oversized group back
        onto the queue); with ``pad`` a short tail is zero-filled."""
        q = self._queues[bucket]
        items, idxs, rows = [], [], 0
        while q and rows < self.microbatch:
            t, ix, now, is_row = q.pop(0)
            if is_row:
                t = t[None]                      # row -> group, at flush time
            need = self.microbatch - rows
            if t.shape[0] > need:
                q.insert(0, (t[need:], ix[need:], now, False))
                t, ix = t[:need], ix[:need]
            items.append(t)
            idxs.extend(ix)
            rows += t.shape[0]
        if not q:
            self._queues.pop(bucket)
        n_real = rows
        if pad and rows < self.microbatch:
            items.append(jnp.zeros((self.microbatch - rows,)
                                   + items[0].shape[1:], items[0].dtype))
        toks = items[0] if len(items) == 1 else jnp.concatenate(items, axis=0)
        self.flushes += 1
        return FrameBatch(bucket, toks, idxs, n_real)

    def drain(self, select: Callable[[Hashable], bool] | None = None
              ) -> list[FrameBatch]:
        """Flush every partial queue (zero-padded to the micro-batch size).
        ``select`` restricts the sweep to matching queue keys — the server
        drains one finished session's queues without disturbing the rest."""
        keys = [k for k in sorted(self._queues)
                if select is None or select(k)]
        return [self._take(k, pad=True) for k in keys]

    def flush_stale(self, deadline: int) -> list[FrameBatch]:
        """Pad-flush every queue whose oldest entry was pushed at or before
        ``deadline`` (the ``now`` tick of ``push``/``push_many``), oldest
        queue first — the server's max-wait latency bound."""
        stale = [(q[0][2], k) for k, q in self._queues.items()
                 if q and q[0][2] <= deadline]
        return [self._take(k, pad=True) for _, k in sorted(
            stale, key=lambda e: (e[0], str(e[1])))]

    def flush_filled(self, threshold_of: Callable[[Hashable], int]
                     ) -> list[FrameBatch]:
        """Pad-flush every queue holding at least ``threshold_of(key)``
        rows (thresholds at or above the micro-batch size never fire here
        — full queues already flushed in ``_collect``). The control
        plane's per-bucket flush-threshold knob: a chronically partial
        bucket stops waiting for a fill that never comes."""
        out = []
        for k in sorted(self._queues, key=str):
            thr = threshold_of(k)
            if thr < self.microbatch and self._rows(k) >= thr:
                out.append(self._take(k, pad=True))
        return out

    def discard(self, select: Callable[[Hashable], bool]) -> int:
        """Drop every queue whose key matches ``select`` without flushing
        it — the quarantine path: a hard-failed session's queued frames
        must never reach the device (their launches would be billed and
        their padding would waste flush slots). Returns rows dropped."""
        doomed = [k for k in self._queues if select(k)]
        dropped = 0
        for k in doomed:
            dropped += self._rows(k)
            del self._queues[k]
        return dropped

    def export(self, select: Callable[[Hashable], bool] | None = None
               ) -> list:
        """Non-destructive snapshot of queued entries as
        ``(key, tokens, frame_idx, now, is_row)`` tuples, queue order
        preserved — the checkpoint/migration surface. Re-``push``-ing the
        entries into an empty batcher in export order reconstructs the
        exact queue state (same groups, same ``now`` ticks), which is what
        keeps a restored serve's per-launch absmax scopes — and therefore
        its predictions — bitwise identical (pad-flushing partials at
        checkpoint time would change them)."""
        out = []
        for k in sorted(self._queues, key=str):
            if select is not None and not select(k):
                continue
            for t, ix, now, is_row in self._queues[k]:
                out.append((k, t, list(ix), now, is_row))
        return out

    def rows(self, key: Hashable) -> int:
        """Rows currently queued under ``key`` (0 for unknown keys)."""
        return self._rows(key)

    def queue_stats(self) -> dict:
        """key -> (queued rows, oldest entry's ``now`` tick) for every
        non-empty queue — the live depth view the controller's re-tuning
        reads without touching ``_queues``."""
        return {k: (self._rows(k), q[0][2])
                for k, q in self._queues.items() if q}

    def pending_keys(self) -> tuple:
        """Keys of queues currently holding frames."""
        return tuple(sorted(self._queues, key=str))

    @property
    def pending(self) -> int:
        return sum(self._rows(k) for k in self._queues)
