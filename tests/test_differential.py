"""Property-based cross-backend differential harness.

One module owns the repo's numerics contracts, as *generated* properties
instead of hand-picked sweeps (the ad-hoc shape lists that used to live in
test_backend_parity.py / test_bucket_parity.py are replaced by strategies
here; those files keep pinned regression cases):

  (a) the four matmul backends agree on ``linear`` within per-backend
      tolerances — photonic_sim and photonic_pallas to f32-epilogue noise,
      qat to dequant-reassociation noise, bf16 to 8-bit quantization noise
      (correlation, not allclose);
  (b) masked-dense and gathered-top-k ViT forwards agree for every
      backend x attention backend, including photonic_pallas in interpret
      mode — the serving parity contract under generated budgets;
  (c) the fused RoI-masked flash attention (both lowerings: the Pallas
      kernel in interpret mode and the XLA twin) matches the dense
      NEG_INF-masked oracle ``kernels/ref.py::flash_attention_ref`` over
      generated shapes, masks and dtypes;

  (d) the fused int8 FFN (kernels/fused_ffn.py, both lowerings) is
      bit-identical to the composed two-linear dispatch on every matmul
      backend, its packed ``live_rows`` skip matches the composed dispatch
      on the live slice exactly, and the fully-fused scanned encoder
      (photonic_pallas + flash + fused, single jit) is bit-identical to an
      unrolled per-layer loop of the same composed dispatch.

Tolerance policy (documented in README "Testing & parity"):
  float-only paths            rtol/atol 2e-5 (2e-2 for bf16 io)
  integer-photonic pairs      bitwise on accumulates, 1e-6 after dequant
  quant vs float              corr > 0.999 (8-bit noise is not allclose-able)
  masked vs gathered (w8a8)   corr > 0.995 generated budgets / 0.999 pinned
                              ladder budgets, + allclose 0.35 (the two modes
                              absmax-scale different token sets)

Runs under real hypothesis (CI) or the deterministic fallback shim
(seed container). Reproduce a CI failure locally with the printed seed:
    PYTHONPATH=src python -m pytest tests/test_differential.py -p no:randomly
Every strategy feeds jax.random.PRNGKey(seed), so a drawn example is fully
pinned by its integers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # seed container
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core import backend as be
from repro.core.backend import (ExecPolicy, QuantizedWeight, linear,
                                prepare_params, quantize_weight)
from repro.core.mgnet import select_topk_patches
from repro.kernels.flash_attention import (flash_attention_masked,
                                           flash_attention_masked_xla)
from repro.kernels.fused_ffn import fused_ffn_int8, fused_ffn_xla
from repro.kernels.ref import flash_attention_ref
from repro.models import ffn as ffn_mod
from repro.models.layers import layernorm
from repro.models.vit import (embed_patches, encode_tokens,
                              encoder_layer_step, forward_vit_masked,
                              forward_vit_tokens, init_vit)

pytestmark = pytest.mark.slow          # CI runs this module in the slow job

N_PATCHES = 16


# --------------------------------------------------------------------------
# shared model fixtures (one smoke ViT reused across generated examples)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_cfg():
    return smoke_variant(get_config("tiny")).with_(n_layers=2)


@pytest.fixture(scope="module")
def params(base_cfg):
    return init_vit(jax.random.PRNGKey(1), base_cfg, n_classes=8)


@pytest.fixture(scope="module")
def prepared(params):
    return prepare_params(params, bits=8)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))


def _mask_from_idx(idx, n):
    b = idx.shape[0]
    return jnp.zeros((b, n)).at[jnp.arange(b)[:, None], idx].set(1.0)


def _masked_vs_gathered(cfg, params, images, k, seed, rtol=None):
    """The serving parity property: gathered top-k logits == masked dense
    logits, to float noise on float paths / 8-bit noise on w8a8 paths."""
    scores = jax.random.normal(jax.random.PRNGKey(seed), (2, N_PATCHES))
    toks = embed_patches(params, images, cfg)
    pruned, idx = select_topk_patches(scores, toks, k)
    lg_topk, kept = forward_vit_tokens(params, pruned, cfg)
    assert kept == k
    lg_mask, _ = forward_vit_masked(params, images,
                                    _mask_from_idx(idx, N_PATCHES), cfg)
    a = np.asarray(lg_topk, np.float32)
    m = np.asarray(lg_mask, np.float32)
    if rtol is not None:
        np.testing.assert_allclose(a, m, rtol=rtol, atol=rtol)
    else:                                   # w8a8: scale sets differ
        # generated budgets include tiny k, where per-tensor activation
        # scales diverge most between the two token sets — corr > 0.995
        # here; the pinned ladder budgets hold 0.999 (test_bucket_parity)
        assert np.corrcoef(a.ravel(), m.ravel())[0, 1] > 0.995
        np.testing.assert_allclose(a, m, rtol=0.35, atol=0.35)


# --------------------------------------------------------------------------
# (a) four matmul backends on generated shapes
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 160), st.integers(1, 96),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_linear_backend_agreement(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    out = {name: np.asarray(linear(x, w, policy=ExecPolicy(backend=name,
                                                           quant_bits=8,
                                                           training=False)))
           for name in ("bf16", "qat", "photonic_sim", "photonic_pallas")}
    # the two photonic executions share one integer contract
    np.testing.assert_allclose(out["photonic_sim"], out["photonic_pallas"],
                               rtol=1e-6, atol=1e-6)
    # fake-quant computes the same w8a8 product in float order
    scale = max(np.abs(out["photonic_sim"]).max(), 1e-6)
    np.testing.assert_allclose(out["qat"], out["photonic_sim"],
                               rtol=2e-4, atol=2e-4 * scale)
    # full precision agrees to 8-bit quantization noise only
    if out["bf16"].size > 1 and np.abs(out["bf16"]).max() > 1e-6:
        corr = np.corrcoef(out["bf16"].ravel(),
                           out["photonic_sim"].ravel())[0, 1]
        assert corr > 0.999, corr


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 96), st.integers(1, 200), st.integers(1, 96),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_int_accumulate_bit_identical(m, k, n, seed):
    """The generated-shape version of the pinned tiny-96 accumulate sweep."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    xq = jax.random.randint(kx, (m, k), -127, 128, jnp.int32).astype(jnp.int8)
    wq = jax.random.randint(kw, (k, n), -127, 128, jnp.int32).astype(jnp.int8)
    exact = np.asarray(be.int_accumulate_exact(xq, wq))
    np.testing.assert_array_equal(exact, np.asarray(be.int_accumulate_sim(xq, wq)))
    np.testing.assert_array_equal(exact,
                                  np.asarray(be.int_accumulate_pallas(xq, wq)))


# --------------------------------------------------------------------------
# (b) masked vs gathered forwards, generated budgets
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(1, N_PATCHES), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["standard", "decomposed"]),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_bf16(base_cfg, params, images,
                                      k, seed, attn_impl, attn_backend):
    cfg = base_cfg.with_(matmul_backend="bf16", attn_impl=attn_impl,
                         attn_backend=attn_backend)
    _masked_vs_gathered(cfg, params, images, k, seed, rtol=1e-4)


@settings(max_examples=4, deadline=None)
@given(st.integers(1, N_PATCHES - 1), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["qat", "photonic_sim"]),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_quant(base_cfg, params, prepared, images,
                                       k, seed, backend, attn_backend):
    cfg = base_cfg.with_(matmul_backend=backend, quant_bits=8,
                         attn_backend=attn_backend)
    p = prepared if backend.startswith("photonic") else params
    _masked_vs_gathered(cfg, p, images, k, seed)


@settings(max_examples=2, deadline=None)
@given(st.sampled_from([4, 8, 12]), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_pallas_interpret(base_cfg, prepared, images,
                                                  k, seed, attn_backend):
    """The acceptance path: the int8 Pallas kernel (interpret mode) holds
    the same masked-vs-gathered contract; with attn_backend=flash the
    whole MHSA block runs the fused prequant serving hot path."""
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend=attn_backend)
    _masked_vs_gathered(cfg, prepared, images, k, seed)


# --------------------------------------------------------------------------
# (c) fused RoI-masked attention vs the dense NEG_INF oracle
# --------------------------------------------------------------------------

def _qkv_mask(seed, b, h, hk, hv, s, d, dv, density, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hv, s, dv), dtype)
    mask = (jax.random.uniform(ks[3], (b, s)) < density).astype(jnp.float32)
    mask = mask.at[:, 0].set(1.0)          # the [cls] invariant
    return q, k, v, mask


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.integers(4, 48), st.sampled_from([8, 16, 32]),
       st.floats(0.1, 1.0), st.integers(0, 2 ** 31 - 1))
def test_fuzz_fused_masked_xla_twin_matches_ref(b, h, s, d, density, seed):
    q, k, v, mask = _qkv_mask(seed, b, h, h, h, s, d, d, density)
    out = flash_attention_masked_xla(q, k, v, mask)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([(2, 1, 2), (4, 2, 4), (2, 2, 2)]),
       st.integers(4, 40), st.sampled_from([(16, 16), (32, 8)]),
       st.floats(0.15, 1.0), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([16, 64]))
def test_fuzz_fused_masked_kernel_matches_ref(b, heads, s, dims, density,
                                              seed, bkv):
    """The Pallas kernel itself (interpret mode), over generated GQA/MQA
    head layouts, D != Dv, block sizes, shapes that need padding, and
    mask densities — bit-compared (allclose 2e-5) to the masked oracle."""
    h, hk, hv = heads
    d, dv = dims
    q, k, v, mask = _qkv_mask(seed, b, h, hk, hv, s, d, dv, density)
    out = flash_attention_masked(q, k, v, mask, bq=16, bkv=bkv)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.integers(4, 40), st.integers(0, 40),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_fused_kvlen_matches_mask(b, s, kv_len, seed):
    """Packed kept-count == explicit prefix mask, on both lowerings."""
    kv_len = min(kv_len, s)
    q, k, v, _ = _qkv_mask(seed, b, 2, 2, 2, s, 16, 16, 1.0)
    prefix = jnp.broadcast_to(
        (jnp.arange(s) < kv_len).astype(jnp.float32)[None], (b, s))
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=prefix)
    out_k = flash_attention_masked(q, k, v, kv_len=kv_len, bq=16, bkv=16)
    out_x = flash_attention_masked_xla(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# pinned regression seeds (cases that once failed or probe known edges)
# --------------------------------------------------------------------------

PINNED = [
    # (b, (h, hk, hv), s, (d, dv), density, seed, bkv)
    (1, (2, 1, 2), 37, (64, 24), 0.5, 7, 16),    # Eq.2 layout: MQA keys, dv<d
    (2, (4, 2, 4), 17, (16, 16), 0.3, 11, 16),   # GQA + heavy pruning
    (1, (2, 2, 2), 33, (32, 32), 1.0, 3, 16),    # dense (no mask effect)
    (2, (2, 2, 2), 16, (16, 16), 0.05, 5, 8),    # near-empty mask, cls only
]


@pytest.mark.parametrize("b,heads,s,dims,density,seed,bkv", PINNED)
def test_pinned_fused_masked_kernel(b, heads, s, dims, density, seed, bkv):
    h, hk, hv = heads
    d, dv = dims
    q, k, v, mask = _qkv_mask(seed, b, h, hk, hv, s, d, dv, density)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    out = flash_attention_masked(q, k, v, mask, bq=16, bkv=bkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    out_x = flash_attention_masked_xla(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pinned_all_masked_rows_return_zero():
    """A batch row whose every key is pruned outputs exactly 0 on the
    kernel, the XLA twin, the oracle AND both attend() backends (the
    zero-denominator guard is part of the attention contract, not a
    flash-only behavior)."""
    from repro.core.backend import attend
    q, k, v, _ = _qkv_mask(0, 2, 2, 2, 2, 12, 16, 16, 1.0)
    mask = jnp.zeros((2, 12)).at[0, 3].set(1.0)    # row 1 fully masked
    for fn in (lambda: flash_attention_masked(q, k, v, mask, bq=8, bkv=8),
               lambda: flash_attention_masked_xla(q, k, v, mask),
               lambda: flash_attention_ref(q, k, v, causal=False,
                                           key_mask=mask),
               lambda: attend(q, k, v, ExecPolicy(), mask=mask),
               lambda: attend(q, k, v, ExecPolicy(attn_backend="flash"),
                              mask=mask)):
        out = np.asarray(fn())
        assert np.isfinite(out).all()
        np.testing.assert_array_equal(out[1], np.zeros_like(out[1]))


def test_pinned_fused_prequant_accepts_elided_mask(base_cfg, prepared):
    """The fused hot path accepts the same lead-dim-elided (n,) masks the
    composed dispatch broadcasts — whether cached weights are installed
    must not change the accepted mask shapes of mhsa_standard."""
    from repro.core.backend import QuantizedWeight
    from repro.core.decomposed_attention import mhsa_standard
    blk = {name: QuantizedWeight(w.wq[0], w.scale[0], w.bits)
           for name, w in prepared["blocks"]["attn"].items()}
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, base_cfg.d_model))
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                     attn_backend="flash")
    shared = jnp.zeros((8,)).at[:5].set(1.0)
    o_1d = mhsa_standard(x, blk, base_cfg.n_heads, pol, shared)
    o_2d = mhsa_standard(x, blk, base_cfg.n_heads, pol,
                         jnp.broadcast_to(shared[None], (2, 8)))
    np.testing.assert_array_equal(np.asarray(o_1d), np.asarray(o_2d))


def test_pinned_bf16_io_fused_masked():
    q, k, v, mask = _qkv_mask(9, 1, 2, 2, 2, 24, 16, 16, 0.6, jnp.bfloat16)
    out = flash_attention_masked(q, k, v, mask, bq=8, bkv=8)
    assert out.dtype == jnp.bfloat16
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("k", [4, 8, 12])
def test_pinned_one_shape_kvlen_matches_gathered(base_cfg, params, images, k):
    """One-shape serving parity: encoding all N score-ordered tokens with
    a static packed kv_len == encoding the gathered top-k tokens (the
    first k of the same order) — on both attention backends."""
    scores = jax.random.normal(jax.random.PRNGKey(3), (2, N_PATCHES))
    order = jnp.argsort(scores, axis=-1, stable=True, descending=True)
    toks = embed_patches(params, images, base_cfg)
    permuted = jnp.take_along_axis(toks, order[:, :, None], axis=1)
    for ab in ("", "flash"):
        cfg = base_cfg.with_(matmul_backend="bf16", attn_backend=ab)
        lg_one, kept = forward_vit_tokens(params, permuted, cfg, kv_len=k)
        assert kept == k
        lg_gath, _ = forward_vit_tokens(params, permuted[:, :k], cfg)
        np.testing.assert_allclose(np.asarray(lg_one), np.asarray(lg_gath),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=ab or "xla")


def test_pinned_attend_broadcastable_mask_both_backends():
    """attend() accepts lead-dim-elided masks ((Skv,) shared across the
    batch) identically on both attention backends — the dispatch must not
    change the mask contract."""
    from repro.core.backend import attend
    q, k, v, _ = _qkv_mask(6, 3, 2, 2, 2, 12, 16, 16, 1.0)
    shared = jnp.zeros((12,)).at[:7].set(1.0)      # one mask, every batch
    full = jnp.broadcast_to(shared[None], (3, 12))
    for ab in ("", "flash"):
        pol = ExecPolicy(attn_backend=ab)
        got = attend(q, k, v, pol, mask=shared)
        want = attend(q, k, v, pol, mask=full)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=ab or "xla")


def test_pinned_fused_prequant_equals_composed(base_cfg, params, prepared,
                                               images):
    """The one-jit serving hot path (int8 prequant projections + fused
    masked attention) is bit-identical to composing ``linear`` + ``attend``
    — through the full masked forward."""
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (2, N_PATCHES))
            > 0.5).astype(jnp.float32)
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                        attn_backend="flash")
    lg_fused, _ = forward_vit_masked(prepared, images, mask, cfg)
    # raw weights force the composed (non-fused) dispatch, same numbers
    lg_comp, _ = forward_vit_masked(params, images, mask, cfg)
    np.testing.assert_array_equal(np.asarray(lg_fused), np.asarray(lg_comp))


# --------------------------------------------------------------------------
# (d) fused int8 FFN vs the composed two-linear dispatch
# --------------------------------------------------------------------------

def _ffn_params(seed, d, dff, cache=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = {"w1": jax.random.normal(ks[0], (d, dff)) * 0.1,
         "b1": jax.random.normal(ks[1], (dff,)) * 0.1,
         "w2": jax.random.normal(ks[2], (dff, d)) * 0.1,
         "b2": jax.random.normal(ks[3], (d,)) * 0.1}
    if cache:
        p = {"w1": quantize_weight(p["w1"]), "b1": p["b1"],
             "w2": quantize_weight(p["w2"]), "b2": p["b2"]}
    return p


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(2, 48), st.sampled_from([16, 48, 64]),
       st.sampled_from([32, 96, 160]), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["bf16", "qat", "photonic_sim", "photonic_pallas"]))
def test_fuzz_fused_ffn_matches_composed(b, s, d, dff, seed, backend):
    """ffn_backend="fused" == ffn_backend="xla" bit-for-bit on every
    matmul backend: on photonic_pallas via the fused kernels, elsewhere
    via the documented auto-fallback to the composed dispatch."""
    p = _ffn_params(seed, d, dff, cache=backend.startswith("photonic"))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d))
    pol = dict(backend=backend, quant_bits=8, training=False)
    ref = ffn_mod.mlp(p, x, ExecPolicy(**pol))
    got = ffn_mod.mlp(p, x, ExecPolicy(**pol, ffn_backend="fused"))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                  err_msg=backend)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.integers(2, 40), st.integers(1, 40),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_fused_ffn_live_rows_packed_skip(b, s, live, seed):
    """The packed live_rows skip matches the composed dispatch on the live
    slice — bit-for-bit on the XLA twin (the bit-pinned lowering), to the
    one-quant-step kernel tolerance on the Pallas kernel (its body may FMA
    the dequant+bias chain; see kernels/fused_ffn.py "Parity contract") —
    and dead rows are exactly 0 on both."""
    live = min(live, s)
    p = _ffn_params(seed, 32, 64)
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, s, 32))
    ref = np.asarray(ffn_mod.mlp(p, x[:, :live],
                     ExecPolicy(backend="photonic_pallas", quant_bits=8,
                                training=False)))
    args = (p["w1"].wq, p["w1"].scale.reshape(-1), p["b1"],
            p["w2"].wq, p["w2"].scale.reshape(-1), p["b2"])
    twin = np.asarray(fused_ffn_xla(x, *args, live_rows=live))
    np.testing.assert_array_equal(twin[:, :live], ref, err_msg="xla-twin")
    assert (twin[:, live:] == 0).all()
    kern = np.asarray(fused_ffn_int8(x, *args, live_rows=live,
                                     interpret=True))
    np.testing.assert_allclose(kern[:, :live], ref, rtol=1e-2, atol=1e-2,
                               err_msg="pallas-interpret")
    assert (kern[:, live:] == 0).all()


# --------------------------------------------------------------------------
# (d) scanned fused encoder vs per-layer composed loop
# --------------------------------------------------------------------------

def _slice_layer(blocks, layer):
    def slc(w):
        if isinstance(w, QuantizedWeight):
            return QuantizedWeight(w.wq[layer], w.scale[layer],
                                   w.layer_bits(layer))
        return w[layer]
    return jax.tree_util.tree_map(
        slc, blocks, is_leaf=lambda w: isinstance(w, QuantizedWeight))


def _unrolled_encoder(params, tokens, cfg, policy, kv_len=None):
    """Per-layer python loop over manual layer slices — the composed
    dispatch the scanned single-jit encoder must match bit-for-bit."""
    b, _, d = tokens.shape
    cls = jnp.broadcast_to(params["cls"], (b, 1, d)) + params["pos"][:, :1]
    x = jnp.concatenate([cls.astype(tokens.dtype), tokens], axis=1)
    attn_kv = None if kv_len is None else int(kv_len) + 1
    for layer in range(cfg.n_layers):
        x = encoder_layer_step(x, _slice_layer(params["blocks"], layer),
                               cfg, policy, None, attn_kv, attn_kv)
    x = layernorm(x, params["final_ln_g"], params["final_ln_b"],
                  cfg.norm_eps)
    return linear(x[:, 0], params["head"], policy=policy)


FUSED_ENCODER_SEEDS = [0, 7, 23]          # pinned regression seeds


@pytest.mark.parametrize("seed", FUSED_ENCODER_SEEDS)
def test_pinned_scanned_encoder_equals_unrolled_loop(base_cfg, prepared,
                                                     seed):
    """The tentpole contract: the fully-fused scanned encoder (one cached
    jit, lax.scan over stacked QuantizedWeight layers) is bit-identical to
    an unrolled per-layer loop of the same composed steps under jit. The
    *eager* loop additionally agrees to float noise — jax.nn.gelu's tanh
    compiles differently as a standalone eager op than inside a jit
    (seed 7 pins a last-ulp divergence), which is an eager-context
    artifact, not a scan-vs-loop one."""
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend="flash", ffn_backend="fused")
    pol = ExecPolicy.from_cfg(cfg, training=False)
    toks = jax.random.normal(jax.random.PRNGKey(seed),
                             (2, N_PATCHES, cfg.d_model))
    lg_scan = encode_tokens(prepared, toks, cfg, pol)
    lg_loop_j = jax.jit(
        lambda p, t: _unrolled_encoder(p, t, cfg, pol))(prepared, toks)
    np.testing.assert_array_equal(np.asarray(lg_scan), np.asarray(lg_loop_j))
    lg_loop_e = _unrolled_encoder(prepared, toks, cfg, pol)
    np.testing.assert_allclose(np.asarray(lg_scan), np.asarray(lg_loop_e),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("seed", FUSED_ENCODER_SEEDS)
def test_pinned_fused_encoder_equals_composed_backends(base_cfg, params,
                                                       prepared, images,
                                                       seed):
    """ffn_backend="fused" == ffn_backend="xla" through the full encoder,
    per matmul backend (cached weights on photonic_pallas take the fused
    kernels; everything else exercises the fallback contract)."""
    mask = (jax.random.uniform(jax.random.PRNGKey(seed), (2, N_PATCHES))
            > 0.5).astype(jnp.float32)
    for backend, p in [("photonic_pallas", prepared), ("bf16", params),
                       ("photonic_sim", prepared)]:
        cfg_x = base_cfg.with_(matmul_backend=backend, quant_bits=8,
                               attn_backend="flash")
        cfg_f = cfg_x.with_(ffn_backend="fused")
        lg_x, _ = forward_vit_masked(p, images, mask, cfg_x)
        lg_f, _ = forward_vit_masked(p, images, mask, cfg_f)
        np.testing.assert_array_equal(np.asarray(lg_x), np.asarray(lg_f),
                                      err_msg=backend)


@pytest.mark.parametrize("k", [4, 8, 12])
def test_pinned_one_shape_fused_ffn_parity(base_cfg, prepared, images, k):
    """One-shape serving with the fused FFN: the packed kv_len prunes FFN
    rows, so on the w8a8 path the activation scale sets differ from the
    full-row composed dispatch — the same legitimate 8-bit noise class as
    masked-vs-gathered, held to the pinned-ladder tolerance (corr >
    0.999). The gathered-top-k reference uses identical live tokens."""
    cfg_f = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                           attn_backend="flash", ffn_backend="fused")
    cfg_x = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                           attn_backend="flash")
    scores = jax.random.normal(jax.random.PRNGKey(3), (2, N_PATCHES))
    order = jnp.argsort(scores, axis=-1, stable=True, descending=True)
    toks = embed_patches(prepared, images, cfg_f)
    permuted = jnp.take_along_axis(toks, order[:, :, None], axis=1)
    lg_f, kept = forward_vit_tokens(prepared, permuted, cfg_f, kv_len=k)
    assert kept == k
    lg_x, _ = forward_vit_tokens(prepared, permuted, cfg_x, kv_len=k)
    lg_g, _ = forward_vit_tokens(prepared, permuted[:, :k], cfg_f)
    a = np.asarray(lg_f, np.float32)
    for name, b in [("vs composed full-row", np.asarray(lg_x, np.float32)),
                    ("vs gathered top-k", np.asarray(lg_g, np.float32))]:
        assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999, name
        np.testing.assert_allclose(a, b, rtol=0.35, atol=0.35,
                                   err_msg=name)


# --------------------------------------------------------------------------
# (e) mixed-precision per-layer bit plans
# --------------------------------------------------------------------------

def _mixed_prepared(params, plan):
    return prepare_params(params, bits=8, bit_plan=plan)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([4, 6, 8]), st.sampled_from([4, 6, 8]),
       st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["photonic_pallas", "photonic_sim"]))
def test_fuzz_bit_plan_fused_matches_composed(base_cfg, params, images,
                                              b0, b1, seed, backend):
    """Generated per-layer 4/6/8 plans: ffn_backend="fused" ==
    ffn_backend="xla" bit-for-bit under the *same* plan, per matmul
    backend — on photonic_pallas through the mixed-width fused kernels
    (per-weight bits as static params), on photonic_sim through the
    documented auto-fallback. ``cfg.bit_plan`` marks the width divergence
    deliberate, so the stale-cache check stays out of the way."""
    plan = (b0, b1)
    prep = _mixed_prepared(params, plan)
    mask = (jax.random.uniform(jax.random.PRNGKey(seed), (2, N_PATCHES))
            > 0.5).astype(jnp.float32)
    cfg_x = base_cfg.with_(matmul_backend=backend, quant_bits=8,
                           attn_backend="flash", bit_plan=plan)
    cfg_f = cfg_x.with_(ffn_backend="fused")
    lg_x, _ = forward_vit_masked(prep, images, mask, cfg_x)
    lg_f, _ = forward_vit_masked(prep, images, mask, cfg_f)
    np.testing.assert_array_equal(np.asarray(lg_x), np.asarray(lg_f),
                                  err_msg=f"{backend} plan={plan}")


MIXED_PLANS = [(8, 4), (4, 8), (6, 6), (8, 6)]   # segment layouts: split,
#                                                  split, uniform-low, split


@pytest.mark.parametrize("plan", MIXED_PLANS)
@pytest.mark.parametrize("seed", FUSED_ENCODER_SEEDS)
def test_pinned_segmented_scan_equals_unrolled_loop_mixed(base_cfg, params,
                                                          plan, seed):
    """The mixed-plan tentpole contract: the segmented-scan encoder (one
    jit, one lax.scan per run of equal bit signature) is bit-identical to
    the jitted unrolled per-layer loop of composed steps at the same
    per-layer widths. Eager-loop agreement is float-noise only, for the
    same standalone-GELU codegen reason as the uniform pinned test."""
    prep = _mixed_prepared(params, plan)
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend="flash", ffn_backend="fused",
                         bit_plan=plan)
    pol = ExecPolicy.from_cfg(cfg, training=False)
    toks = jax.random.normal(jax.random.PRNGKey(seed),
                             (2, N_PATCHES, cfg.d_model))
    lg_scan = encode_tokens(prep, toks, cfg, pol)
    lg_loop_j = jax.jit(
        lambda p, t: _unrolled_encoder(p, t, cfg, pol))(prep, toks)
    np.testing.assert_array_equal(np.asarray(lg_scan),
                                  np.asarray(lg_loop_j),
                                  err_msg=f"plan={plan}")
    lg_loop_e = _unrolled_encoder(prep, toks, cfg, pol)
    np.testing.assert_allclose(np.asarray(lg_scan), np.asarray(lg_loop_e),
                               rtol=2e-5, atol=2e-5,
                               err_msg=f"plan={plan} (eager)")


def test_pinned_bit_segments_layout(base_cfg, params, prepared):
    """Segment boundaries fall exactly at bit-signature changes, and a
    uniform cache keeps the single-scan fast path (no slicing)."""
    from repro.models.vit import _bit_segments
    assert _bit_segments(prepared["blocks"], base_cfg.n_layers) == [(0, 2)]
    mixed = _mixed_prepared(params, (8, 4))
    assert _bit_segments(mixed["blocks"], base_cfg.n_layers) == \
        [(0, 1), (1, 2)]
    low = _mixed_prepared(params, (6, 6))    # uniform plan collapses
    assert _bit_segments(low["blocks"], base_cfg.n_layers) == [(0, 2)]


@pytest.mark.parametrize("plan", [(8, 4), (6, 8)])
def test_pinned_mixed_plan_masked_vs_gathered(base_cfg, params, images,
                                              plan):
    """The serving parity property survives a mixed plan: gathered top-k
    == masked dense on the fully-fused mixed-width hot path, to the same
    w8a8 tolerance class as the uniform contract."""
    prep = _mixed_prepared(params, plan)
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend="flash", ffn_backend="fused",
                         bit_plan=plan)
    _masked_vs_gathered(cfg, prep, images, k=8, seed=5)
