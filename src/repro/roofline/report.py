"""Roofline terms + report rows from analyzed dry-run artifacts.

Hardware constants (TPU v5e-class, per chip — pinned by the assignment):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per device — HLO shapes are already per-shard):
    compute    = hlo_flops / 197e12
    memory     = hlo_bytes / 819e9
    collective = wire_bytes / 50e9

MODEL_FLOPS (the "useful work" yardstick):
    train  : 6 * N * D     (fwd 2ND + bwd 4ND), N = params (active for MoE)
    prefill: 2 * N * D
    decode : 2 * N * B     (one token per sequence in the batch)
with D = tokens processed globally; reported per-device for the ratio
against per-device HLO FLOPs. ratio < 1 flags remat/redundant compute;
the gap is the re-computation + attention/vocab FLOPs the 6ND yardstick
ignores.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig
from repro.roofline.hlo_analysis import Cost

__all__ = ["HW", "roofline_terms", "model_flops", "make_row", "render_table"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # B/s / chip
    link_bw: float = 50e9               # B/s / link


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Global ideal FLOPs for one step of this cell."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token / seq


def roofline_terms(cost: Cost, cfg: ArchConfig, shape: ShapeConfig,
                   n_devices: int, hw: HW = HW()) -> dict:
    # int8 dots (the paper's w8a8 execution mode) run at 2x MXU peak
    t_c = ((cost.flops - cost.int8_flops) / hw.peak_flops
           + cost.int8_flops / (2.0 * hw.peak_flops))
    t_m = cost.bytes / hw.hbm_bw
    t_x = cost.coll_bytes / hw.link_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(cfg, shape) / n_devices
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": cost.flops,
        "useful_ratio": (mf / cost.flops) if cost.flops else 0.0,
        # fraction of roofline-limited time that is the useful-compute
        # floor: (mf/peak) / max-term — the score we hillclimb.
        "roofline_frac": (mf / hw.peak_flops) / bound if bound else 0.0,
        "step_s_lower_bound": bound,
    }


def make_row(arch: str, shape: str, mesh: str, cost: Cost, terms: dict,
             bytes_per_dev: float | None = None) -> dict:
    return {
        "arch": arch, "shape": shape, "mesh": mesh,
        "flops": cost.flops, "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes, "coll_by_op": cost.coll_by_op,
        "mem_per_dev_bytes": bytes_per_dev,
        **terms,
    }


_COLS = [
    ("arch", 22), ("shape", 12), ("compute_s", 11), ("memory_s", 11),
    ("collective_s", 13), ("dominant", 10), ("useful_ratio", 12),
    ("roofline_frac", 13),
]


def _fmt(v, w):
    if isinstance(v, float):
        s = f"{v:.4g}"
    else:
        s = str(v)
    return s.ljust(w)


def render_table(rows: list[dict]) -> str:
    head = "".join(_fmt(c, w) for c, w in _COLS)
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append("".join(_fmt(r.get(c, ""), w) for c, w in _COLS))
    return "\n".join(lines)
