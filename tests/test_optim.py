"""Optimizer + schedule tests (from-scratch AdamW/SGD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, sgd_init, sgd_update,
                               warmup_cosine)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_weight_decay_shrinks():
    cfg = AdamWConfig(lr=0.01, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    zero_g = {"w": jnp.zeros((4,))}
    params2, _ = adamw_update(zero_g, state, params, cfg)
    assert float(params2["w"][0]) < 1.0       # decay applies sans gradient


def test_low_mem_state_dtype():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(params, AdamWConfig(low_mem=True))
    assert st["m"]["w"].dtype == jnp.bfloat16
    st = adamw_init(params, AdamWConfig(low_mem=False))
    assert st["m"]["w"].dtype == jnp.float32


def test_lr_scale_applies():
    cfg = AdamWConfig(lr=1.0, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([1.0])}
    p_full, _ = adamw_update(g, adamw_init(params, cfg), params, cfg,
                             lr_scale=1.0)
    p_tenth, _ = adamw_update(g, adamw_init(params, cfg), params, cfg,
                              lr_scale=0.1)
    step_full = 1.0 - float(p_full["w"][0])
    step_tenth = 1.0 - float(p_tenth["w"][0])
    assert step_tenth == pytest.approx(0.1 * step_full, rel=1e-5)


def test_sgd_momentum():
    params = {"w": jnp.asarray([4.0])}
    state = sgd_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = sgd_update(g, state, params, lr=0.05)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, gn = clip_by_global_norm(g, max_norm=1.0)
    total = float(sum(jnp.sum(l ** 2)
                      for l in jax.tree_util.tree_leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-4)
    assert float(gn) == pytest.approx(np.sqrt(700.0), rel=1e-5)
    # no-op when already small
    g2 = {"a": jnp.asarray([0.1])}
    c2, _ = clip_by_global_norm(g2, max_norm=1.0)
    assert float(c2["a"][0]) == pytest.approx(0.1, rel=1e-6)


def test_warmup_cosine_shape():
    w = warmup_cosine(jnp.asarray(0), warmup=100, total=1000)
    assert float(w) == 0.0
    mid_warm = warmup_cosine(jnp.asarray(50), warmup=100, total=1000)
    assert float(mid_warm) == pytest.approx(0.5)
    peak = warmup_cosine(jnp.asarray(100), warmup=100, total=1000)
    assert float(peak) == pytest.approx(1.0, abs=1e-3)
    end = warmup_cosine(jnp.asarray(1000), warmup=100, total=1000,
                        floor=0.1)
    assert float(end) == pytest.approx(0.1, abs=1e-3)
    # monotone decay after warmup
    vals = [float(warmup_cosine(jnp.asarray(s), warmup=100, total=1000))
            for s in range(100, 1000, 100)]
    assert vals == sorted(vals, reverse=True)
