"""Serving control plane benchmark: calibration accuracy + autotune win.

Two gates over ``repro.serving.control``:

  1. **Calibration accuracy** (tiny-224, natural MGNet routing): run an
     autotuned server so every flush is timed, then cut the fit on the
     *first half* of the telemetry and score it on the *second half* —
     a strictly prequential split, no observation scores its own fit.
     Gate: median relative error <= 25%. The cost model's raw numbers are
     TPU-class roofline seconds and the host is not that machine; what the
     gate pins is that the fitted ``obs ~= a * pred + b`` map transfers,
     i.e. the HLO-derived FLOP/byte features *rank and scale* real flush
     walls well enough to steer knobs.

  2. **Autotune win** (tiny-96, 4 bursty streams, pinned 50% skip): the
     same uneven fleet served twice — a static-default server that warms
     the full jit ladder (the status quo deployment), and an autotuned
     server whose route probe compiles only reachable buckets (costing
     doubles as warm-up) and whose controller re-tunes the re-timing
     knobs online. Gate: autotuned aggregate fps >= 1.1x static, with fps
     charged end-to-end (warm/prepare wall included — startup cost is
     real cost). Predictions must stay per-stream bitwise identical: the
     control plane re-times, it never re-routes.

    PYTHONPATH=src python -m benchmarks.controller_bench           # gates
    PYTHONPATH=src python -m benchmarks.controller_bench --smoke   # fast
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

from repro.configs.opto_vit import get_config
from repro.data.pipeline import VideoStream, video_fleet
from repro.serving.control import Controller, FlushTelemetry, TunedKnobs
from repro.serving.server import ServerConfig, StreamServer
from repro.serving.session import ServingConfig

MEDRELERR_GATE = 0.25
SPEEDUP_GATE = 1.1
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _calibration_split(img: int, n_streams: int, frames: int) -> dict:
    """Prequential calibration score: fit on the first half of the timed
    flushes, evaluate on the second half."""
    print(f"  [1] calibration split: tiny-{img}, {n_streams} streams x "
          f"{frames} frames, natural routing")
    cfg = get_config("tiny", img_size=img, mgnet=True).with_(
        matmul_backend="bf16")
    sc = ServingConfig(microbatch=4, chunk=8)        # no pin: spread buckets
    srv = StreamServer(cfg, ServerConfig.from_serving(
        sc, warm_start=False, autotune=True), n_classes=10)
    for i in range(n_streams):
        srv.add_session(VideoStream(img_size=img, patch=16, cut_every=32),
                        n_frames=frames, start=32 * i)
    srv.autotune_prepare()
    srv.serve()

    obs = sorted(srv.telemetry, key=lambda o: o.seq)
    cut = len(obs) // 2
    train, test = obs[:cut], obs[cut:]
    replay = FlushTelemetry(window=max(1, len(train)))
    for o in train:
        replay.record(o.bucket, o.n_real, o.microbatch, o.n_streams,
                      o.wall_s, o.round)
    ctl = Controller(srv.cost_model, replay, TunedKnobs())
    assert ctl.calibrate(), "calibration needs at least one priced bucket"
    errs = [abs(ctl.predict_flush_s(o.bucket) - o.wall_s) / o.wall_s
            for o in test if o.wall_s > 0]
    med = statistics.median(errs) if errs else None
    a, b = ctl._fit
    med_s = f"{med:.1%}" if med is not None else "n/a"
    print(f"      {len(train)} fit obs -> obs = {a:.3g} * pred + {b:.3g}; "
          f"{len(errs)} held-out obs, medrelerr {med_s}")
    return {"fit_obs": len(train), "eval_obs": len(errs),
            "medrelerr": med, "fit_a": a, "fit_b": b,
            "buckets": sorted(srv.cost_model.costs)}


def _serve_fleet(srv: StreamServer, fleet, frames_per, prepare) -> dict:
    """Serve the bursty fleet on ``srv``; ``prepare`` pays the startup
    (warm or autotune) inside the charged wall."""
    t0 = time.time()
    sessions = [srv.add_session(st, n_frames=n, start=16 * i)
                for i, (st, n) in enumerate(zip(fleet, frames_per))]
    prepare(srv)
    prep_s = time.time() - t0
    results = srv.serve()
    serve_wall = results[sessions[0].sid].wall_s
    n_frames = sum(r.frames for r in results.values())
    wall = prep_s + serve_wall
    return {"results": {s.sid: results[s.sid] for s in sessions},
            "order": [s.sid for s in sessions],
            "prep_s": prep_s, "serve_wall_s": serve_wall,
            "fps": n_frames / wall, "frames": n_frames,
            "launches": len(srv.flush_log)}


def _autotune_win(img: int, n_streams: int, frames_per: tuple) -> dict:
    """Static-default all-warm server vs autotuned server on one bursty
    fleet (uneven frame budgets, phase-offset starts)."""
    print(f"  [2] autotune win: tiny-{img}, {n_streams} bursty streams "
          f"{list(frames_per)} frames, 50% skip")
    cfg = get_config("tiny", img_size=img, mgnet=True).with_(
        matmul_backend="bf16")
    sc = ServingConfig(microbatch=4, chunk=8, force_bucket=0.5)

    static = StreamServer(cfg, ServerConfig.from_serving(
        sc, warm_start=False), n_classes=10)
    st = _serve_fleet(static, video_fleet(n_streams, img_size=img, patch=16,
                                          cut_every=32), frames_per,
                      lambda s: s.warm_start())
    print(f"      static:    {st['frames']} frames, warm {st['prep_s']:.2f}s"
          f" + serve {st['serve_wall_s']:.2f}s -> {st['fps']:6.1f} fps "
          f"({st['launches']} launches, full ladder warmed)")

    auto = StreamServer(cfg, ServerConfig.from_serving(
        sc, warm_start=False, autotune=True, retune_every=16), n_classes=10)
    au = _serve_fleet(auto, video_fleet(n_streams, img_size=img, patch=16,
                                        cut_every=32), frames_per,
                      lambda s: s.autotune_prepare())
    ctl = auto.controller
    print(f"      autotuned: {au['frames']} frames, prep {au['prep_s']:.2f}s"
          f" + serve {au['serve_wall_s']:.2f}s -> {au['fps']:6.1f} fps "
          f"({au['launches']} launches, buckets "
          f"{sorted(auto.cost_model.costs)} priced+AOT)")
    print(f"      {ctl.report()}")

    # the control plane re-times flushes but never re-routes: per-stream
    # predictions are bitwise identical to the static-default server's
    for sid_s, sid_a in zip(st["order"], au["order"]):
        assert (st["results"][sid_s].predictions
                == au["results"][sid_a].predictions), (
            f"autotuning changed stream {sid_a}'s predictions")
    assert ctl.clamp_violations == 0, (
        f"applied knobs escaped the clamp box "
        f"{ctl.clamp_violations} times")

    speedup = au["fps"] / st["fps"]
    print(f"      -> {speedup:.2f}x aggregate fps (gate {SPEEDUP_GATE}x; "
          f"probe-trimmed compiles + tuned re-timing)")
    return {"static_fps": st["fps"], "autotuned_fps": au["fps"],
            "speedup": speedup,
            "static_prep_s": st["prep_s"], "autotune_prep_s": au["prep_s"],
            "retunes": ctl.applied_retunes,
            "knobs": {"max_wait_chunks": ctl.knobs.max_wait_chunks,
                      "interleave_depth": ctl.knobs.interleave_depth,
                      "flush_threshold": dict(ctl.knobs.flush_threshold)},
            "clamp_engaged": ctl.clamp_engaged,
            "clamp_violations": ctl.clamp_violations,
            "converged": ctl.converged}


def run(smoke: bool = False) -> dict:
    print("\n== serving control plane: calibrated cost model + autotuner ==")
    if smoke:
        calib = _calibration_split(img=64, n_streams=2, frames=24)
        win = _autotune_win(img=64, n_streams=2, frames_per=(24, 16))
    else:
        calib = _calibration_split(img=224, n_streams=2, frames=48)
        win = _autotune_win(img=96, n_streams=4,
                            frames_per=(60, 36, 48, 24))
    payload = {"calibration": calib, **win}

    if smoke:
        print("  (smoke mode: gates + BENCH json skipped)")
        return payload

    merged = {}
    if os.path.exists(OUT_JSON):           # shared perf-trajectory file
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["controller"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON}")

    assert calib["medrelerr"] is not None and (
        calib["medrelerr"] <= MEDRELERR_GATE), (
        f"calibrated cost model must predict held-out flush walls within "
        f"{MEDRELERR_GATE:.0%} median relative error; measured "
        f"{calib['medrelerr']:.1%}")
    assert win["speedup"] >= SPEEDUP_GATE, (
        f"autotuned serving must beat the static-default all-warm server "
        f"by >= {SPEEDUP_GATE}x aggregate fps; measured "
        f"{win['speedup']:.2f}x")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small-config validity run: no gates, no BENCH "
                         "json (the fast-CI configuration)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
