"""Sharding rules + context tests (single-device degenerate mesh; the
512-device production meshes are exercised by launch/dryrun.py only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import quant
from repro.core.backend import QuantizedWeight, place_params
from repro.distributed.collectives import (exact_int_psum,
                                           replicated_absmax_scale)
from repro.distributed.sharding import (DATA_RULES, DEFAULT_RULES,
                                        MODEL_RULES, MULTIPOD_RULES,
                                        ShardingCtx, current_ctx,
                                        logical_spec, named_sharding,
                                        rules_for_mesh, shard, use_sharding,
                                        validate_rules)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingCtx(mesh, DEFAULT_RULES)


def test_shard_noop_without_ctx():
    x = jnp.ones((4, 8))
    y = shard(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert current_ctx() is None


def test_ctx_installs_and_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert current_ctx() is None
    with use_sharding(mesh):
        assert current_ctx() is not None
        with use_sharding(None):
            assert current_ctx() is None
        assert current_ctx() is not None
    assert current_ctx() is None


def test_spec_mapping(ctx):
    assert ctx.spec("batch", "seq", "embed") == P("data", None, None)
    assert ctx.spec("batch", None, "mlp") == P("data", None, "model")
    assert ctx.spec("p_embed", "p_mlp") == P("data", "model")


def test_multipod_rules_add_pod_axis():
    assert MULTIPOD_RULES["batch"] == ("pod", "data")
    assert MULTIPOD_RULES["p_embed"] == ("pod", "data")
    assert MULTIPOD_RULES["p_mlp"] == "model"       # TP unchanged


def test_logical_spec_divisibility_fallback():
    """Rules whose axis size does not divide the dim drop to replicated —
    e.g. GQA kv_heads=8 on model=16, odd vocabs, batch=1 decode."""

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    fctx = ShardingCtx(FakeMesh(), DEFAULT_RULES)
    # 50280 % 16 != 0 -> vocab dim replicated
    spec = logical_spec((32, 50280), ("batch", "vocab"), fctx)
    assert spec == P("data", None)
    # batch=1 under data=16 -> replicated
    spec = logical_spec((1, 128), ("batch", "seq"), fctx)
    assert spec == P(None, None)
    # clean divisible case keeps both
    spec = logical_spec((32, 4096), ("batch", "mlp"), fctx)
    assert spec == P("data", "model")


def test_shard_applies_constraint_under_jit(ctx):
    with use_sharding(ctx.mesh, ctx.rules):
        @jax.jit
        def f(x):
            return shard(x, "batch", "embed") * 2

        y = f(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(y), 2.0)


def test_shard_rank_mismatch_raises(ctx):
    with use_sharding(ctx.mesh, ctx.rules):
        with pytest.raises(ValueError, match="rank"):
            shard(jnp.ones((4, 8)), "batch")


def test_named_sharding_roundtrip(ctx):
    ns = named_sharding((8, 16), ("batch", "mlp"), ctx)
    assert ns.spec == P("data", "model")


# ---- 2-D serving mesh: MODEL_RULES / rules_for_mesh / validate_rules ----


def test_model_rules_mapping():
    """MODEL_RULES shards heads/d_ff over "model", keeps embed replicated
    (no FSDP at inference — see the table's comment)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mctx = ShardingCtx(mesh, MODEL_RULES)
    assert mctx.spec("batch", "heads", None) == P("data", "model", None)
    assert mctx.spec("p_embed", "p_heads") == P(None, "model")
    assert mctx.spec("p_embed", "p_mlp") == P(None, "model")
    # embed dims replicate: the prepared int8 cache is small
    assert MODEL_RULES.get("p_embed") is None


def test_rules_for_mesh_selection():
    assert rules_for_mesh(None) is None
    assert rules_for_mesh(jax.make_mesh((1,), ("data",))) is DATA_RULES
    assert rules_for_mesh(
        jax.make_mesh((1, 1), ("data", "model"))) is MODEL_RULES
    assert rules_for_mesh(
        jax.make_mesh((1, 1, 1), ("pod", "data", "model"))) is MULTIPOD_RULES


def test_validate_rules_raises_on_unmapped_axis():
    """A size>1 mesh axis no rule uses would silently replicate everything
    — validate_rules turns that into a loud error. Size-1 axes are exempt."""

    class FakeMesh:
        shape = {"data": 2, "model": 2}
        axis_names = ("data", "model")

    with pytest.raises(ValueError, match="model"):
        validate_rules(FakeMesh(), DATA_RULES)
    validate_rules(FakeMesh(), MODEL_RULES)      # uses both axes: fine

    class DegenerateModel:
        shape = {"data": 2, "model": 1}
        axis_names = ("data", "model")

    validate_rules(DegenerateModel(), DATA_RULES)    # size-1 exempt


def test_use_sharding_validates_explicit_rules():
    class FakeMesh:
        shape = {"data": 2, "model": 2}
        axis_names = ("data", "model")

    with pytest.raises(ValueError, match="model"):
        with use_sharding(FakeMesh(), DATA_RULES):
            pass


def test_place_params_pins_quantized_weights():
    """place_params puts QuantizedWeight codes *and* scales under the
    logical-axis sharding; the scale's size-1 contraction dim falls back
    to replicated so per-out-channel scales follow their columns."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mctx = ShardingCtx(mesh, MODEL_RULES)
    params = {
        "wq": QuantizedWeight(jnp.zeros((8, 16), jnp.int8),
                              jnp.zeros((1, 16), jnp.float32), 8),
        "ln": jnp.ones((8,)),
    }
    axes = {"wq": ("p_embed", "p_heads"), "ln": (None,)}
    placed = place_params(params, axes, mctx)
    assert placed["wq"].wq.sharding.spec == P(None, "model")
    assert placed["wq"].scale.sharding.spec == P(None, "model")
    assert placed["ln"].sharding.is_fully_replicated
    assert placed["wq"].bits == 8


# ---- exact collectives (distributed/collectives.py) ----


def test_replicated_absmax_scale_bitwise_matches_unsharded():
    """Inside shard_map on a degenerate mesh the pmax is an identity, so
    the result must equal core.quant.absmax_scale bit for bit — the op
    order (max -> pmax -> eps clamp -> reciprocal-multiply) is the whole
    contract."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32), jnp.float32)
    ref = quant.absmax_scale(x, bits=8)
    got = shard_map(
        lambda t: replicated_absmax_scale(t, 8, ("data", "model")),
        mesh=mesh, in_specs=P(None, None), out_specs=P(),
        check_rep=False)(x)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_exact_int_psum_rejects_float():
    with pytest.raises(TypeError, match="integer"):
        exact_int_psum(jnp.ones((4,), jnp.float32), "model")


def test_exact_int_psum_identity_on_degenerate_axis():
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(8, dtype=jnp.int32)
    got = shard_map(lambda t: exact_int_psum(t, "model"), mesh=mesh,
                    in_specs=P(None), out_specs=P(None),
                    check_rep=False)(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
