"""Blockwise/flash (XLA) attention vs dense reference; decode paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blockwise_attention, decode_attention,
                                    full_attention, update_kv_cache)


def _qkv(key, b, sq, skv, h, hkv, d):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (b, sq, h, d)),
            jax.random.normal(k2, (b, skv, hkv, d)),
            jax.random.normal(k3, (b, skv, hkv, d)))


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
def test_blockwise_matches_full(h, hkv):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 256, 256, h, hkv, 16)
    blk = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ful = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ful),
                               rtol=2e-5, atol=2e-5)


def test_block_skip_equivalence():
    """causal_block_skip (lax.cond over masked blocks) is numerically
    identical to the plain scan — it only skips blocks that contribute
    nothing."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 256, 4, 2, 16)
    a = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            block_skip=False)
    b = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            block_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_window_attention(window):
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 256, 256, 2, 2, 16)
    blk = blockwise_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_kv=64)
    ful = full_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ful),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_attention_row():
    """decode_attention(q_t, cache) == row t of full causal attention."""
    b, s, h, hkv, d = 2, 32, 4, 2, 16
    q_all, k_all, v_all = _qkv(jax.random.PRNGKey(3), b, s, s, h, hkv, d)
    full = full_attention(q_all, k_all, v_all, causal=True)
    t = 17
    out = decode_attention(q_all[:, t:t + 1], k_all, v_all, length=t + 1)
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, t]),
                               rtol=2e-5, atol=2e-5)


def test_update_kv_cache_writes_at_pos():
    k_cache = jnp.zeros((1, 8, 2, 4))
    v_cache = jnp.zeros((1, 8, 2, 4))
    k_new = jnp.ones((1, 1, 2, 4))
    v_new = 2 * jnp.ones((1, 1, 2, 4))
    k2, v2 = update_kv_cache(k_cache, v_cache, k_new, v_new, 3)
    assert float(k2[0, 3].sum()) == 8.0
    assert float(k2[0, 2].sum()) == 0.0
    assert float(v2[0, 3, 0, 0]) == 2.0


def test_incremental_decode_equals_prefill():
    """Token-by-token decode over a growing cache reproduces the full
    causal attention output at every position."""
    b, s, h, hkv, d = 1, 16, 2, 1, 8
    q_all, k_all, v_all = _qkv(jax.random.PRNGKey(4), b, s, s, h, hkv, d)
    full = full_attention(q_all, k_all, v_all, causal=True)
    k_cache = jnp.zeros((b, s, hkv, d))
    v_cache = jnp.zeros((b, s, hkv, d))
    for t in range(s):
        k_cache, v_cache = update_kv_cache(
            k_cache, v_cache, k_all[:, t:t + 1], v_all[:, t:t + 1], t)
        out = decode_attention(q_all[:, t:t + 1], k_cache, v_cache,
                               length=t + 1)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-5, atol=2e-5)
