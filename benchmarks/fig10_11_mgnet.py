"""Paper Figs. 10/11: MGNet RoI selection energy + latency savings.

Baseline ViT-Base processing all patches vs MGNet-pruned processing (the
MGNet's own cost included). The paper reports up to 84% energy savings at
~66-68% pixel skip; savings scale with the skip ratio."""

from __future__ import annotations

from benchmarks.common import frame_report


def run() -> list[dict]:
    rows = []
    print("\n== Figs. 10/11: MGNet RoI savings ==")
    for variant, img in (("base", 96), ("base", 224), ("tiny", 224)):
        n_patches = (img // 16) ** 2
        base = frame_report(variant, img)
        print(f"\n{variant} {img}x{img} ({n_patches} patches); "
              f"baseline E={base.total_uj:.1f}uJ t={base.total_us:.1f}us")
        for skip in (0.33, 0.5, 0.67, 0.85):
            kept = max(1, int(round((1 - skip) * n_patches)))
            masked = frame_report(variant, img, kept_patches=kept,
                                  include_mgnet=True)
            e_sav = 1 - masked.total_uj / base.total_uj
            t_sav = 1 - masked.total_us / base.total_us
            rows.append({"variant": variant, "img": img, "skip": skip,
                         "kept": kept, "energy_uj": masked.total_uj,
                         "latency_us": masked.total_us,
                         "energy_saving": e_sav, "latency_saving": t_sav})
            print(f"  skip={skip:.0%} kept={kept:3d}  "
                  f"E={masked.total_uj:8.1f}uJ (save {e_sav:5.1%})   "
                  f"t={masked.total_us:7.1f}us (save {t_sav:5.1%})")

    # paper claims: saving grows with skip ratio; MGNet overhead is small;
    # large inputs save more (more patches to skip). The residual gap to
    # the paper's best-case 84% is the M-independent weight-tuning/SRAM
    # cost (per-frame MR re-tuning does not shrink with pruned patches) +
    # the sensor-interface savings the paper also counts — see DESIGN.md.
    for variant, img in (("base", 96), ("base", 224), ("tiny", 224)):
        sub = [r for r in rows if r["img"] == img
               and r["variant"] == variant]
        sav = [r["energy_saving"] for r in sub]
        assert sav == sorted(sav), "saving must grow with skip ratio"
    best = max(rows, key=lambda r: r["energy_saving"])
    print(f"\nbest case: {best['variant']}-{best['img']} "
          f"@{best['skip']:.0%} skip -> {best['energy_saving']:.1%} energy "
          f"saving (paper: 'up to 84%' incl. sensor-interface savings)")
    assert best["energy_saving"] > 0.6, best
    # 224 saves more than 96 at equal skip (paper Fig. 10 trend)
    b96 = [r for r in rows if r["img"] == 96 and r["skip"] == 0.67][0]
    b224 = [r for r in rows if r["img"] == 224 and r["skip"] == 0.67
            and r["variant"] == "base"][0]
    assert b224["energy_saving"] > b96["energy_saving"]
    return rows
