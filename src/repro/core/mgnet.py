"""MGNet: lightweight region-of-interest Mask Generation Network.

Paper §IV "Region of Interest Selection": a single transformer block followed
by a self-attention scoring layer and a linear projection. For each frame:

  1. patchify + embed (patch p=16, embed L=192, 3 heads; the detection
     variant uses 384/6),
  2. one transformer encoder block over [cls] + patch tokens,
  3. attention score  S_cls_attn = q_cls . K^T / sqrt(d)   (Eq. 3),
  4. linear head -> per-patch region scores S_region,
  5. sigmoid + threshold t_reg -> binary patch mask,
  6. trained with BCE against box-derived {0,1} patch labels;
     mask quality measured by mIoU.

Masked patches are dropped *before* the first backbone encoder block. Since a
ViT never mixes patches spatially outside attention, every downstream FLOP of
a dropped patch is saved (linear savings — the paper's key observation).

JIT-compatibility: dynamic patch counts don't trace, so the backbone-facing
API offers two modes:
  * ``mask``   — multiplicative binary masking (shapes static; compute not
    reduced, used for training/accuracy studies),
  * ``topk``   — keep a fixed budget of the k highest-scoring patches
    (shapes static at k; compute *is* reduced; k = ceil((1-skip)*n)).
The hardware energy model consumes the true expected skip ratio either way.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import ExecPolicy, linear

__all__ = ["MGNetConfig", "init_mgnet", "mgnet_logical_axes", "mgnet_scores",
           "mgnet_mask", "select_topk_patches", "mask_iou", "bce_loss",
           "mask_budget", "frame_delta"]


@dataclass(frozen=True)
class MGNetConfig:
    patch: int = 16
    embed: int = 192        # 384 for the detection variant
    heads: int = 3          # 6 for the detection variant
    mlp_ratio: float = 4.0
    t_reg: float = 0.5      # sigmoid threshold for the binary mask
    img_size: int = 96

    @property
    def n_patches(self) -> int:
        return (self.img_size // self.patch) ** 2


def _dense_init(key, shape, scale=None):
    scale = scale or (1.0 / jnp.sqrt(shape[0]))
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_mgnet(key: jax.Array, cfg: MGNetConfig) -> dict:
    """Parameter pytree for MGNet (patch-embed + 1 block + score head)."""
    d = cfg.embed
    n_in = 3 * cfg.patch * cfg.patch
    ks = jax.random.split(key, 12)
    return {
        "patch_embed": {"w": _dense_init(ks[0], (n_in, d)), "b": jnp.zeros((d,))},
        "cls_token": jax.random.normal(ks[1], (1, 1, d)) * 0.02,
        "pos_embed": jax.random.normal(ks[2], (1, cfg.n_patches + 1, d)) * 0.02,
        "block": {
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "wqkv": _dense_init(ks[3], (d, 3 * d)),
            "wo": _dense_init(ks[4], (d, d)),
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "w1": _dense_init(ks[5], (d, int(d * cfg.mlp_ratio))),
            "b1": jnp.zeros((int(d * cfg.mlp_ratio),)),
            "w2": _dense_init(ks[6], (int(d * cfg.mlp_ratio), d)),
            "b2": jnp.zeros((d,)),
        },
        # scoring attention (Eq. 3) + linear region head
        "score": {
            "wq": _dense_init(ks[7], (d, d)),
            "wk": _dense_init(ks[8], (d, d)),
            "head_w": _dense_init(ks[9], (cfg.n_patches, cfg.n_patches)),
            "head_b": jnp.zeros((cfg.n_patches,)),
        },
    }


def mgnet_logical_axes() -> dict:
    """Replicated (all-None) sharding-axis tree structurally matching
    ``init_mgnet``'s params — MGNet is tiny, so it is never partitioned, but
    the axis tree must still mirror the param pytree for the annotation
    machinery (models/vit.py::vit_logical_axes)."""
    return {
        "patch_embed": {"w": (None, None), "b": (None,)},
        "cls_token": (None, None, None),
        "pos_embed": (None, None, None),
        "block": {
            "ln1": {"g": (None,), "b": (None,)},
            "wqkv": (None, None),
            "wo": (None, None),
            "ln2": {"g": (None,), "b": (None,)},
            "w1": (None, None), "b1": (None,),
            "w2": (None, None), "b2": (None,),
        },
        "score": {"wq": (None, None), "wk": (None, None),
                  "head_w": (None, None), "head_b": (None,)},
    }


def _ln(x, p, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _mhsa(x, wqkv, wo, heads, policy=None):
    b, n, d = x.shape
    qkv = linear(x, wqkv, policy=policy)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    dh = d // heads
    q = q.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, n, heads, dh).transpose(0, 2, 1, 3)
    att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(dh), axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(b, n, d)
    return linear(o, wo, policy=policy)


def patchify(images: jnp.ndarray, patch: int) -> jnp.ndarray:
    """(B, H, W, C) -> (B, n_patches, patch*patch*C)."""
    b, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch * patch * c)


def mgnet_scores(params: dict, images: jnp.ndarray, cfg: MGNetConfig,
                 policy: ExecPolicy | None = None) -> jnp.ndarray:
    """Per-patch region scores S_region (pre-sigmoid logits), shape (B, N).

    Every weight matmul routes through the shared ``linear`` backend
    dispatch — on the paper's hardware MGNet runs on the same optical cores
    as the backbone, so it executes under the same policy (photonic w8a8 at
    serve time). Only the q.K^T and att.V activation matmuls stay in float.
    """
    x = linear(patchify(images, cfg.patch), params["patch_embed"]["w"],
               params["patch_embed"]["b"], policy)
    b, n, d = x.shape
    cls = jnp.broadcast_to(params["cls_token"], (b, 1, d))
    x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"][:, : n + 1]

    blk = params["block"]
    x = x + _mhsa(_ln(x, blk["ln1"]), blk["wqkv"], blk["wo"], cfg.heads,
                  policy)
    h = linear(_ln(x, blk["ln2"]), blk["w1"], blk["b1"], policy)
    x = x + linear(jax.nn.gelu(h), blk["w2"], blk["b2"], policy)

    # Eq. 3: S_cls_attn = q_cls . K^T / sqrt(d) over patch tokens.
    q_cls = linear(x[:, :1], params["score"]["wq"], policy=policy)  # (B,1,d)
    k_pat = linear(x[:, 1:], params["score"]["wk"], policy=policy)  # (B,N,d)
    s_cls = (q_cls @ k_pat.transpose(0, 2, 1))[:, 0] / jnp.sqrt(d)  # (B, N)
    # linear layer with output dim = n_patches -> S_region
    return linear(s_cls, params["score"]["head_w"],
                  params["score"]["head_b"], policy)


def mgnet_mask(params: dict, images: jnp.ndarray, cfg: MGNetConfig,
               policy: ExecPolicy | None = None) -> jnp.ndarray:
    """Binary patch mask (B, N) in {0., 1.}: sigmoid(S_region) > t_reg."""
    s = jax.nn.sigmoid(mgnet_scores(params, images, cfg, policy))
    return (s > cfg.t_reg).astype(jnp.float32)


def select_topk_patches(scores: jnp.ndarray, tokens: jnp.ndarray, keep: int):
    """Static-shape RoI pruning: keep the ``keep`` highest-scoring patches.

    scores: (B, N) region logits; tokens: (B, N, D) patch embeddings.
    Returns (pruned_tokens (B, keep, D), kept_idx (B, keep)).

    Tie-breaking is deterministic: among equal scores the lowest patch index
    wins (stable descending argsort rather than ``lax.top_k``, whose tie
    order is backend-defined). The serving bucket router keys on the kept
    set, so reproducible routing requires reproducible selection.
    """
    idx = jnp.argsort(scores, axis=-1, stable=True, descending=True)
    idx = idx[..., :keep]
    pruned = jnp.take_along_axis(tokens, idx[..., None], axis=1)
    return pruned, idx


def mask_budget(scores, t_reg: float = 0.5):
    """Per-frame kept-patch count implied by the binary mask, shape (B,).

    This is the *token budget* a frame requests from the serving bucket
    ladder: the number of patches whose sigmoid score clears ``t_reg``.
    Accepts numpy or jax scores and stays in that domain — the serving
    engine's routing decision runs on host-resident cached scores, and a
    device round-trip per chunk would cost more than the count itself.
    """
    if isinstance(scores, np.ndarray):
        keep = 1.0 / (1.0 + np.exp(-scores.astype(np.float64))) > t_reg
        return keep.sum(axis=-1).astype(np.int32)
    return (jax.nn.sigmoid(scores) > t_reg).sum(axis=-1).astype(jnp.int32)


def frame_delta(frames, ref):
    """Cheap per-frame change signal vs a reference frame, shape (B,).

    Mean absolute pixel difference — the near-sensor trigger for re-running
    MGNet: below a threshold the cached RoI mask is reused (static scene),
    above it (motion / scene cut) the frame is re-scored. O(HW) adds per
    frame, i.e. negligible next to even one MGNet patch-embed matmul.
    Numpy in, numpy out (host-side gating walk); jax in, jax out.
    """
    xp = np if isinstance(frames, np.ndarray) else jnp
    d = xp.abs(frames.astype(xp.float32) - ref.astype(xp.float32))
    return d.mean(axis=tuple(range(1, frames.ndim)))


def mask_iou(pred: jnp.ndarray, gt: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """mIoU between binary masks (B, N) — the paper's mask quality metric."""
    inter = jnp.sum(pred * gt, axis=-1)
    union = jnp.sum(jnp.clip(pred + gt, 0, 1), axis=-1)
    return jnp.mean(inter / (union + eps))


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Binary cross-entropy on region scores vs box-derived labels."""
    log_p = jax.nn.log_sigmoid(logits)
    log_not_p = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(labels * log_p + (1.0 - labels) * log_not_p)
