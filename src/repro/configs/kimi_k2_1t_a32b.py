"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert,
vocab=163840, 384 experts top-8 + 1 shared, first layer dense
(arXiv:2501.kimi2 paper-table). ~1T total / ~32B active params.
Memory: at 1T params a single 256x16GB pod cannot hold params+grads+opt
(8TB at bf16+bf16 AdamW) — the dry-run memory table documents this; the
multi-pod mesh with bf16 optimizer state is the supported configuration."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, kv_heads=8,
        d_ff=2048, vocab=163840,
        n_experts=384, top_k=8, shared_experts=1, first_dense_layers=1,
        capacity_factor=1.25, moe_groups=16,
        rope_theta=50000.0,
        microbatch_steps=8,
        use_fp32_master=False,
    )
