"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; gated image cross-attention every 5th layer (20 of 100);
vision tower STUB supplies patch embeddings (hf:meta-llama/Llama-3.2).
MGNet RoI pruning applies naturally here (mgnet flag prunes image
tokens before cross-attn K/V)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, kv_heads=8,
        d_ff=28672, vocab=128256,
        rope_theta=500000.0,
        cross_every=5, n_img_tokens=1601, d_frontend=1280,
        microbatch_steps=4,
    )
