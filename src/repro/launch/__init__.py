"""Launch layer: production meshes, jit step builders, dry-run, drivers."""
