"""Mamba-2 SSD tests: chunked dual form vs naive recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.ssm import (init_ssd, ssd_decode_step, ssd_forward,
                              ssd_state_shape)


def _cfg(chunk=8):
    return ArchConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=4, kv_heads=4, d_ff=0, vocab=64,
                      ssm_state=8, ssm_headdim=8, ssm_expand=2,
                      conv_kernel=4, ssm_chunk=chunk, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_ssd(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    return cfg, params, x


def test_chunk_size_invariance(setup):
    """The SSD chunked algorithm must give the same output for any chunk
    size (it's an exact reformulation, not an approximation)."""
    _, params, x = setup
    outs = []
    for chunk in (4, 8, 16, 32):
        cfg = _cfg(chunk)
        y, st = ssd_forward(params, x, cfg)
        outs.append(np.asarray(y, np.float32))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


def test_forward_vs_stepwise_decode(setup):
    """Running the token-by-token recurrence must reproduce the chunked
    full-sequence output (state-space duality, Dao & Gu)."""
    cfg, params, x = setup
    y_full, final = ssd_forward(params, x, cfg)

    b = x.shape[0]
    st = ssd_state_shape(cfg, b)
    state = {"h": jnp.zeros(st["h"], jnp.float32),
             "conv": jnp.zeros(st["conv"], jnp.float32)}
    ys = []
    for t in range(x.shape[1]):
        y_t, state = ssd_decode_step(params, x[:, t:t + 1], state, cfg)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=3e-3, atol=3e-3)
    # final chunked state == final stepwise state
    np.testing.assert_allclose(np.asarray(final["h"]),
                               np.asarray(state["h"]),
                               rtol=3e-3, atol=3e-3)


def test_state_handoff(setup):
    """forward(x[:, :16]) then forward(x[:, 16:], initial_state) ==
    forward(x) — prefill-to-decode (and sequence-parallel) handoff."""
    cfg, params, x = setup
    y_full, _ = ssd_forward(params, x, cfg)
    y1, st1 = ssd_forward(params, x[:, :16], cfg)
    y2, _ = ssd_forward(params, x[:, 16:], cfg, initial_state=st1)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_cat, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=3e-3, atol=3e-3)


def test_decay_masks_future(setup):
    """Causality: y[:, :t] must not depend on x[:, t:]."""
    cfg, params, x = setup
    y1, _ = ssd_forward(params, x, cfg)
    x2 = x.at[:, 20:].set(jax.random.normal(jax.random.PRNGKey(9),
                                            x[:, 20:].shape))
    y2, _ = ssd_forward(params, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :17]),
                               np.asarray(y2[:, :17]), rtol=1e-4, atol=1e-4)
