"""Decoder-only LM assembly for all families (dense / moe / ssm / hybrid).

Layer stacking follows the MaxText pattern: per-layer params are stacked on
a leading axis and the layer loop is a ``jax.lax.scan`` (optionally with
per-layer ``jax.checkpoint`` remat), so HLO size and compile time are O(1)
in depth — a 126-layer 405B model lowers on this host.

Heterogeneous stacks (hybrid RG-LRU 2:1 local-attention, MoE with leading
dense layers) scan over *super-blocks* of the repeating pattern, with any
remainder layers unrolled.

Decode carries a per-family cache pytree whose leaves are stacked on the
same leading layer axis; the layer scan threads cache slices as xs/ys.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (blockwise_attention, decode_attention,
                                    full_attention, update_kv_cache)
from repro.models.layers import (ExecPolicy, apply_rope, embedding_lookup,
                                 he_init, linear, rmsnorm, rope)

__all__ = ["init_lm", "lm_logical_axes", "forward_lm", "lm_loss",
           "cache_spec", "decode_step"]


# --------------------------------------------------------------------------
# attention sub-block
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": he_init(ks[0], (d, h * hd), dtype),
         "wk": he_init(ks[1], (d, hkv * hd), dtype),
         "wv": he_init(ks[2], (d, hkv * hd), dtype),
         "wo": he_init(ks[3], (h * hd, d), dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attention_logical_axes(cfg: ArchConfig) -> dict:
    ax = {"wq": ("p_embed", "p_heads"), "wk": ("p_embed", None),
          "wv": ("p_embed", None), "wo": ("p_heads", "p_embed")}
    if cfg.qkv_bias:
        ax.update({"bq": ("p_heads",), "bk": (None,), "bv": (None,)})
    return ax


def _project_qkv(p, x, cfg, policy, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq"), policy).reshape(b, s, h, hd)
    k = linear(x, p["wk"], p.get("bk"), policy).reshape(b, s, hkv, hd)
    v = linear(x, p["wv"], p.get("bv"), policy).reshape(b, s, hkv, hd)
    cos, sin = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    return q, k, v


def attn_forward(p, x, cfg: ArchConfig, policy, *, window=0):
    """Full-sequence self attention (train/prefill)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, policy, positions)
    if cfg.attn_impl == "decomposed":
        o = _decomposed_attn(p, x, q, v, cfg)
    else:
        o = blockwise_attention(
            q, k, v, causal=True, window=window,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            block_skip=cfg.causal_block_skip, p_bf16=cfg.attn_p_bf16,
            qk_bf16=cfg.attn_qk_bf16)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return linear(o, p["wo"], policy=policy), (k, v)


def _decomposed_attn(p, x, q, v, cfg):
    """Paper Eq. 2 dataflow: scores_h = (Q_h W_K,h^T / sqrt(dh)) X^T.

    RoPE is skipped in this mode (the decomposition requires scores be a
    bilinear form in the *raw* X; the paper's ViT has no RoPE). Intended for
    ViT-scale models; memory grows with H*d_model."""
    b, s, h, hd = q.shape
    d = x.shape[-1]
    hkv = cfg.kv_heads
    g = h // hkv
    wk = p["wk"]
    if hasattr(wk, "dequantize"):      # cached weight: re-tune W_K^T raw
        wk = wk.dequantize()
    wk = wk.reshape(d, hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    # re-project q without rope: Eq.2 path recomputes raw Q
    q_raw = linear(x, p["wq"], p.get("bq")).reshape(b, s, hkv, g, hd)
    qwk = jnp.einsum("bshgk,dhk->bshgd", q_raw.astype(jnp.float32),
                     wk.astype(jnp.float32)) * scale
    scores = jnp.einsum("bshgd,btd->bhgst", qwk, x.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    pattn = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthk->bshgk", pattn, v.astype(jnp.float32))
    return o.reshape(b, s, h, hd).astype(x.dtype)


def attn_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig, policy,
                *, window=0):
    """One-token attention; returns (out, new_k, new_v)."""
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq"), policy).reshape(b, 1, h, hd)
    k = linear(x, p["wk"], p.get("bk"), policy).reshape(b, 1, hkv, hd)
    v = linear(x, p["wv"], p.get("bv"), policy).reshape(b, 1, hkv, hd)
    posv = jnp.asarray(pos)[None]
    cos, sin = rope(posv, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if window > 0 and cache_k.shape[1] <= window:
        # ring-buffer local cache: slot = pos mod window
        slot = jnp.mod(pos, cache_k.shape[1])
        cache_k, cache_v = update_kv_cache(cache_k, cache_v, k, v, slot)
        o = _ring_decode_attention(q, cache_k, cache_v, pos, window)
    else:
        cache_k, cache_v = update_kv_cache(cache_k, cache_v, k, v, pos)
        o = decode_attention(q, cache_k, cache_v, pos + 1, window=window,
                             bf16_compute=cfg.decode_attn_bf16)
    o = o.reshape(b, 1, h * hd)
    return linear(o, p["wo"], policy=policy), cache_k, cache_v


def _ring_decode_attention(q, k_cache, v_cache, pos, window):
    """Decode over a ring-buffer window cache. Slot s holds absolute
    position p with p mod W == s and p <= pos; valid iff p > pos - W,
    i.e. every slot is valid once pos >= W - 1."""
    b, _, h, hd = q.shape
    w = k_cache.shape[1]
    slots = jnp.arange(w)
    # absolute position currently stored in each slot
    cur = jnp.mod(pos, w)
    abs_pos = jnp.where(slots <= cur, pos - cur + slots, pos - cur + slots - w)
    valid = abs_pos >= 0
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.reshape(b, hkv, g, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    s = jnp.where(valid[None, None, None], s, -1e30)
    m = s.max(-1, keepdims=True)
    p_ = jnp.exp(s - m)
    o = jnp.einsum("bhgs,bshd->bhgd", p_, v_cache.astype(jnp.float32))
    o = o / p_.sum(-1, keepdims=True)[..., 0, None]
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# per-family layer blocks (pre-norm residual)
# --------------------------------------------------------------------------

def init_dense_layer(key, cfg, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ffn": ffn_mod.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)}


def dense_layer_axes(cfg):
    return {"ln1": (None,), "attn": attention_logical_axes(cfg),
            "ln2": (None,), "ffn": ffn_mod.swiglu_logical_axes()}


def dense_layer_fwd(p, x, cfg, policy, window=0):
    h, _ = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                        cfg, policy, window=window)
    x = x + h
    x = x + ffn_mod.swiglu(p["ffn"], rmsnorm(x, p["ln2"], cfg.norm_eps), policy)
    return shard(x, "batch", "seq", "embed")


def init_moe_layer(key, cfg, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "moe": moe_mod.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                    cfg.shared_experts, dtype)}


def moe_layer_axes(cfg):
    return {"ln1": (None,), "attn": attention_logical_axes(cfg),
            "ln2": (None,), "moe": moe_mod.moe_logical_axes(cfg.shared_experts)}


def moe_layer_fwd(p, x, cfg, policy):
    h, _ = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                        cfg, policy)
    x = x + h
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe_impl == "shard_map":
        y, aux = moe_mod.moe_ffn_shard_map(
            p["moe"], h2, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, policy=policy)
    else:
        y, aux = moe_mod.moe_ffn(p["moe"], h2,
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 groups=cfg.moe_groups, policy=policy,
                                 local_combine=cfg.moe_local_combine)
    return shard(x + y, "batch", "seq", "embed"), aux


def init_ssm_layer(key, cfg, dtype=jnp.bfloat16):
    return {"ln": jnp.ones((cfg.d_model,), dtype),
            "ssd": ssm_mod.init_ssd(key, cfg, dtype)}


def ssm_layer_axes(cfg):
    return {"ln": (None,), "ssd": ssm_mod.ssd_logical_axes(cfg)}


def init_rec_layer(key, cfg, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "rec": rglru_mod.init_rglru(k1, cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "ffn": ffn_mod.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)}


def rec_layer_axes(cfg):
    return {"ln1": (None,), "rec": rglru_mod.rglru_logical_axes(cfg),
            "ln2": (None,), "ffn": ffn_mod.swiglu_logical_axes()}


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------

def _stack_init(key, n, init_fn):
    """vmap an init over n layers -> stacked leaves with leading n axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_lm(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "final_ln": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(ks[1], (d, cfg.vocab), dtype)

    fam = cfg.family
    if fam == "dense":
        params["blocks"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: init_dense_layer(k, cfg, dtype))
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["dense_blocks"] = _stack_init(
                ks[3], nd, lambda k: init_dense_layer(k, cfg, dtype))
        params["blocks"] = _stack_init(
            ks[2], cfg.n_layers - nd, lambda k: init_moe_layer(k, cfg, dtype))
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: init_ssm_layer(k, cfg, dtype))
    elif fam == "hybrid":
        nsb = cfg.n_layers // 3          # super-block = (rec, rec, attn)
        rem = cfg.n_layers - 3 * nsb
        params["blocks"] = _stack_init(
            ks[2], nsb,
            lambda k: {
                "rec0": init_rec_layer(jax.random.fold_in(k, 0), cfg, dtype),
                "rec1": init_rec_layer(jax.random.fold_in(k, 1), cfg, dtype),
                "attn": init_dense_layer(jax.random.fold_in(k, 2), cfg, dtype),
            })
        if rem:
            params["tail_blocks"] = _stack_init(
                ks[3], rem, lambda k: init_rec_layer(k, cfg, dtype))
    else:
        raise ValueError(f"init_lm does not handle family {fam}")
    return params


def _tree_prepend_axis(tree, axis_name="p_layers"):
    return jax.tree_util.tree_map(lambda ax: (axis_name,) + tuple(ax), tree,
                                  is_leaf=lambda t: isinstance(t, tuple))


def lm_logical_axes(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    ax: dict[str, Any] = {"embed": ("p_vocab", "p_embed"),
                          "final_ln": (None,)}
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("p_embed", "p_vocab")
    fam = cfg.family
    if fam == "dense":
        ax["blocks"] = _tree_prepend_axis(dense_layer_axes(cfg))
    elif fam == "moe":
        if cfg.first_dense_layers:
            ax["dense_blocks"] = _tree_prepend_axis(dense_layer_axes(cfg))
        ax["blocks"] = _tree_prepend_axis(moe_layer_axes(cfg))
    elif fam == "ssm":
        ax["blocks"] = _tree_prepend_axis(ssm_layer_axes(cfg))
    elif fam == "hybrid":
        sb = {"rec0": rec_layer_axes(cfg), "rec1": rec_layer_axes(cfg),
              "attn": dense_layer_axes(cfg)}
        ax["blocks"] = _tree_prepend_axis(sb)
        if cfg.n_layers % 3:
            ax["tail_blocks"] = _tree_prepend_axis(rec_layer_axes(cfg))
    return ax


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward_lm(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
               policy: ExecPolicy | None = None):
    """tokens (B, S) -> (logits (B, S, V), aux_loss scalar)."""
    policy = policy or ExecPolicy.from_cfg(cfg)
    x = embedding_lookup(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "dense":
        def body(carry, lp):
            return dense_layer_fwd(lp, carry, cfg, policy), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["blocks"])
    elif fam == "moe":
        if cfg.first_dense_layers:
            def dbody(carry, lp):
                return dense_layer_fwd(lp, carry, cfg, policy), None
            x, _ = jax.lax.scan(_maybe_remat(dbody, cfg), x,
                                params["dense_blocks"])

        def mbody(carry, lp):
            y, aux = moe_layer_fwd(lp, carry, cfg, policy)
            return y, aux
        x, auxs = jax.lax.scan(_maybe_remat(mbody, cfg), x, params["blocks"])
        aux_total = aux_total + auxs.sum()
    elif fam == "ssm":
        def sbody(carry, lp):
            y, _ = ssm_mod.ssd_forward(
                lp["ssd"], rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg, policy)
            return shard(carry + y, "batch", "seq", "embed"), None
        x, _ = jax.lax.scan(_maybe_remat(sbody, cfg), x, params["blocks"])
    elif fam == "hybrid":
        def rec_fwd(lp, carry):
            y, _ = rglru_mod.rglru_forward(
                lp["rec"], rmsnorm(carry, lp["ln1"], cfg.norm_eps), cfg, policy)
            carry = carry + y
            carry = carry + ffn_mod.swiglu(
                lp["ffn"], rmsnorm(carry, lp["ln2"], cfg.norm_eps), policy)
            return shard(carry, "batch", "seq", "embed")

        def hbody(carry, lp):
            carry = rec_fwd(lp["rec0"], carry)
            carry = rec_fwd(lp["rec1"], carry)
            carry = dense_layer_fwd(lp["attn"], carry, cfg, policy,
                                    window=cfg.window)
            return carry, None
        x, _ = jax.lax.scan(_maybe_remat(hbody, cfg), x, params["blocks"])
        if "tail_blocks" in params:
            def tbody(carry, lp):
                return rec_fwd(lp, carry), None
            x, _ = jax.lax.scan(_maybe_remat(tbody, cfg), x,
                                params["tail_blocks"])
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = linear(x, head, policy=policy)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux_total


def lm_loss(params, batch, cfg: ArchConfig, policy=None,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE balance aux)."""
    logits, aux = forward_lm(params, batch["tokens"], cfg, policy)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    return nll + aux_weight * aux


# --------------------------------------------------------------------------
# decode (serve_step)
# --------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> tuple[dict, dict]:
    """(shapes, logical_axes) for the decode cache pytree.

    KV caches are sharded ("batch", "kv_seq", ...) -> seq over the model
    axis: the flash-decoding layout (DESIGN.md §4). Recurrent states are
    batch-sharded only.
    """
    fam = cfg.family
    hkv, hd = cfg.kv_heads, cfg.head_dim
    if fam in ("dense", "moe"):
        n_l = cfg.n_layers
        shapes = {"k": ((n_l, batch, seq_len, hkv, hd), dtype),
                  "v": ((n_l, batch, seq_len, hkv, hd), dtype)}
        axes = {"k": ("p_layers", "batch", "kv_seq", None, None),
                "v": ("p_layers", "batch", "kv_seq", None, None)}
    elif fam == "ssm":
        st = ssm_mod.ssd_state_shape(cfg, batch)
        n_l = cfg.n_layers
        shapes = {"h": ((n_l,) + st["h"], jnp.float32),
                  "conv": ((n_l,) + st["conv"], dtype)}
        axes = {"h": ("p_layers", "batch", None, None, None),
                "conv": ("p_layers", "batch", None, None)}
    elif fam == "hybrid":
        nsb = cfg.n_layers // 3
        rem = cfg.n_layers - 3 * nsb
        w = min(cfg.window or seq_len, seq_len)
        rst = rglru_mod.rglru_state_shape(cfg, batch)
        shapes = {
            "rec_h": ((nsb, 2) + rst["h"], jnp.float32),
            "rec_conv": ((nsb, 2) + rst["conv"], dtype),
            "attn_k": ((nsb, batch, w, hkv, hd), dtype),
            "attn_v": ((nsb, batch, w, hkv, hd), dtype),
        }
        axes = {"rec_h": ("p_layers", None, "batch", "mlp"),
                "rec_conv": ("p_layers", None, "batch", None, "mlp"),
                "attn_k": ("p_layers", "batch", "kv_seq", None, None),
                "attn_v": ("p_layers", "batch", "kv_seq", None, None)}
        if rem:
            shapes["tail_h"] = ((rem,) + rst["h"], jnp.float32)
            shapes["tail_conv"] = ((rem,) + rst["conv"], dtype)
            axes["tail_h"] = ("p_layers", "batch", "mlp")
            axes["tail_conv"] = ("p_layers", "batch", None, "mlp")
    else:
        raise ValueError(fam)
    return shapes, axes


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray, pos,
                cfg: ArchConfig, policy: ExecPolicy | None = None):
    """One decode step. tokens (B, 1) int32, pos scalar int32 (current
    position = number of tokens already in cache). Returns (logits (B, V),
    new_cache)."""
    policy = policy or ExecPolicy.from_cfg(cfg, training=False)
    x = embedding_lookup(params["embed"], tokens)
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(carry, xs):
            if fam == "moe":
                lp, ck, cv, is_moe = xs
            else:
                lp, ck, cv = xs
            h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
            o, ck, cv = attn_decode(lp["attn"], h, ck, cv, pos, cfg, policy)
            carry = carry + o
            h2 = rmsnorm(carry, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                y, _ = moe_mod.moe_ffn(lp["moe"], h2, top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor,
                                       groups=cfg.moe_groups, policy=policy,
                                       local_combine=cfg.moe_local_combine)
            else:
                y = ffn_mod.swiglu(lp["ffn"], h2, policy)
            return carry + y, (ck, cv)

        if fam == "moe" and cfg.first_dense_layers:
            nd = cfg.first_dense_layers
            kd, vd = cache["k"][:nd], cache["v"][:nd]

            def dbody(carry, xs):
                lp, ck, cv = xs
                h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                o, ck, cv = attn_decode(lp["attn"], h, ck, cv, pos, cfg, policy)
                carry = carry + o
                y = ffn_mod.swiglu(lp["ffn"],
                                   rmsnorm(carry, lp["ln2"], cfg.norm_eps),
                                   policy)
                return carry + y, (ck, cv)
            x, (kd2, vd2) = jax.lax.scan(dbody, x,
                                         (params["dense_blocks"], kd, vd))
            km, vm = cache["k"][nd:], cache["v"][nd:]

            def mbody(carry, xs):
                lp, ck, cv = xs
                h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                o, ck, cv = attn_decode(lp["attn"], h, ck, cv, pos, cfg, policy)
                carry = carry + o
                y, _ = moe_mod.moe_ffn(lp["moe"],
                                       rmsnorm(carry, lp["ln2"], cfg.norm_eps),
                                       top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor,
                                       groups=cfg.moe_groups, policy=policy,
                                       local_combine=cfg.moe_local_combine)
                return carry + y, (ck, cv)
            x, (km2, vm2) = jax.lax.scan(mbody, x, (params["blocks"], km, vm))
            new_cache = {"k": jnp.concatenate([kd2, km2]),
                         "v": jnp.concatenate([vd2, vm2])}
        else:
            def ubody(carry, xs):
                lp, ck, cv = xs
                h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                o, ck, cv = attn_decode(lp["attn"], h, ck, cv, pos, cfg, policy)
                carry = carry + o
                h2 = rmsnorm(carry, lp["ln2"], cfg.norm_eps)
                if fam == "moe":
                    y, _ = moe_mod.moe_ffn(lp["moe"], h2, top_k=cfg.top_k,
                                           capacity_factor=cfg.capacity_factor,
                                           policy=policy,
                                           local_combine=cfg.moe_local_combine)
                else:
                    y = ffn_mod.swiglu(lp["ffn"], h2, policy)
                return carry + y, (ck, cv)
            x, (k2, v2) = jax.lax.scan(ubody, x,
                                       (params["blocks"], cache["k"],
                                        cache["v"]))
            new_cache = {"k": k2, "v": v2}

    elif fam == "ssm":
        def sbody(carry, xs):
            lp, hs, cs = xs
            y, st = ssm_mod.ssd_decode_step(
                lp["ssd"], rmsnorm(carry, lp["ln"], cfg.norm_eps),
                {"h": hs, "conv": cs}, cfg, policy)
            return carry + y, (st["h"], st["conv"])
        x, (h2, c2) = jax.lax.scan(sbody, x,
                                   (params["blocks"], cache["h"],
                                    cache["conv"]))
        new_cache = {"h": h2, "conv": c2}

    elif fam == "hybrid":
        def rec_step(lp, carry, hs, cs):
            y, st = rglru_mod.rglru_decode_step(
                lp["rec"], rmsnorm(carry, lp["ln1"], cfg.norm_eps),
                {"h": hs, "conv": cs}, cfg, policy)
            carry = carry + y
            carry = carry + ffn_mod.swiglu(
                lp["ffn"], rmsnorm(carry, lp["ln2"], cfg.norm_eps), policy)
            return carry, st["h"], st["conv"]

        def hbody(carry, xs):
            lp, rh, rc, ak, av = xs
            carry, h0, c0 = rec_step(lp["rec0"], carry, rh[0], rc[0])
            carry, h1, c1 = rec_step(lp["rec1"], carry, rh[1], rc[1])
            h = rmsnorm(carry, lp["attn"]["ln1"], cfg.norm_eps)
            o, ak, av = attn_decode(lp["attn"]["attn"], h, ak, av, pos, cfg,
                                    policy, window=cfg.window)
            carry = carry + o
            carry = carry + ffn_mod.swiglu(
                lp["attn"]["ffn"],
                rmsnorm(carry, lp["attn"]["ln2"], cfg.norm_eps), policy)
            return carry, (jnp.stack([h0, h1]), jnp.stack([c0, c1]), ak, av)

        x, (rh2, rc2, ak2, av2) = jax.lax.scan(
            hbody, x, (params["blocks"], cache["rec_h"], cache["rec_conv"],
                       cache["attn_k"], cache["attn_v"]))
        new_cache = {"rec_h": rh2, "rec_conv": rc2,
                     "attn_k": ak2, "attn_v": av2}
        if "tail_blocks" in params:
            def tbody(carry, xs):
                lp, hs, cs = xs
                carry, h, c = rec_step(lp, carry, hs, cs)
                return carry, (h, c)
            x, (th2, tc2) = jax.lax.scan(
                tbody, x, (params["tail_blocks"], cache["tail_h"],
                           cache["tail_conv"]))
            new_cache["tail_h"] = th2
            new_cache["tail_conv"] = tc2
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = linear(x, head, policy=policy)[:, 0]
    return logits, new_cache
