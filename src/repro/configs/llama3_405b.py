"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 (arXiv:2407.21783). Memory policy for 256x16GB v5e:
microbatch accumulation + bf16 optimizer state (DESIGN.md §4)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, kv_heads=8,
        d_ff=53248, vocab=128256,
        rope_theta=500000.0,
        microbatch_steps=8,          # microbatch 32 of global 256
        use_fp32_master=False,       # bf16 m/v (low_mem AdamW)
        attn_block_q=512, attn_block_kv=1024,
    )
