"""Pure-jnp oracles for every Pallas kernel (the numerics contracts)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["photonic_matmul_ref", "flash_attention_ref", "expand_kv_heads",
           "prefix_key_mask"]


def prefix_key_mask(kv_len, b: int, skv: int) -> jax.Array:
    """Packed kept-count -> (b, skv) prefix keep-mask (key j kept iff
    j < kv_len; kv_len scalar or (b,)). One definition shared by every
    attention lowering."""
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    return (jnp.arange(skv, dtype=jnp.int32)[None, :]
            < lens[:, None]).astype(jnp.float32)


def expand_kv_heads(t: jax.Array, h: int) -> jax.Array:
    """(..., hk, s, d) -> (..., h, s, d): THE head-grouping contract every
    attention dataflow shares (contiguous GQA repeat; hk == 1 — the Eq. 2
    shared-X keys — broadcasts). Query head i reads KV head i // (h//hk),
    matching the Pallas kernels' ``i // g`` BlockSpec index maps."""
    hk = t.shape[-3]
    if hk == h:
        return t
    if hk == 1:
        return jnp.broadcast_to(t, t.shape[:-3] + (h,) + t.shape[-2:])
    return jnp.repeat(t, h // hk, axis=-3)


def photonic_matmul_ref(xq: jax.Array, wq: jax.Array, sx: jax.Array,
                        sw: jax.Array) -> jax.Array:
    """Integer-exact w8a8 matmul + dequant. xq (M,K) int8; wq (K,N) int8;
    sx () f32; sw (N,) f32 -> (M,N) f32. Must match the Pallas kernel
    bit-for-bit (integer accumulate is exact)."""
    acc = jax.lax.dot_general(xq.astype(jnp.int32), wq.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw[None, :]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        key_mask: jax.Array | None = None,
                        scale: float | None = None) -> jax.Array:
    """Dense softmax attention oracle. q (B,H,Sq,D); k (B,Hk,Skv,D);
    v (B,Hv,Skv,Dv) -> (B,H,Sq,Dv).

    ``key_mask`` (B, Skv) keep-mask prunes keys per batch row with
    ``NEG_INF`` scores before the softmax — the contract the RoI-masked
    Pallas kernel (and every masked-vs-gathered parity test) is checked
    against, so kernel tests share this one reference instead of
    hand-rolling their own. Query rows whose every visible key is masked
    return exactly 0, matching the kernel's zero-denominator guard.
    ``scale`` defaults to 1/sqrt(D); pass 1.0 when it is already folded
    into Q (Eq. 2 decomposed scores).
    """
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kf = expand_kv_heads(k, h).astype(jnp.float32)
    vf = expand_kv_heads(v, h).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= q_pos - kv_pos < window
    mask = jnp.broadcast_to(mask[None, None], (b, 1, sq, skv))
    if key_mask is not None:
        mask = mask & (key_mask[:, None, None, :] > 0)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    o = jnp.where(mask.any(-1)[..., None], o, 0.0)     # fully-masked rows
    return o.reshape(b, h, sq, dv).astype(q.dtype)
