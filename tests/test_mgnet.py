"""MGNet RoI mask-generation tests (paper Eq. 3 + §IV RoI Selection)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mgnet import (MGNetConfig, bce_loss, init_mgnet, mask_iou,
                              mgnet_mask, mgnet_scores, patchify,
                              select_topk_patches)
from repro.data.pipeline import ImageStream


@pytest.fixture(scope="module")
def cfg():
    return MGNetConfig(patch=8, embed=32, heads=2, img_size=32)


@pytest.fixture(scope="module")
def params(cfg):
    return init_mgnet(jax.random.PRNGKey(0), cfg)


def test_patchify_roundtrip_shape(cfg):
    imgs = jnp.arange(2 * 32 * 32 * 3, dtype=jnp.float32).reshape(
        2, 32, 32, 3)
    p = patchify(imgs, cfg.patch)
    assert p.shape == (2, 16, 8 * 8 * 3)
    # first patch = top-left 8x8 block
    np.testing.assert_array_equal(
        np.asarray(p[0, 0].reshape(8, 8, 3)), np.asarray(imgs[0, :8, :8]))


def test_scores_shape(params, cfg):
    imgs = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 32, 3))
    s = mgnet_scores(params, imgs, cfg)
    assert s.shape == (3, cfg.n_patches)
    assert not bool(jnp.isnan(s).any())


def test_mask_binary(params, cfg):
    imgs = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32, 3))
    m = mgnet_mask(params, imgs, cfg)
    vals = set(np.unique(np.asarray(m)).tolist())
    assert vals <= {0.0, 1.0}


def test_topk_selects_highest(cfg):
    scores = jnp.asarray([[0.1, 0.9, 0.5, 0.7]])
    tokens = jnp.arange(4, dtype=jnp.float32)[None, :, None] + 10
    pruned, idx = select_topk_patches(scores, tokens, keep=2)
    assert pruned.shape == (1, 2, 1)
    assert set(np.asarray(idx[0]).tolist()) == {1, 3}


def test_topk_tiebreak_deterministic():
    """Equal scores must resolve to the *lowest patch index* — stable,
    backend-independent routing for the serving bucket ladder."""
    scores = jnp.asarray([[0.5, 0.9, 0.5, 0.5, 0.1]])
    tokens = jnp.arange(5, dtype=jnp.float32)[None, :, None]
    _, idx = select_topk_patches(scores, tokens, keep=3)
    np.testing.assert_array_equal(np.asarray(idx), [[1, 0, 2]])
    # jit and eager agree, and repeated calls are bit-identical
    jidx = jax.jit(lambda s, t: select_topk_patches(s, t, 3)[1])(scores,
                                                                 tokens)
    np.testing.assert_array_equal(np.asarray(jidx), np.asarray(idx))
    for _ in range(3):
        _, again = select_topk_patches(scores, tokens, keep=3)
        np.testing.assert_array_equal(np.asarray(again), np.asarray(idx))


def test_topk_all_equal_scores_keeps_prefix():
    scores = jnp.zeros((2, 6))
    tokens = jnp.broadcast_to(jnp.arange(6, dtype=jnp.float32)[None, :, None],
                              (2, 6, 1))
    pruned, idx = select_topk_patches(scores, tokens, keep=4)
    np.testing.assert_array_equal(np.asarray(idx), [[0, 1, 2, 3]] * 2)
    np.testing.assert_array_equal(np.asarray(pruned[..., 0]),
                                  [[0, 1, 2, 3]] * 2)


def test_mask_budget_counts_threshold_crossers():
    from repro.core.mgnet import mask_budget
    scores = jnp.asarray([[10.0, -10.0, 10.0, -10.0],
                          [10.0, 10.0, 10.0, 10.0]])
    np.testing.assert_array_equal(np.asarray(mask_budget(scores, 0.5)),
                                  [2, 4])


def test_frame_delta_signal():
    from repro.core.mgnet import frame_delta
    a = jnp.zeros((2, 8, 8, 3))
    b = a.at[1].add(1.0)
    d = frame_delta(b, jnp.zeros((8, 8, 3)))
    assert float(d[0]) == pytest.approx(0.0)
    assert float(d[1]) == pytest.approx(1.0)


def test_mask_iou_properties():
    a = jnp.asarray([[1.0, 1, 0, 0]])
    assert float(mask_iou(a, a)) == pytest.approx(1.0)
    b = jnp.asarray([[0.0, 0, 1, 1]])
    assert float(mask_iou(a, b)) == pytest.approx(0.0)
    c = jnp.asarray([[1.0, 0, 1, 0]])
    assert float(mask_iou(a, c)) == pytest.approx(1 / 3, abs=1e-6)


def test_bce_loss_direction():
    logits = jnp.asarray([10.0, -10.0])
    good = bce_loss(logits, jnp.asarray([1.0, 0.0]))
    bad = bce_loss(logits, jnp.asarray([0.0, 1.0]))
    assert float(good) < 0.01 < float(bad)


def test_mgnet_learns_synthetic_boxes(cfg, params):
    """Train MGNet on the planted-box ImageStream: mIoU must improve
    substantially over the untrained net (mechanism-level reproduction of
    the paper's BCE-against-box-labels training)."""
    stream = ImageStream(img_size=32, global_batch=16, patch=8, seed=3)

    def loss_fn(p, batch):
        s = mgnet_scores(p, batch["images"], cfg)
        return bce_loss(s, batch["patch_mask"])

    @jax.jit
    def step(p, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g), l

    def miou(p):
        batch = stream.batch_at(999)
        pred = (jax.nn.sigmoid(mgnet_scores(p, batch["images"], cfg))
                > cfg.t_reg).astype(jnp.float32)
        return float(mask_iou(pred, batch["patch_mask"]))

    m0 = miou(params)
    p = params
    for i in range(200):
        p, _ = step(p, stream.batch_at(i))
    m1 = miou(p)
    assert m1 > max(m0 + 0.15, 0.4), (m0, m1)
