"""Synthetic sharded data pipelines with deterministic, resumable streams.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after a preemption the pipeline resumes at the checkpointed
step with bit-identical data (fault-tolerance requirement, DESIGN.md §4).

On a multi-host deployment each host generates only its addressable shard
(``jax.make_array_from_callback``); on this single-process host that
degenerates to a device_put with the right NamedSharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx, named_sharding

__all__ = ["TokenStream", "ImageStream", "FrameStream", "lm_batch_specs"]


def _host_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


@dataclass
class TokenStream:
    """Synthetic LM batches: {"tokens": (B, S) i32, "labels": (B, S) i32}.

    Markov-ish synthetic text (mixture of n-gram repeats) so that loss
    actually decreases during the example training runs.
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ctx: ShardingCtx | None = None

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        b, s = self.global_batch, self.seq_len
        # repeatable structure: random walk over a small state machine
        base = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int32)
        steps = rng.integers(1, 7, size=(b, s), dtype=np.int32)
        toks = (base + np.cumsum(steps, axis=1)) % self.vocab
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        batch = {"tokens": tokens, "labels": labels}
        return self._put(batch)

    def _put(self, batch: dict) -> dict:
        if self.ctx is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            sh = named_sharding(v.shape, ("batch", "seq"), self.ctx)
            out[k] = jax.device_put(v, sh)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class ImageStream:
    """Synthetic image-classification batches with planted RoI structure:
    one bright object box on a dark background; the label is a function of
    the box quadrant + texture — so MGNet has real signal to learn."""

    img_size: int
    global_batch: int
    n_classes: int = 10
    patch: int = 16
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        b, h = self.global_batch, self.img_size
        imgs = rng.normal(0.0, 0.1, size=(b, h, h, 3)).astype(np.float32)
        g = h // self.patch
        patch_mask = np.zeros((b, g * g), np.float32)
        labels = np.zeros((b,), np.int32)
        for i in range(b):
            bw = rng.integers(h // 4, h // 2)
            bh = rng.integers(h // 4, h // 2)
            y0 = rng.integers(0, h - bh)
            x0 = rng.integers(0, h - bw)
            tex = rng.integers(0, 5)
            imgs[i, y0:y0 + bh, x0:x0 + bw] += 1.0 + 0.2 * tex
            quad = (2 * ((y0 + bh / 2) > h / 2) + ((x0 + bw / 2) > h / 2))
            labels[i] = int(quad) * 5 // 2 + tex % 5 if False else int(quad * 2 + tex % 2)
            # ground-truth patch mask from the box (paper: 1 if any overlap)
            py0, py1 = y0 // self.patch, (y0 + bh - 1) // self.patch
            px0, px1 = x0 // self.patch, (x0 + bw - 1) // self.patch
            m2 = np.zeros((g, g), np.float32)
            m2[py0:py1 + 1, px0:px1 + 1] = 1.0
            patch_mask[i] = m2.reshape(-1)
        return {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels),
                "patch_mask": jnp.asarray(patch_mask)}


@dataclass
class FrameStream:
    """Synthetic precomputed frontend embeddings (whisper/vlm stubs)."""

    n_frames: int
    dim: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        x = rng.normal(size=(self.global_batch, self.n_frames, self.dim))
        return {"frames": jnp.asarray(x.astype(np.float32))}


def quadrant_labels(patch_mask: jnp.ndarray) -> jnp.ndarray:
    """4-class labels from the planted-box mask centroid quadrant —
    a strongly learnable target for the QAT mechanism benchmarks."""
    b, n = patch_mask.shape
    g = int(np.sqrt(n))
    m = patch_mask.reshape(b, g, g)
    ys = jnp.arange(g)[None, :, None]
    xs = jnp.arange(g)[None, None, :]
    tot = m.sum((1, 2)) + 1e-6
    cy = (m * ys).sum((1, 2)) / tot
    cx = (m * xs).sum((1, 2)) / tot
    mid = (g - 1) / 2.0
    return ((cy > mid).astype(jnp.int32) * 2 + (cx > mid).astype(jnp.int32))


def lm_batch_specs(shape_cfg, dtype=jnp.int32):
    """ShapeDtypeStructs for an LM batch (dry-run input stand-ins)."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, s), dtype),
            "labels": jax.ShapeDtypeStruct((b, s), dtype)}
