"""RG-LRU tests: associative scan vs naive loop; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.rglru import (init_rglru, rglru_decode_step, rglru_forward,
                                rglru_state_shape)


def _cfg():
    return ArchConfig(name="t", family="hybrid", n_layers=3, d_model=24,
                      n_heads=2, kv_heads=1, d_ff=48, vocab=64,
                      lru_width=24, window=8, attn_every=3,
                      conv_kernel=4, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model)) * 0.5
    return cfg, params, x


def test_forward_vs_stepwise(setup):
    cfg, params, x = setup
    y_full, final = rglru_forward(params, x, cfg)
    st = rglru_state_shape(cfg, 2)
    state = {"h": jnp.zeros(st["h"], jnp.float32),
             "conv": jnp.zeros(st["conv"], jnp.float32)}
    ys = []
    for t in range(x.shape[1]):
        y_t, state = rglru_decode_step(params, x[:, t:t + 1], state, cfg)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final["h"]),
                               np.asarray(state["h"]), rtol=2e-4, atol=2e-4)


def test_state_handoff(setup):
    cfg, params, x = setup
    y_full, _ = rglru_forward(params, x, cfg)
    y1, st1 = rglru_forward(params, x[:, :12], cfg)
    y2, _ = rglru_forward(params, x[:, 12:], cfg, initial_state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4)


def test_gate_range(setup):
    """a_t = exp(-c softplus(Lambda) r_t) must stay in (0, 1) — the
    stability condition of the RG-LRU."""
    cfg, params, x = setup
    from repro.models.rglru import _gates
    u = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.lru_dim))
    a, b = _gates(params, u)
    assert float(a.min()) > 0.0 and float(a.max()) < 1.0


def test_causality(setup):
    cfg, params, x = setup
    y1, _ = rglru_forward(params, x, cfg)
    x2 = x.at[:, 15:].set(0.0)
    y2, _ = rglru_forward(params, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :12]),
                               np.asarray(y2[:, :12]), rtol=1e-5, atol=1e-5)
