"""Roofline analysis: optimized-HLO parsing + per-cell term derivation."""
