"""Family-dispatch model API: one uniform surface for the launch layer.

Every architecture family exposes the same five entry points here:

    init_model(key, cfg)            -> params pytree
    model_logical_axes(cfg)         -> logical-axis pytree (matches params)
    loss_fn(params, batch, cfg)     -> scalar loss          (train shapes)
    prefill_fn(params, batch, cfg)  -> logits               (prefill shapes)
    decode_fn(params, cache, tokens, pos, cfg) -> (logits, cache)  (decode)

plus the input plumbing the dry-run needs:

    batch_specs(cfg, shape_cfg)     -> {name: (shape, dtype, logical_axes)}
    cache_axes_spec(cfg, b, s)      -> ({name: (shape, dtype)}, {name: axes})

Batches are dicts; the per-family key sets are:
    dense/moe/ssm/hybrid : tokens, labels
    encdec (whisper)     : frames (stub embeddings), tokens, labels
    vlm                  : img_embeds (stub embeddings), tokens, labels
    vit                  : images, labels
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ed_mod
from repro.models import transformer as tf_mod
from repro.models import vit as vit_mod
from repro.models import vlm as vlm_mod
from repro.models.layers import ExecPolicy

__all__ = ["init_model", "model_logical_axes", "loss_fn", "prefill_fn",
           "decode_fn", "batch_specs", "cache_axes_spec", "supports_decode",
           "skips_long_context", "BATCH_AXES"]

_LM_FAMILIES = ("dense", "moe", "ssm", "hybrid")

# logical axes of every batch key (rank must match the array)
BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", None),
    "img_embeds": ("batch", None, None),
    "images": ("batch", None, None, None),
    "decode_tokens": ("batch", None),
}


def init_model(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    if cfg.family in _LM_FAMILIES:
        return tf_mod.init_lm(key, cfg, dtype)
    if cfg.family == "encdec":
        return ed_mod.init_encdec(key, cfg, dtype)
    if cfg.family == "vlm":
        return vlm_mod.init_vlm(key, cfg, dtype)
    if cfg.family == "vit":
        return vit_mod.init_vit(key, cfg)
    raise ValueError(cfg.family)


def model_logical_axes(cfg: ArchConfig):
    if cfg.family in _LM_FAMILIES:
        return tf_mod.lm_logical_axes(cfg)
    if cfg.family == "encdec":
        return ed_mod.encdec_logical_axes(cfg)
    if cfg.family == "vlm":
        return vlm_mod.vlm_logical_axes(cfg)
    if cfg.family == "vit":
        return vit_mod.vit_logical_axes(cfg)
    raise ValueError(cfg.family)


def _xent(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def loss_fn(params, batch, cfg: ArchConfig,
            policy: ExecPolicy | None = None) -> jnp.ndarray:
    fam = cfg.family
    if fam in _LM_FAMILIES:
        return tf_mod.lm_loss(params, batch, cfg, policy)
    if fam == "encdec":
        logits, _ = ed_mod.forward_encdec(params, batch["frames"],
                                          batch["tokens"], cfg, policy)
        return _xent(logits, batch["labels"])
    if fam == "vlm":
        logits, _ = vlm_mod.forward_vlm(params, batch["tokens"],
                                        batch["img_embeds"], cfg, policy)
        return _xent(logits, batch["labels"])
    if fam == "vit":
        logits, _ = vit_mod.forward_vit(params, batch["images"], cfg, policy)
        return _xent(logits, batch["labels"])
    raise ValueError(fam)


def prefill_fn(params, batch, cfg: ArchConfig,
               policy: ExecPolicy | None = None):
    """Inference forward over the full prompt (logits out)."""
    fam = cfg.family
    policy = policy or ExecPolicy.from_cfg(cfg, training=False)
    if fam in _LM_FAMILIES:
        logits, _ = tf_mod.forward_lm(params, batch["tokens"], cfg, policy)
        return logits
    if fam == "encdec":
        logits, _ = ed_mod.forward_encdec(params, batch["frames"],
                                          batch["tokens"], cfg, policy)
        return logits
    if fam == "vlm":
        logits, _ = vlm_mod.forward_vlm(params, batch["tokens"],
                                        batch["img_embeds"], cfg, policy)
        return logits
    if fam == "vit":
        logits, _ = vit_mod.forward_vit(params, batch["images"], cfg, policy)
        return logits
    raise ValueError(fam)


def decode_fn(params, cache, tokens, pos, cfg: ArchConfig,
              policy: ExecPolicy | None = None):
    fam = cfg.family
    policy = policy or ExecPolicy.from_cfg(cfg, training=False)
    if fam in _LM_FAMILIES:
        return tf_mod.decode_step(params, cache, tokens, pos, cfg, policy)
    if fam == "encdec":
        return ed_mod.decode_step_encdec(params, cache, tokens, pos, cfg,
                                         policy)
    if fam == "vlm":
        return vlm_mod.decode_step_vlm(params, cache, tokens, pos, cfg,
                                       policy)
    raise ValueError(f"{fam} has no decode step")


def supports_decode(cfg: ArchConfig) -> bool:
    return cfg.family != "vit"


def skips_long_context(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM / hybrid window
    attention). Full-attention archs skip — see DESIGN.md §5."""
    return cfg.family not in ("ssm", "hybrid")


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """{key: (shape_tuple, dtype, logical_axes)} for the given cell.

    decode kinds describe the *single-token step* inputs (the cache is
    produced separately via ``cache_axes_spec``).
    """
    b, s = shape.global_batch, shape.seq_len
    fam = cfg.family
    if shape.kind == "decode":
        return {"tokens": ((b, 1), jnp.int32, BATCH_AXES["decode_tokens"])}

    out: dict = {}
    if fam in _LM_FAMILIES:
        out["tokens"] = ((b, s), jnp.int32, BATCH_AXES["tokens"])
    elif fam == "encdec":
        dfr = cfg.d_frontend or cfg.d_model
        out["frames"] = ((b, cfg.enc_frames, dfr), jnp.float32,
                         BATCH_AXES["frames"])
        out["tokens"] = ((b, s), jnp.int32, BATCH_AXES["tokens"])
    elif fam == "vlm":
        dfr = cfg.d_frontend or cfg.d_model
        out["img_embeds"] = ((b, cfg.n_img_tokens, dfr), jnp.float32,
                             BATCH_AXES["img_embeds"])
        out["tokens"] = ((b, s), jnp.int32, BATCH_AXES["tokens"])
    elif fam == "vit":
        out["images"] = ((b, cfg.img_size, cfg.img_size, 3), jnp.float32,
                         BATCH_AXES["images"])
    else:
        raise ValueError(fam)

    if shape.kind == "train":
        if fam == "vit":
            out["labels"] = ((b,), jnp.int32, ("batch",))
        else:
            out["labels"] = ((b, s), jnp.int32, BATCH_AXES["labels"])
    return out


def cache_axes_spec(cfg: ArchConfig, batch: int, seq_len: int,
                    dtype=jnp.bfloat16):
    """(shapes {name: (shape, dtype)}, axes {name: logical_axes})."""
    fam = cfg.family
    if fam in _LM_FAMILIES:
        return tf_mod.cache_spec(cfg, batch, seq_len, dtype)
    if fam == "encdec":
        return ed_mod.encdec_cache_spec(cfg, batch, seq_len, dtype)
    if fam == "vlm":
        return vlm_mod.vlm_cache_spec(cfg, batch, seq_len, dtype)
    raise ValueError(f"{fam} has no decode cache")
