"""Launch-layer tests: abstract state, input specs, cell skip rules.

The 512-device production meshes cannot be built in tests (device count
is locked at first jax init) — those paths are covered by the dry-run
artifacts; here we validate the pure logic + 1-device lowering."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, ShapeConfig, smoke_variant
from repro.configs.registry import all_lm_archs, get_config
from repro.distributed.sharding import ShardingCtx, DEFAULT_RULES, use_sharding
from repro.launch.dryrun import cell_skip_reason
from repro.launch.mesh import batch_shard_count, make_host_mesh
from repro.launch.steps import (abstract_state, batch_arg_specs, build_cell,
                                state_logical_axes, tree_shardings)
from repro.models import api as model_api


@pytest.fixture(scope="module")
def ctx():
    mesh = make_host_mesh(1, 1)
    return ShardingCtx(mesh, DEFAULT_RULES)


def test_abstract_state_matches_real_init():
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    abs_st = abstract_state(cfg)
    from repro.launch.train import init_state
    real = init_state(cfg)
    flat_a = jax.tree_util.tree_leaves(abs_st)
    flat_r = jax.tree_util.tree_leaves(real)
    assert len(flat_a) == len(flat_r)
    for a, r in zip(flat_a, flat_r):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_state_axes_cover_state():
    cfg = smoke_variant(get_config("qwen3-moe-30b-a3b"))
    st = abstract_state(cfg)
    ax = state_logical_axes(cfg)
    # tree_shardings must succeed leaf-for-leaf (same structure)
    mesh = make_host_mesh(1, 1)
    sh = tree_shardings(ax, st, ShardingCtx(mesh, DEFAULT_RULES))
    assert (len(jax.tree_util.tree_leaves(sh))
            == len(jax.tree_util.tree_leaves(st)))


def test_batch_specs_per_family(ctx):
    shape = ShapeConfig("t", 64, 4, "train")
    for arch, keys in [("qwen2-1.5b", {"tokens", "labels"}),
                       ("whisper-medium", {"frames", "tokens", "labels"}),
                       ("llama-3.2-vision-90b",
                        {"img_embeds", "tokens", "labels"})]:
        cfg = get_config(arch)
        specs, _ = batch_arg_specs(cfg, shape, ctx)
        assert set(specs) == keys, arch


def test_decode_specs(ctx):
    shape = ShapeConfig("d", 64, 4, "decode")
    cfg = get_config("qwen2-1.5b")
    specs, _ = batch_arg_specs(cfg, shape, ctx)
    assert specs["tokens"].shape == (4, 1)


@pytest.mark.parametrize("arch", all_lm_archs())
def test_skip_rules(arch):
    cfg = get_config(arch)
    reason = cell_skip_reason(cfg, SHAPES["long_500k"])
    if cfg.family in ("ssm", "hybrid"):
        assert reason is None
    else:
        assert reason is not None
    assert cell_skip_reason(cfg, SHAPES["train_4k"]) is None


def test_build_cell_lowers_on_host_mesh():
    """End-to-end: build + lower + compile a smoke cell on the 1-device
    mesh (the dry-run does the same on 512)."""
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    shape = ShapeConfig("t", 64, 4, "train")
    mesh = make_host_mesh(1, 1)
    with mesh, use_sharding(mesh):
        jitted, arg_specs = build_cell(cfg, shape, mesh)
        compiled = jitted.lower(*arg_specs).compile()
    assert compiled.cost_analysis() is not None


def test_batch_shard_count():
    mesh = make_host_mesh(1, 1)
    assert batch_shard_count(mesh) == 1
