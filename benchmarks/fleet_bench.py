"""Fleet front-end benchmark: worker scaling, sharded parity, placement.

Three gates over ``serving/fleet.py`` + the model-sharded encoder, all on
a forced-multi-device CPU host (``--xla_force_host_platform_device_count``
— subprocesses, so the parent's JAX runtime stays untouched):

  1. **scaling**: the bursty tiny-96 fleet (8 streams, skewed 1x..3x
     frame mix) must serve >= 1.5x more aggregate frames/s on 4 workers
     than on 1. Workers are in-process and serve sequentially, each on
     its own measured wall; fleet fps is ``total_frames / max(wall)`` —
     the W-host model where walls overlap. The win is structural
     (multiplexing W-ways smaller queues), so the gate mostly guards
     against the router serializing what should parallelize.
  2. **sharded parity**: the fully-fused serving combo
     (photonic_pallas + flash + fused) under ``model_shards=2`` on the
     2-D ("data", "model") mesh must predict **bitwise identically** to
     the 1-device fused path, and the sharded jit cache must actually
     engage (no silent fallback) — the tentpole contract of
     models/sharded_encoder.py.
  3. **placement**: on a mix adversarial to round-robin (the two heavy
     streams land on the same worker mod W), cost placement's aggregate
     fps must beat rr by >= 1.15x (structural ~1.5x; the margin absorbs
     wall-clock noise).

Results merge into ``BENCH_serving.json`` under ``"fleet"``.

    PYTHONPATH=src python -m benchmarks.fleet_bench            # gates
    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke    # fast CI
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SCALE_GATE = 1.5
PLACEMENT_GATE = 1.15
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")

# heavy streams collide on worker 0 under rr (i % workers), so the mix is
# adversarial to blind placement: rr's max queue is ~2x cost's
_HEAVY, _LIGHT = 3, 1


def _env(devices: int = 4) -> dict:
    return dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
                XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count"
                           f"={devices}"))


def _run_script(script: str, *argv: str, devices: int = 4) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", script, *argv],
        capture_output=True, text=True, timeout=1200, env=_env(devices),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


# one fleet serve: argv = workers, placement, streams, base_frames, img,
# heavy_every. The frame mix depends only on heavy_every (every run
# serves the identical stream set — fps comparisons stay apples-to-
# apples); heavy_every equal to the many-worker count makes the heavy
# streams collide on worker 0 under rr. Prices come from the PR-7 cost
# model (EncodeCostModel per-bucket per-frame seconds) — the fleet
# router's default pricing path.
_FLEET_SCRIPT = """
import json, sys, warnings
from repro.configs.opto_vit import get_config
from repro.data.pipeline import video_fleet
from repro.serving.fleet import FleetRouter
from repro.serving.server import ServerConfig
from repro.serving.session import ServingConfig

workers, placement, streams, base, img, heavy_every = (
    int(sys.argv[1]), sys.argv[2], int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]))
cfg = get_config("tiny", img_size=img, mgnet=True).with_(
    matmul_backend="bf16")
sc = ServerConfig.from_serving(
    ServingConfig(microbatch=4, chunk=8, force_bucket=0.5),
    warm_start=True)
router = FleetRouter(cfg, sc, workers=workers, placement=placement)
heavy, light = %d, %d
with warnings.catch_warnings():
    warnings.simplefilter("ignore")       # dead buckets: expected at 50%%
    for i, st in enumerate(video_fleet(streams, img_size=img, patch=16,
                                       cut_every=32)):
        nf = base * (heavy if i %% heavy_every == 0 else light)
        router.add_job(st, n_frames=nf, start=8 * i)
    res = router.serve()
print(json.dumps({
    "fps": router.aggregate_fps,
    "frames": sum(r.frames for r in res.values()),
    "walls": router.last_walls,
    "owners": {j.job_id: j.worker for j in router.jobs.values()},
    "price": router.price_per_frame(),
}))
""" % (_HEAVY, _LIGHT)

# one sharded-vs-unsharded serve on the fully-fused combo:
# argv = model_shards ("0" = mesh off)
_PARITY_SCRIPT = """
import json, sys
import jax
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer

shards = int(sys.argv[1])
cfg = _smoke_cfg("photonic_pallas", "flash", "fused")
sc = ServerConfig(microbatch=4, chunk=8, warm_start=False,
                  mesh="auto" if shards else "off",
                  model_shards=shards, one_shape=True)
srv = StreamServer(cfg, sc, n_classes=8)
if shards:
    assert srv.mesh is not None and len(jax.devices()) == 4, jax.devices()
    assert tuple(srv.mesh.axis_names) == ("data", "model"), srv.mesh
sessions = [srv.add_session(st, n_frames=16)
            for st in video_fleet(2, img_size=32, patch=8, seed=0,
                                  cut_every=16)]
res = srv.serve()
from repro.models.sharded_encoder import sharded_encoder_cache_size
print(json.dumps({
    "predictions": {str(s.sid): res[s.sid].predictions for s in sessions},
    "sharded_jits": sharded_encoder_cache_size(),
}))
"""


def run(smoke: bool = False) -> dict:
    streams = 4 if smoke else 8
    base = 8 if smoke else 16
    img = 64 if smoke else 96
    many = 2 if smoke else 4
    print(f"\n== fleet front-end: {streams} bursty streams, tiny-{img}, "
          f"1 vs {many} workers ==")

    he = str(many)
    one = _run_script(_FLEET_SCRIPT, "1", "cost", str(streams), str(base),
                      str(img), he)
    cost = _run_script(_FLEET_SCRIPT, str(many), "cost", str(streams),
                       str(base), str(img), he)
    rr = _run_script(_FLEET_SCRIPT, str(many), "rr", str(streams),
                     str(base), str(img), he)
    scale = cost["fps"] / one["fps"]
    place = cost["fps"] / rr["fps"]
    print(f"  1 worker : {one['frames']} frames, wall "
          f"{max(one['walls']):.2f}s -> {one['fps']:6.1f} fps "
          f"(cost-model price {one['price'] * 1e3:.2f} ms/frame)")
    print(f"  {many} workers: cost-placed {cost['fps']:6.1f} fps "
          f"(walls {['%.2f' % w for w in cost['walls']]}) | "
          f"rr-placed {rr['fps']:6.1f} fps "
          f"(walls {['%.2f' % w for w in rr['walls']]})")
    print(f"  -> scaling {scale:.2f}x (gate {SCALE_GATE}x), "
          f"cost-vs-rr {place:.2f}x (gate {PLACEMENT_GATE}x)")

    print("  sharded parity: photonic_pallas+flash+fused, "
          "model_shards=2 on forced 4 devices vs mesh off ...")
    sharded = _run_script(_PARITY_SCRIPT, "2")
    plain = _run_script(_PARITY_SCRIPT, "0")
    bitwise = sharded["predictions"] == plain["predictions"]
    engaged = sharded["sharded_jits"] > 0 and plain["sharded_jits"] == 0
    print(f"  -> bitwise equal: {bitwise} | sharded jits engaged: "
          f"{sharded['sharded_jits']} (unsharded run: "
          f"{plain['sharded_jits']})")

    payload = {
        "config": f"tiny-{img}", "streams": streams,
        "base_frames": base, "workers": many,
        "fps_1": one["fps"], "fps_cost": cost["fps"], "fps_rr": rr["fps"],
        "scaling": scale, "placement_speedup": place,
        "price_s_per_frame": cost["price"],
        "sharded_bitwise": bitwise,
        "sharded_jits": sharded["sharded_jits"],
    }

    assert bitwise, (
        "model-sharded fused encode must predict bitwise-identically to "
        "the 1-device fused path (models/sharded_encoder.py contract)")
    assert engaged, (
        f"sharded jit cache must engage under model_shards=2 (got "
        f"{sharded['sharded_jits']}) and stay cold unsharded (got "
        f"{plain['sharded_jits']}) — a silent fallback would make the "
        f"parity check vacuous")
    if smoke:
        print("  (smoke mode: scaling/placement gates + BENCH json "
              "skipped)")
        return payload

    merged = {}
    if os.path.exists(OUT_JSON):           # shared with the serving benches
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["fleet"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON}")

    assert scale >= SCALE_GATE, (
        f"fleet aggregate fps must scale >= {SCALE_GATE}x from 1 -> "
        f"{many} workers on the bursty tiny-{img} mix; measured "
        f"{scale:.2f}x")
    assert place >= PLACEMENT_GATE, (
        f"cost placement must beat round-robin by >= {PLACEMENT_GATE}x "
        f"on the rr-adversarial mix; measured {place:.2f}x")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, parity gate only (fast CI)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
