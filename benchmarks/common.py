"""Shared helpers for the per-figure benchmark modules."""

from __future__ import annotations

import math
import time

from repro.configs.opto_vit import get_config
from repro.core.energy import (EnergyReport, accumulate_matmuls,
                               energy_of_stats, latency_of_stats)
from repro.models.vit import vit_matmul_shapes

VARIANTS = ("tiny", "small", "base", "large")
IMG_SIZES = (96, 224)


def interleaved_best(fns, trials: int = 9) -> list[float]:
    """Best-of-``trials`` wall per (fn, args) pair, trials interleaved
    round-robin so transient host load (shared CI runners) penalizes every
    path equally instead of whichever one it happened to land on. Each fn
    is called once up front to compile + warm."""
    for fn, args in fns:
        fn(*args).block_until_ready()
    best = [math.inf] * len(fns)
    for _ in range(trials):
        for i, (fn, args) in enumerate(fns):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def nonlin_elems(cfg, n_tokens: int) -> int:
    """Softmax (H * n^2) + GELU (n * d_ff) element count per frame."""
    return cfg.n_layers * (cfg.n_heads * n_tokens * n_tokens
                           + n_tokens * cfg.d_ff)


def frame_report(variant: str, img_size: int,
                 kept_patches: int | None = None,
                 include_mgnet: bool = False,
                 pipelined_tuning: bool = True) -> EnergyReport:
    """Full per-frame energy+latency report for one ViT workload."""
    cfg = get_config(variant, img_size=img_size)
    shapes = vit_matmul_shapes(cfg, kept_patches=kept_patches,
                               include_mgnet=include_mgnet)
    stats, tiles = accumulate_matmuls(shapes)
    n = (kept_patches if kept_patches is not None
         else (img_size // cfg.patch) ** 2) + 1
    nl = nonlin_elems(cfg, n)
    rep = energy_of_stats(stats, nl)
    lat = latency_of_stats(stats, nl, n_tiles=tiles,
                           pipelined_tuning=pipelined_tuning)
    rep.optical_us, rep.epu_us, rep.memory_us = (lat.optical_us, lat.epu_us,
                                                 lat.memory_us)
    return rep


def fmt_uj(rep: EnergyReport) -> str:
    return (f"tuning={rep.tuning_uj:.2f} vcsel={rep.vcsel_uj:.2f} "
            f"bpd={rep.bpd_uj:.2f} adc={rep.adc_uj:.2f} dac={rep.dac_uj:.2f} "
            f"mem={rep.memory_uj:.2f} epu={rep.epu_uj:.2f}")
