"""Pallas photonic-matmul kernel vs pure-jnp oracle (interpret mode).

Contract: integer accumulate must match kernels/ref.py bit-for-bit; the
f32 dequant epilogue may differ only by reassociation ulps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # seed container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import quant

pytestmark = pytest.mark.slow      # interpret-mode kernels -> CI slow job
from repro.core.photonic import photonic_matmul_exact
from repro.kernels.ops import photonic_matmul
from repro.kernels.photonic_matmul import photonic_matmul_int8
from repro.kernels.ref import photonic_matmul_ref


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int32).astype(
        jnp.int8)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    (256, 128, 128),
    (128, 256, 384),
    (384, 384, 128),
])
def test_int8_kernel_exact_vs_ref(m, k, n):
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(m + k + n), 3)
    xq = _rand_int8(kx, (m, k))
    wq = _rand_int8(kw, (k, n))
    sx = jnp.float32(0.01)
    sw = jax.random.uniform(ks, (n,), jnp.float32, 0.001, 0.1)
    out = photonic_matmul_int8(xq, wq, sx, sw)
    ref = photonic_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (128, 256, 128),
                                      (256, 128, 256)])
def test_block_shape_invariance(bm, bn, bk):
    """Grid/block decomposition must not change the integer result."""
    m, k, n = 256, 256, 256
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    xq = _rand_int8(kx, (m, k))
    wq = _rand_int8(kw, (k, n))
    sx = jnp.float32(0.02)
    sw = jnp.full((n,), 0.05, jnp.float32)
    out = photonic_matmul_int8(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk)
    ref = photonic_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(1, 300), st.integers(1, 200),
       st.integers(0, 2**31 - 1))
def test_float_api_matches_core_sim(m, k, n, seed):
    """ops.photonic_matmul (pad + int8 kernel + dequant) == the behavioural
    simulator's numerics for arbitrary (non-aligned) shapes."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    out = photonic_matmul(x, w)
    ref = photonic_matmul_exact(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_float_api_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 48)).astype(dtype)
    out = photonic_matmul(x, w)
    assert out.shape == (64, 48)
    assert not bool(jnp.isnan(out).any())


def test_leading_batch_dims():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 40))
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 24))
    out = photonic_matmul(x, w)
    assert out.shape == (2, 3, 24)
    ref = photonic_matmul_exact(x.reshape(-1, 40), w).reshape(2, 3, 24)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
