"""Micro-batch scheduler: group same-bucket frames into one encode launch.

Frames routed to the same bucket size k are queued until ``microbatch`` of
them are waiting, then flushed as one (microbatch, k, d) ``forward_vit_tokens``
call — a single warm-jit launch per flush. Frames arrive as *groups* (all
same-bucket frames of one ingest chunk come in one (m, k, d) gather output),
and the queue stores groups, so the flush is at most one concatenate — not
per-frame slicing + stacking, which at serving rates costs more dispatches
than the encode itself. End-of-stream partials are padded with zero frames
up to the micro-batch size so the encode shape set stays exactly |ladder|
(no trailing-shape recompiles); padded rows are discarded and never
accounted.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["FrameBatch", "MicroBatcher"]


@dataclass
class FrameBatch:
    """One flushed encode workload: ``tokens[:n_real]`` are live frames."""

    bucket: int                 # kept-patch count k
    tokens: jnp.ndarray         # (microbatch, k, d) — zero-padded past n_real
    frame_idx: list[int]        # len n_real, stream positions of live rows
    n_real: int


class MicroBatcher:
    """Per-bucket group queues with flush-at-``microbatch`` semantics."""

    def __init__(self, microbatch: int = 4):
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        self.microbatch = microbatch
        # k -> [(tokens (m, k, d), [frame_idx] * m)]
        self._queues: dict[int, list] = {}
        self.flushes = 0

    def push(self, bucket: int, tokens, frame_idx: int) -> list[FrameBatch]:
        """Queue a single frame (row vector of one group)."""
        return self.push_many(bucket, tokens[None], [frame_idx])

    def push_many(self, bucket: int, tokens, frame_idx: list[int]
                  ) -> list[FrameBatch]:
        """Queue a group of same-bucket frames; returns every FrameBatch
        that became ready (possibly several if the group overfills)."""
        if tokens.shape[0] != len(frame_idx):
            raise ValueError("tokens/frame_idx length mismatch")
        q = self._queues.setdefault(bucket, [])
        q.append((tokens, list(frame_idx)))
        out = []
        while self._rows(bucket) >= self.microbatch:
            out.append(self._take(bucket))
        return out

    def _rows(self, bucket: int) -> int:
        return sum(t.shape[0] for t, _ in self._queues.get(bucket, ()))

    def _take(self, bucket: int, pad: bool = False) -> FrameBatch:
        """Pop exactly ``microbatch`` rows (splitting an oversized group back
        onto the queue); with ``pad`` a short tail is zero-filled."""
        q = self._queues[bucket]
        items, idxs, rows = [], [], 0
        while q and rows < self.microbatch:
            t, ix = q.pop(0)
            need = self.microbatch - rows
            if t.shape[0] > need:
                q.insert(0, (t[need:], ix[need:]))
                t, ix = t[:need], ix[:need]
            items.append(t)
            idxs.extend(ix)
            rows += t.shape[0]
        if not q:
            self._queues.pop(bucket)
        n_real = rows
        if pad and rows < self.microbatch:
            items.append(jnp.zeros((self.microbatch - rows,)
                                   + items[0].shape[1:], items[0].dtype))
        toks = items[0] if len(items) == 1 else jnp.concatenate(items, axis=0)
        self.flushes += 1
        return FrameBatch(bucket, toks, idxs, n_real)

    def drain(self) -> list[FrameBatch]:
        """Flush every partial queue (zero-padded to the micro-batch size)."""
        return [self._take(k, pad=True) for k in sorted(self._queues)]

    @property
    def pending(self) -> int:
        return sum(self._rows(k) for k in self._queues)
