"""Streaming serving engine benchmark (repro.serving) — the paper's
near-sensor scenario as a perf trajectory.

Two measurements:

  1. **natural routing** (tiny-96, photonic-model accounting): stream the
     synthetic video with MGNet-derived budgets and record frames/s, model
     KFPS/W, the bucket-hit histogram and the mask-reuse rate — written to
     ``BENCH_serving.json`` so the perf trajectory records every run;

  2. **bucketed vs mask-mode dense** (tiny-224, pinned 50% skip): identical
     gating, one path encodes top-k-gathered tokens at the k = N/2 bucket,
     the other encodes all N patches with the RoI mask on the attention key
     axis. Gate: the bucketed path must be >= 1.5x frames/s — the shape-
     static compute reduction the serving subsystem exists to deliver.

Timing statistic: best-of-TRIALS wall per path (background load on a shared
host only ever adds time).
"""

from __future__ import annotations

import json
import os

from repro.configs.opto_vit import get_config
from repro.data.pipeline import VideoStream
from repro.serving.engine import ServingConfig, ServingEngine

TRIALS = 3
FRAMES = 96
SPEEDUP_GATE = 1.5
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _best_runs(engine, stream, frames):
    """(best bucketed StreamResult, best dense StreamResult) over TRIALS."""
    engine.run(stream, n_frames=32)            # compile + warm every bucket
    engine.run_dense(stream, n_frames=32)
    best_b = best_d = None
    for t in range(TRIALS):
        b = engine.run(stream, n_frames=frames, start=1000 + t)
        d = engine.run_dense(stream, n_frames=frames, start=1000 + t)
        if best_b is None or b.wall_s < best_b.wall_s:
            best_b = b
        if best_d is None or d.wall_s < best_d.wall_s:
            best_d = d
    return best_b, best_d


def run() -> dict:
    print("\n== streaming serving engine: RoI-gated bucketed encode ==")

    # -- 1) natural bucket routing + accelerator-model accounting ----------
    cfg96 = get_config("tiny", img_size=96, mgnet=True).with_(
        matmul_backend="bf16")
    eng96 = ServingEngine(cfg96, ServingConfig(microbatch=8, chunk=8),
                          n_classes=10)
    stream96 = VideoStream(img_size=96, patch=16, cut_every=32)
    eng96.run(stream96, n_frames=16)                       # warm
    nat = eng96.run(stream96, n_frames=FRAMES, start=500)
    print(f"  natural routing (tiny-96): {nat.fps:7.1f} frames/s  "
          f"{nat.kfps_per_watt:7.1f} KFPS/W  "
          f"(dense model: {nat.dense_kfps_per_watt:.1f})")
    print(f"  bucket hits: {nat.bucket_hits}   mgnet scored "
          f"{nat.scored_frames}/{nat.frames}")

    # -- 2) bucketed top-k vs mask-mode dense at pinned 50% skip -----------
    cfg224 = get_config("tiny", img_size=224, mgnet=True).with_(
        matmul_backend="bf16")
    sc = ServingConfig(microbatch=16, chunk=16, force_bucket=0.5)
    eng224 = ServingEngine(cfg224, sc, n_classes=10)
    stream224 = VideoStream(img_size=224, patch=16, cut_every=32)
    bucketed, dense = _best_runs(eng224, stream224, FRAMES)
    speedup = bucketed.fps / dense.fps
    print(f"  50% skip (tiny-224): bucketed {bucketed.fps:6.1f} frames/s vs "
          f"mask-mode dense {dense.fps:6.1f} frames/s -> {speedup:.2f}x")
    print(f"  model energy: {bucketed.mean_frame_uj:.2f} uJ/frame bucketed "
          f"vs {dense.mean_frame_uj:.2f} dense "
          f"({bucketed.kfps_per_watt:.1f} vs {dense.kfps_per_watt:.1f} KFPS/W)")

    payload = {}
    if os.path.exists(OUT_JSON):           # merge: attention_bench shares
        with open(OUT_JSON) as f:          # this file ("attention" key)
            payload = json.load(f)
    payload |= {
        "natural": {
            "config": "tiny-96", "frames": nat.frames, "fps": nat.fps,
            "kfps_per_watt": nat.kfps_per_watt,
            "mean_frame_uj": nat.mean_frame_uj,
            "bucket_hits": nat.bucket_hits,
            "scored_frames": nat.scored_frames,
            "reused_frames": nat.reused_frames,
        },
        "skip50": {
            "config": "tiny-224", "frames": bucketed.frames,
            "bucketed_fps": bucketed.fps, "dense_fps": dense.fps,
            "speedup": speedup,
            "bucketed_kfps_per_watt": bucketed.kfps_per_watt,
            "dense_kfps_per_watt": dense.kfps_per_watt,
        },
    }
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"  wrote {OUT_JSON}")

    assert speedup >= SPEEDUP_GATE, (
        f"bucketed top-k must beat mask-mode dense by >= {SPEEDUP_GATE}x "
        f"frames/s at 50% skip; measured {speedup:.2f}x")
    # the model-level claim of the whole subsystem: skipping patches saves
    # energy, so the gated stream's KFPS/W beats the dense baseline's
    assert nat.kfps_per_watt > nat.dense_kfps_per_watt, (
        nat.kfps_per_watt, nat.dense_kfps_per_watt)
    return payload


if __name__ == "__main__":
    run()
