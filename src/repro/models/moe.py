"""Mixture-of-Experts FFN with grouped, sort-based capacity dispatch.

Top-k softmax routing (Switch/GShard lineage). Dispatch is *grouped*
(GShard pattern): tokens are reshaped to (G, T/G) where G matches the
data-parallel shard count, and all routing/sort/capacity logic runs
*within* a group — so under GSPMD every sort/cumsum/scatter is local to a
device and the only cross-device movement is the (G, E, C, d) dispatch
buffer resharding from G-sharded to E-sharded: the expert-parallel
all-to-all, measured in the roofline collective term.

Within a group dispatch is *sort-based* (argsort by expert id + gather) —
no one-hot dispatch einsum, so the FLOP profile stays honest (the one-hot
formulation inflates HLO FLOPs by T*E*C*d, poisoning the roofline).
Over-capacity tokens are dropped (combine weight zero) — GShard semantics
with ``capacity_factor`` slack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                     # jax >= 0.6: top-level API
    _shard_map = jax.shard_map
    _SHARD_MAP_CHECK_KW = "check_vma"
except AttributeError:                   # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_CHECK_KW = "check_rep"

from repro.distributed.sharding import current_ctx, shard
from repro.models.layers import ExecPolicy, he_init
from repro.models import ffn as ffn_mod

__all__ = ["init_moe", "moe_ffn", "moe_logical_axes", "moe_ffn_shard_map"]


def init_moe(key, d: int, d_ff: int, n_experts: int, shared_experts: int = 0,
             dtype=jnp.bfloat16) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    keys = jax.random.split(ke, 3)
    params = {
        "router": he_init(kr, (d, n_experts), jnp.float32),
        # experts stacked on a leading E axis
        "w_gate": he_init(keys[0], (n_experts, d, d_ff), dtype),
        "w_up": he_init(keys[1], (n_experts, d, d_ff), dtype),
        "w_down": he_init(keys[2], (n_experts, d_ff, d), dtype),
    }
    if shared_experts:
        params["shared"] = ffn_mod.init_swiglu(ks, d, d_ff * shared_experts,
                                               dtype)
    return params


def moe_logical_axes(shared_experts: int = 0) -> dict:
    ax = {
        "router": ("p_embed", None),
        "w_gate": ("p_experts", "p_embed", None),
        "w_up": ("p_experts", "p_embed", None),
        "w_down": ("p_experts", None, "p_embed"),
    }
    if shared_experts:
        ax["shared"] = ffn_mod.swiglu_logical_axes()
    return ax


def _dispatch_group(xt, probs, top_k, cap):
    """Single-group sort-based dispatch.

    xt (T, d); probs (T, E). Returns (disp (E, C, d), combine info)."""
    t, d = xt.shape
    e = probs.shape[-1]
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)           # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_idx.reshape(-1)                          # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                              # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    rank = jnp.arange(t * top_k, dtype=jnp.int32)
    first = jnp.full((e,), t * top_k, jnp.int32).at[se].min(rank)
    slot = rank - first[se]
    keep = slot < cap
    dest = jnp.where(keep, se * cap + slot, e * cap)              # drop bucket

    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[st])
    disp = buf[: e * cap].reshape(e, cap, d)
    return disp, (st, sg, keep, dest)


def _combine_group(out, info, t, top_k, dtype):
    """out (E, C, d) -> y (T, d) weighted by gates."""
    st, sg, keep, dest = info
    e_cap, d = out.shape[0] * out.shape[1], out.shape[2]
    out_flat = out.reshape(e_cap, d)
    contrib = jnp.where(keep[:, None],
                        out_flat[jnp.clip(dest, 0, e_cap - 1)]
                        * sg[:, None].astype(out.dtype), 0)
    y = jnp.zeros((t, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    return y.astype(dtype)


def moe_ffn(params: dict, x: jnp.ndarray, *, top_k: int,
            capacity_factor: float = 1.25, groups: int = 1,
            policy: ExecPolicy | None = None,
            local_combine: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).

    groups: dispatch-group count; set to the batch-shard count so routing
    stays device-local (launch resolves it from the mesh; 1 for tests).
    aux_loss is the Switch load-balancing loss.

    local_combine (§Perf): reshard the expert outputs from E-sharded back
    to group-local BEFORE the combine gather. Without it GSPMD partitions
    the combine gather against an expert(model)-sharded buffer and falls
    back to a masked full-size all-reduce of the (T*k, d) result — the
    dominant collective in the MoE train cells (verified in the dry-run
    HLO). The explicit reshard lowers to one bf16 all-gather of the
    (E, C, d) slab per group instead.
    """
    b, s, d = x.shape
    e = params["w_gate"].shape[0]
    t = b * s
    g = min(groups, b)
    while b % g:                       # groups must divide batch
        g -= 1
    tg = t // g
    xt = x.reshape(g, tg, d)
    xt = shard(xt, "batch", None, None)

    logits = (xt.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))             # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # load-balance aux (global statistics — scalars, cheap collectives)
    me = probs.mean(axis=(0, 1))
    _, top_idx = jax.lax.top_k(probs, top_k)
    load = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(1.0) \
        / (t * top_k)
    aux = e * jnp.sum(me * load)

    cap = max(int(capacity_factor * tg * top_k / e), 1)

    disp, info = jax.vmap(
        lambda xg, pg: _dispatch_group(xg, pg, top_k, cap))(xt, probs)
    # (G, E, C, d): reshard G-sharded -> E-sharded  == the EP all-to-all
    disp = shard(disp, "batch", "experts", None, None)

    def expert_mm(h, w):               # (G,E,C,a) x (E,a,b) -> (G,E,C,b)
        if jax.default_backend() == "cpu":
            # CPU DotThunk can't execute batched bf16 x bf16 -> f32; smoke
            # tests upcast. TPU keeps bf16 operands on the MXU.
            return jnp.einsum("geca,eab->gecb", h.astype(jnp.float32),
                              w.astype(jnp.float32)).astype(h.dtype)
        return jnp.einsum("geca,eab->gecb", h, w,
                          preferred_element_type=jnp.float32).astype(h.dtype)

    gt = expert_mm(disp, params["w_gate"])
    up = expert_mm(disp, params["w_up"])
    hh = jax.nn.silu(gt.astype(jnp.float32)).astype(x.dtype) * up
    hh = shard(hh, "batch", "experts", None, None)
    out = expert_mm(hh, params["w_down"])                         # (G,E,C,d)
    if local_combine:
        # reverse EP reshard: E back to replicated-within-group so the
        # combine gather below is provably local (one all-gather, no
        # masked all-reduce fallback).
        out = shard(out, "batch", None, None, None)
    else:
        out = shard(out, "batch", "experts", None, None)

    y = jax.vmap(lambda og, ig: _combine_group(og, ig, tg, top_k, x.dtype)
                 )(out, info)
    y = y.reshape(b, s, d)

    if "shared" in params:
        y = y + ffn_mod.swiglu(params["shared"], x, policy)
    return y, aux


# --------------------------------------------------------------------------
# explicit expert-parallel path (shard_map) — §Perf "beyond" optimization
# --------------------------------------------------------------------------

def moe_ffn_shard_map(params: dict, x: jnp.ndarray, *, top_k: int,
                      capacity_factor: float = 1.25,
                      policy: ExecPolicy | None = None
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Manual expert-parallel MoE under jax.shard_map.

    Layout exploited: activations are batch-sharded over the DP axes and
    REPLICATED along "model"; expert weights are expert-sharded over
    "model" (+ FSDP over "data" on d_model). Consequences:

      * dispatch needs NO communication — every model peer holds the same
        local tokens, routes them identically, and slices out the rows of
        the capacity buffer belonging to its own experts;
      * FSDP weight gather is an explicit `all_gather` over "data" (same
        wire bytes GSPMD pays);
      * combine is a PARTIAL combine + `psum` over "model": each shard
        scatters only its local experts' outputs into a (T, d) zero
        buffer; the psum both sums multi-expert contributions and restores
        model-replication. Wire = 2·T·d vs the GSPMD fallback's masked
        all-reduce of the f32 (T·k, d) buffer (k·2x more) or
        `moe_local_combine`'s (E·C, d) all-gather (cf·k/2 x more).

    Falls back to the GSPMD path when no mesh ctx is installed or shapes
    don't divide (smoke tests, odd batches).
    """
    ctx = current_ctx()
    b, s, d = x.shape
    e = params["w_gate"].shape[0]
    if ctx is None:
        return moe_ffn(params, x, top_k=top_k,
                       capacity_factor=capacity_factor, policy=policy)
    mesh = ctx.mesh
    batch_rule = ctx.rules.get("batch")
    batch_axes = (batch_rule,) if isinstance(batch_rule, str) else \
        tuple(batch_rule or ())
    embed_rule = ctx.rules.get("p_embed")      # FSDP axes of the d dim
    embed_axes = (embed_rule,) if isinstance(embed_rule, str) else \
        tuple(embed_rule or ())
    m_sz = mesh.shape.get("model", 1)
    dp_sz = 1
    for a in batch_axes:
        dp_sz *= mesh.shape[a]
    fsdp_sz = 1
    for a in embed_axes:
        fsdp_sz *= mesh.shape[a]
    if (b % dp_sz) or (e % m_sz) or (d % fsdp_sz):
        return moe_ffn(params, x, top_k=top_k,
                       capacity_factor=capacity_factor, policy=policy)

    e_loc = e // m_sz
    t_loc = (b // dp_sz) * s
    cap = max(int(capacity_factor * t_loc * top_k / e), 1)

    def body(x_loc, router, wg, wu, wd):
        bl = x_loc.shape[0]
        xt = x_loc.reshape(t_loc, d)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)

        # Switch aux loss from local stats, averaged over the DP axes
        me = probs.mean(axis=0)
        _, top_idx = jax.lax.top_k(probs, top_k)
        load = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
            1.0) / (t_loc * top_k)
        aux = e * jnp.sum(me * load)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)

        disp, (st, sg, keep, dest) = _dispatch_group(xt, probs, top_k, cap)

        # my experts' rows only — dispatch communication-free
        midx = jax.lax.axis_index("model")
        my = jax.lax.dynamic_slice_in_dim(disp, midx * e_loc, e_loc, axis=0)

        # explicit FSDP gather of this shard's expert weights (d_model dim)
        wg_f = jax.lax.all_gather(wg, embed_axes, axis=1, tiled=True)
        wu_f = jax.lax.all_gather(wu, embed_axes, axis=1, tiled=True)
        wd_f = jax.lax.all_gather(wd, embed_axes, axis=2, tiled=True)

        def mm(h, w):
            if jax.default_backend() == "cpu":
                return jnp.einsum("eca,eab->ecb", h.astype(jnp.float32),
                                  w.astype(jnp.float32)).astype(h.dtype)
            return jnp.einsum("eca,eab->ecb", h, w,
                              preferred_element_type=jnp.float32
                              ).astype(h.dtype)

        gt = mm(my, wg_f)
        up = mm(my, wu_f)
        hh = jax.nn.silu(gt.astype(jnp.float32)).astype(x_loc.dtype) * up
        out = mm(hh, wd_f)                       # (E_loc, C, d)

        # partial combine: only slots owned by this shard contribute
        lo = midx * e_loc * cap
        dest_l = dest - lo
        mine = keep & (dest_l >= 0) & (dest_l < e_loc * cap)
        out_flat = out.reshape(e_loc * cap, d)
        contrib = jnp.where(
            mine[:, None],
            out_flat[jnp.clip(dest_l, 0, e_loc * cap - 1)]
            * sg[:, None].astype(out.dtype), 0)
        y = jnp.zeros((t_loc, d), jnp.float32).at[st].add(
            contrib.astype(jnp.float32))
        y = jax.lax.psum(y, "model")             # sum experts + re-replicate
        return y.astype(x_loc.dtype).reshape(bl, s, d), aux

    x_spec = P(batch_rule, None, None)
    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P("model", embed_rule, None), P("model", embed_rule, None),
                  P("model", None, embed_rule)),
        out_specs=(x_spec, P()),
        **{_SHARD_MAP_CHECK_KW: False},
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])

    if "shared" in params:
        y = y + ffn_mod.swiglu(params["shared"], x, policy)
    return y, aux
