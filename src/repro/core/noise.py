"""Microring-resonator (MR) device model: crosstalk, resolution, FPV.

Implements the paper's §IV "MR Resolution Analysis" verbatim:

    phi(i, j) = delta^2 / ((lambda_i - lambda_j)^2 + delta^2)
    delta     = lambda / (2 * Q_factor)
    P_noise   = sum_j phi(i, j) * P_in[j]          (j != i)
    Resolution (levels) = 1 / max_i |P_noise(i)|

and the derived claim: >= 8-bit resolution requires Q ~= 5000 for the 32-channel
WDM grid. The model also provides multiplicative transmission-error sampling
used by the photonic matmul simulator (core/photonic.py) to study accuracy
under fabrication-process variation (FPV).

All wavelengths are in nanometres. The paper does not state its channel
spacing; the default grid spreads 32 channels at 4.8 nm centred on 1550 nm —
calibrated (see tests/test_noise.py) so that the paper's claim "8-bit
resolution requires Q ~= 5000" reproduces exactly under the full crosstalk
sum. (At DWDM 0.8 nm spacing the same formula would require Q ~= 28k; the
free parameter is the grid, which the paper leaves open.)
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MRConfig",
    "wavelength_grid",
    "crosstalk_matrix",
    "noise_power",
    "resolution_bits",
    "required_q_factor",
    "transmission_error",
]


@dataclass(frozen=True)
class MRConfig:
    """Photonic device constants (paper §IV: Q=5000, 32 channels, C-band)."""

    n_channels: int = 32          # WDM wavelength channels (= VCSEL count)
    q_factor: float = 5000.0      # MR quality factor
    center_nm: float = 1550.0     # C-band centre
    spacing_nm: float = 4.8       # calibrated: Q=5000 <-> 8-bit resolution
    # geometry (paper: 400nm input wg, 760nm ring wg, 5um radius) — recorded
    # for documentation; the behavioural model depends only on Q and the grid.
    ring_radius_um: float = 5.0
    input_wg_nm: float = 400.0
    ring_wg_nm: float = 760.0


def wavelength_grid(cfg: MRConfig) -> jnp.ndarray:
    """Channel wavelengths lambda_i (nm), centred on cfg.center_nm."""
    n = cfg.n_channels
    offsets = (jnp.arange(n) - (n - 1) / 2.0) * cfg.spacing_nm
    return cfg.center_nm + offsets


def crosstalk_matrix(cfg: MRConfig) -> jnp.ndarray:
    """phi[i, j]: fraction of channel j's power leaking into channel i.

    phi(i,j) = delta^2 / ((li - lj)^2 + delta^2), delta = lambda/(2Q).
    Diagonal is zeroed (a channel is not its own noise).
    """
    lam = wavelength_grid(cfg)
    delta = lam / (2.0 * cfg.q_factor)          # per-channel linewidth (nm)
    diff2 = (lam[:, None] - lam[None, :]) ** 2
    phi = (delta[:, None] ** 2) / (diff2 + delta[:, None] ** 2)
    return phi * (1.0 - jnp.eye(cfg.n_channels))


def noise_power(cfg: MRConfig, p_in: jnp.ndarray | None = None) -> jnp.ndarray:
    """P_noise[i] = sum_j phi(i,j) * P_in[j] for input power vector p_in.

    The paper evaluates at P_in = 1 (worst case: all channels at full power).
    """
    phi = crosstalk_matrix(cfg)
    if p_in is None:
        p_in = jnp.ones((cfg.n_channels,))
    return phi @ p_in


def resolution_bits(cfg: MRConfig) -> float:
    """Achievable bit resolution = log2(1 / max|P_noise|)."""
    p_noise = noise_power(cfg)
    levels = 1.0 / float(jnp.max(jnp.abs(p_noise)))
    return math.log2(levels)


def required_q_factor(target_bits: float = 8.0, cfg: MRConfig | None = None,
                      q_lo: float = 100.0, q_hi: float = 1e6) -> float:
    """Bisect the minimum Q-factor achieving ``target_bits`` resolution.

    Reproduces the paper's finding that 8-bit needs Q ~= 5000 (the exact
    number depends on the grid spacing; with the 0.8 nm/32ch grid the
    crossover lands in the low thousands, same order as the paper).
    """
    base = cfg or MRConfig()

    def bits_at(q):
        return resolution_bits(MRConfig(
            n_channels=base.n_channels, q_factor=q,
            center_nm=base.center_nm, spacing_nm=base.spacing_nm))

    lo, hi = q_lo, q_hi
    if bits_at(hi) < target_bits:
        raise ValueError("target resolution unreachable within q_hi")
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if bits_at(mid) >= target_bits:
            hi = mid
        else:
            lo = mid
    return hi


def transmission_error(key: jax.Array, shape: tuple[int, ...],
                       cfg: MRConfig | None = None,
                       fpv_sigma: float = 0.0) -> jnp.ndarray:
    """Multiplicative weight-transmission error for the photonic matmul sim.

    Two components:
      * deterministic crosstalk floor: worst-case noise power of the WDM grid
        (bounded by 2^-resolution_bits) treated as a uniform error bound;
      * fabrication-process variation (FPV): gaussian perturbation of the
        effective transmission with std ``fpv_sigma`` (0 disables).

    Returns a multiplier M with E[M] = 1; apply as ``w_effective = w * M``.
    """
    cfg = cfg or MRConfig()
    floor = 2.0 ** (-resolution_bits(cfg))
    u = jax.random.uniform(key, shape, minval=-floor, maxval=floor)
    m = 1.0 + u
    if fpv_sigma > 0.0:
        key2 = jax.random.split(key)[0]
        m = m * (1.0 + fpv_sigma * jax.random.normal(key2, shape))
    return m
