"""Serving control plane tests: HLO cost model pricing + AOT parity, the
telemetry ring buffer, calibration accuracy on synthetic observations,
hysteresis / clamp / watchdog guard rails, the scheduler's threshold-flush
surface, and end-to-end autotuned-vs-static prediction parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import VideoStream, video_fleet
from repro.serving.control import (Controller, ControllerConfig,
                                   FlushTelemetry, TunedKnobs)
from repro.serving.engine import _smoke_cfg
from repro.serving.scheduler import MicroBatcher
from repro.serving.server import ServerConfig, StreamServer
from repro.serving.session import ServingConfig


def _autotuned_server(sc: ServingConfig, n_streams: int = 2,
                      frames: int = 12, **overrides) -> StreamServer:
    cfg = _smoke_cfg("bf16")
    srv = StreamServer(cfg, ServerConfig.from_serving(
        sc, warm_start=False, autotune=True, **overrides), n_classes=10)
    for i, st in enumerate(video_fleet(n_streams, img_size=cfg.img_size,
                                       patch=cfg.patch, cut_every=32)):
        srv.add_session(st, n_frames=frames, start=16 * i)
    srv.autotune_prepare()
    return srv


# --------------------------------------------------------------------------
# cost model: pricing sanity + AOT executable parity
# --------------------------------------------------------------------------

def test_cost_model_prices_probed_buckets():
    """Natural routing: every probed bucket gets a priced BucketCost with
    positive FLOPs/bytes/latency/energy, and cost grows with bucket size."""
    srv = _autotuned_server(ServingConfig(microbatch=2, chunk=4))
    cm = srv.cost_model
    assert cm is not None and cm.costs, "probe must price >= 1 bucket"
    for k in srv.ladder.sizes:                 # lazy pricing fills the rest
        cm.ensure(k)
    ks = sorted(cm.costs)
    for k in ks:
        c = cm.costs[k]
        assert c.flops > 0 and c.hbm_bytes > 0
        assert c.device_s > 0 and c.energy_uj > 0 and c.photonic_us > 0
        assert c.microbatch == 2
    flops = [cm.costs[k].flops for k in ks]
    uj = [cm.costs[k].energy_uj for k in ks]
    assert flops == sorted(flops), "more kept patches -> more FLOPs"
    assert uj == sorted(uj), "more kept patches -> more photonic energy"
    assert "pred us" in cm.render()


def test_cost_model_ensure_rejects_off_ladder_bucket():
    srv = _autotuned_server(ServingConfig(microbatch=2, chunk=4))
    with pytest.raises(KeyError):
        srv.cost_model.ensure(max(srv.ladder.sizes) + 1)


def test_aot_executable_matches_jit_bitwise():
    """The cost model's compiled executables serve as the AOT encode path;
    they must produce bit-identical logits to the jit ladder."""
    srv = _autotuned_server(ServingConfig(microbatch=2, chunk=4))
    if srv.mesh is not None:
        pytest.skip(f"{len(jax.devices())} visible devices -> mesh-sharded "
                    "encode owns the ladder; AOT install is single-device")
    assert srv._encode_aot, "off-mesh autotune must install AOT executables"
    k = sorted(srv._encode_aot)[0]
    img = srv.cfg.img_size
    toks = srv._embed(srv.params, jnp.zeros((4, img, img, 3), jnp.float32))
    toks = toks[:2, :k, :]
    np.testing.assert_array_equal(
        np.asarray(srv._encode_aot[k](srv.params, toks)),
        np.asarray(srv._encode(srv.params, toks)))


# --------------------------------------------------------------------------
# telemetry ring buffer
# --------------------------------------------------------------------------

def test_telemetry_window_evicts_oldest():
    tel = FlushTelemetry(window=4)
    for i in range(6):
        tel.record(bucket=8, n_real=2, microbatch=4, n_streams=1,
                   wall_s=float(i))
    assert len(tel) == 4                       # window holds the newest 4
    assert tel.total_recorded == 6 and tel.seq == 6
    assert tel.latencies(8) == [2.0, 3.0, 4.0, 5.0]
    assert tel.latencies(8, min_seq=4) == [4.0, 5.0]
    assert tel.occupancy() == pytest.approx(0.5)
    assert tel.median_latency(8) == pytest.approx(3.5)
    assert tel.median_latency(99) is None


def test_telemetry_per_bucket_views():
    tel = FlushTelemetry(window=8)
    tel.record(4, 4, 4, 2, 0.1)
    tel.record(8, 2, 4, 1, 0.2)
    tel.record(8, 4, 4, 3, 0.3)
    by = tel.by_bucket()
    assert sorted(by) == [4, 8] and len(by[8]) == 2
    assert tel.occupancy(8) == pytest.approx(0.75)
    assert tel.mean_streams() == pytest.approx(2.0)
    with pytest.raises(ValueError):
        FlushTelemetry(window=0)


# --------------------------------------------------------------------------
# calibration on synthetic observations
# --------------------------------------------------------------------------

class _StubCostModel:
    """Known raw predictions, no compiles."""

    def __init__(self, preds: dict, microbatch: int = 4):
        self.microbatch = microbatch
        self.costs = dict(preds)
        self._builders = {}
        self._preds = preds

    def predicted_flush_s(self, bucket: int) -> float:
        return self._preds[bucket]


def _stub_controller(preds=None, cc=None, window=64):
    cm = _StubCostModel(preds or {4: 1e-5, 8: 2e-5, 16: 4e-5})
    return Controller(cm, FlushTelemetry(window), TunedKnobs(),
                      cc or ControllerConfig())


def test_calibration_recovers_linear_map():
    """obs = 3 * pred + 0.01 exactly -> the fit recovers (a, b) and the
    calibrated predictions land within 1% of the observations."""
    ctl = _stub_controller()
    for k, p in ctl.cost_model._preds.items():
        for i in range(6):                     # > burn_in + min_samples
            ctl.record_flush(k, n_real=4, n_streams=2,
                             wall_s=3.0 * p + 0.01)
    assert ctl.calibrate() and ctl.calibrated
    a, b = ctl._fit
    assert a == pytest.approx(3.0, rel=1e-6)
    assert b == pytest.approx(0.01, rel=1e-6)
    for k, p in ctl.cost_model._preds.items():
        assert ctl.predict_flush_s(k) == pytest.approx(3.0 * p + 0.01,
                                                       rel=0.01)
    assert ctl.median_rel_error(holdout=False) == pytest.approx(0.0,
                                                                abs=1e-6)


def test_calibration_single_bucket_fits_through_origin():
    ctl = _stub_controller(preds={8: 2e-5})
    for _ in range(4):
        ctl.record_flush(8, 4, 1, wall_s=6e-5)
    assert ctl.calibrate()
    a, b = ctl._fit
    assert b == 0.0 and ctl.predict_flush_s(8) == pytest.approx(6e-5)


def test_holdout_split_scores_only_post_fit_observations():
    ctl = _stub_controller(preds={8: 2e-5})
    for _ in range(4):
        ctl.record_flush(8, 4, 1, wall_s=6e-5)
    ctl.calibrate()
    assert ctl.median_rel_error() is None      # nothing recorded since fit
    ctl.record_flush(8, 4, 1, wall_s=12e-5)    # workload shifted 2x
    assert ctl.median_rel_error() == pytest.approx(0.5)


# --------------------------------------------------------------------------
# guard rails: hysteresis, clamp, watchdog
# --------------------------------------------------------------------------

def test_hysteresis_defers_then_applies():
    """A persistent low-occupancy signal must survive ``hysteresis``
    consecutive steps before the knobs move."""
    ctl = _stub_controller(cc=ControllerConfig(hysteresis=2))
    for k, _ in ctl.cost_model._preds.items():
        for _ in range(6):
            ctl.record_flush(k, n_real=2, n_streams=2, wall_s=1e-4)  # 50%
    assert ctl.step({}, 16, 1.0) is False      # pending, not applied
    assert ctl.applied_retunes == 0
    assert ctl.knobs.key() == ctl.defaults.key()
    assert ctl.step({}, 32, 2.0) is True       # second identical rec lands
    assert ctl.applied_retunes == 1
    assert ctl.knobs.max_wait_chunks > 0
    assert ctl.knobs.flush_threshold           # partial buckets got one
    assert ctl.converged                       # applied == fixed point
    assert ctl.clamp_violations == 0


def test_full_occupancy_recommends_defaults():
    ctl = _stub_controller()
    for k in ctl.cost_model._preds:
        for _ in range(4):
            ctl.record_flush(k, n_real=4, n_streams=2, wall_s=1e-4)
    assert ctl.step({}, 16, 1.0) is False
    assert ctl.knobs.key() == ctl.defaults.key()
    assert ctl.converged


def test_clamp_forces_box_and_counts():
    ctl = _stub_controller()
    wild = TunedKnobs(max_wait_chunks=99, interleave_depth=0,
                      flush_threshold={8: 999, 16: 0})
    out = ctl._clamp(wild)
    assert ctl._in_bounds(out) and not ctl._in_bounds(wild)
    assert 0 <= out.max_wait_chunks <= ctl.cc.max_wait_bound
    assert out.interleave_depth == 1
    assert out.flush_threshold == {8: 4, 16: 2}
    assert ctl.clamp_engaged == 1 and ctl.clamp_violations == 0


def test_watchdog_reverts_and_freezes():
    """Tuned knobs that lose >= safety_margin of the default-knob fps must
    revert to the defaults and freeze the controller."""
    ctl = _stub_controller()
    assert ctl.step({}, 100, 1.0) is False     # baseline: 100 fps
    assert ctl._baseline_fps == pytest.approx(100.0)
    ctl.knobs.set_to(TunedKnobs(max_wait_chunks=2))   # tuned knobs live
    assert ctl.step({}, 110, 2.0) is True      # 10 fps << 75 fps floor
    assert ctl.frozen
    assert ctl.knobs.key() == ctl.defaults.key()
    assert not ctl.converged                   # frozen is never converged
    assert ctl.step({}, 120, 3.0) is False     # frozen: holds defaults


# --------------------------------------------------------------------------
# scheduler: threshold flush + queue stats (the knobs' mechanism)
# --------------------------------------------------------------------------

def test_flush_filled_threshold_and_queue_stats():
    mb = MicroBatcher(microbatch=4)
    mb.push_many(8, jnp.ones((3, 2, 2)), [0, 1, 2], now=5)
    mb.push_many(16, jnp.ones((1, 4, 2)), [3], now=6)
    assert mb.queue_stats() == {8: (3, 5), 16: (1, 6)}
    assert mb.rows(8) == 3 and mb.rows(99) == 0
    out = mb.flush_filled(lambda k: 3)
    assert len(out) == 1 and out[0].bucket == 8 and out[0].n_real == 3
    assert out[0].tokens.shape[0] == 4         # padded to the micro-batch
    assert mb.rows(8) == 0 and mb.rows(16) == 1
    # thresholds at/above the micro-batch never fire here
    assert mb.flush_filled(lambda k: 4) == []


# --------------------------------------------------------------------------
# end-to-end: autotuned serving changes timing, never predictions
# --------------------------------------------------------------------------

def test_autotune_prediction_parity_with_static_server():
    cfg = _smoke_cfg("bf16")
    sc = ServingConfig(microbatch=2, chunk=4, force_bucket=0.5)
    frames, n_streams = 12, 2

    def _serve(autotune: bool):
        srv = StreamServer(cfg, ServerConfig.from_serving(
            sc, warm_start=False, autotune=autotune, retune_every=4),
            n_classes=10)
        sessions = [srv.add_session(st, n_frames=frames, start=16 * i)
                    for i, st in enumerate(video_fleet(
                        n_streams, img_size=cfg.img_size, patch=cfg.patch,
                        cut_every=32))]
        if autotune:
            srv.autotune_prepare()
        else:
            srv.warm_start()
        results = srv.serve()
        return srv, [results[s.sid] for s in sessions]

    srv_a, auto = _serve(True)
    _, static = _serve(False)
    for i, (ra, rs) in enumerate(zip(auto, static)):
        assert ra.predictions == rs.predictions, (
            f"stream {i}: autotuning must never change predictions")
        assert ra.flush_wall_ms, "timed flushes must surface per bucket"
        assert not rs.flush_wall_ms, "untimed server must not fabricate"
        assert all(v > 0 for v in ra.flush_wall_ms.values())
    ctl = srv_a.controller
    assert ctl.clamp_violations == 0
    assert ctl.calibrated
    assert len(srv_a.telemetry) > 0
