"""Exact collectives for the model-sharded fused serving path.

These are the *bitwise-exact* primitives that let the fused int8 kernels
run under ``shard_map`` over the 2-D ("data", "model") serving mesh while
staying prediction-identical to the unsharded path:

``replicated_absmax_scale``
    Per-launch activation absmax scale with a *global* scope: the local
    absmax is pmax'd over the given mesh axes before the epsilon clamp
    and the reciprocal-multiply. max is associative and the subsequent
    ops replicate ``core.quant.absmax_scale``'s exact op order, so every
    shard computes the same f32 scale the unsharded launch would — the
    quantized codes (and therefore the int32 accumulates) match bitwise.

``exact_int_psum``
    Integer partial-sum reduction over the model axis (the fused FFN's
    d_ff contraction). Integer addition is associative and lossless in
    int32 (n_devices * n_k * 127 * 127 stays far under 2^31 for every
    config here), so the reduced accumulate equals the unsharded
    contraction exactly — the float epilogue then sees identical inputs.

The previous occupants (``compressed_psum`` / ``compressed_allreduce_tree``,
lossy int8 gradient all-reduce) had zero callers anywhere in the repo and
were removed; lossy reduction is the opposite of what the serving path
needs (bitwise parity is the contract every serving test pins).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant

__all__ = ["replicated_absmax_scale", "exact_int_psum"]


def replicated_absmax_scale(x: jnp.ndarray, bits: int,
                            axis_names, eps: float = 1e-8) -> jnp.ndarray:
    """Global per-tensor absmax quantization scale inside ``shard_map``.

    Mirrors ``core.quant.absmax_scale(x, bits)`` exactly — same epsilon
    clamp, same reciprocal-multiply (never a divide) — with one pmax over
    ``axis_names`` inserted between the local max and the clamp. Pass
    every mesh axis the launch's rows are split over (both ``"data"`` and
    ``"model"`` when the batch axis is sharded too): the result is the
    scale the *unsharded* launch would compute, replicated on all shards.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    _, qmax = quant.quant_range(bits)
    inv_qmax = jnp.float32(1.0 / qmax)
    amax = jnp.max(jnp.abs(x))
    amax = jax.lax.pmax(amax, tuple(axis_names))
    amax = jnp.maximum(amax, eps)
    return amax.astype(jnp.float32) * inv_qmax


def exact_int_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Lossless integer psum of partial accumulates over one mesh axis.

    Guards the dtype: the whole point is that *integer* partial sums
    reduce exactly (addition is associative, no rounding), so a float
    input is a caller bug — it would reintroduce reduction-order
    nondeterminism that the int8 serving path exists to exclude.
    """
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"exact_int_psum needs an integer dtype (got "
                        f"{x.dtype}): float partial sums do not reduce "
                        f"bitwise-exactly")
    return jax.lax.psum(x, axis_name)
