"""Streaming KFPS/W accounting over the cross-layer accelerator model.

Every encode flush of bucket k adds ``n_real`` frames' worth of the
``vit_matmul_shapes(kept_patches=k)`` event counts; every MGNet invocation
adds the mask-generator's own shapes (frames that *reused* a cached mask pay
nothing — the serving engine's energy win over per-frame scoring). The
aggregate divides out to the paper's Table-4 metric: KFPS/W of a pipelined
accelerator is frames-per-joule / 1000, i.e. 1 / mean-E-frame[mJ] —
independent of host wall time, which is reported separately as frames/s of
the functional simulation.

Two report builders are exposed at module level because the serving control
plane's cost model (``serving/control/costmodel.py``) prices the same
buckets the accounting bills: ``bucket_report`` (one k-patch encode frame)
and ``mgnet_report`` (one mask-generator invocation).

Besides the *modeled* numbers, the accounting can carry *measured*
per-flush wall latencies (``add_flush_wall``, fed by the server's flush
timer when autotuning is on) — ``summary()`` then prints measured ms next
to the modeled us per bucket, and the controller calibrates its cost model
against exactly these observations.

``summary()`` additionally surfaces per-bucket hit/launch counts and warns
on **dead buckets** — ladder entries no stream frame ever routed to. Every
ladder entry costs one compiled encode shape (and, in one-shape mode, one
kv_len-specialized jit), so a bucket with zero hits is pure compile-time
waste and a signal the ladder fractions need retuning for the stream's
budget distribution (see README "Bucket-ladder tuning").
"""

from __future__ import annotations

import warnings
from collections import Counter
from dataclasses import fields as _dc_fields
from typing import Iterable

from repro.configs.base import ArchConfig
from repro.core.energy import (EnergyReport, accumulate_matmuls,
                               energy_of_stats, kfps_per_watt,
                               latency_of_stats, scale_for_bits)
from repro.models.vit import vit_matmul_shapes

__all__ = ["StreamAccounting", "bucket_report", "mgnet_report",
           "retune_report"]


def _nonlin_elems(cfg: ArchConfig, n_tokens: int) -> int:
    """Softmax (H * n^2) + GELU (n * d_ff) element count per frame."""
    return cfg.n_layers * (cfg.n_heads * n_tokens * n_tokens
                           + n_tokens * cfg.d_ff)


# index layout of one layer's chunk in vit_matmul_shapes: q, k, v,
# scores, attn@v, out-proj, mlp w1, mlp w2
_WEIGHT_IDX = (0, 1, 2, 5, 6, 7)
_ACT_IDX = (3, 4)


def _mixed_bits_report(cfg: ArchConfig, shapes: list, nl: int,
                       layer_bits: tuple) -> EnergyReport:
    """Energy *and* latency with each layer's weight-stationary matmuls
    scaled to its planned width: the MR tuning, ADC/DAC conversion and
    SRAM code traffic of the q/k/v, out-projection and both MLP matmuls
    pay ``bits/8`` of the calibrated 8-bit constants — in energy
    (``scale_for_bits``) and in the ADC/SRAM stage latencies
    (``latency_of_stats(bits=...)``). The activation-activation score/PV
    matmuls and the patch embed stay at the default width. Only one
    pipelined tuning exposure is counted across the whole frame
    (``exposed_tunings``), so a uniform-8 plan is bit-exact to the
    aggregate ``energy_of_stats``/``latency_of_stats`` path."""
    embed_stats, _ = accumulate_matmuls(shapes[:1])
    rep = energy_of_stats(embed_stats, nl)
    lat = latency_of_stats(embed_stats, nl, exposed_tunings=1)
    for li, bits in enumerate(layer_bits):
        chunk = shapes[1 + 8 * li: 1 + 8 * (li + 1)]
        w_stats, _ = accumulate_matmuls([chunk[i] for i in _WEIGHT_IDX])
        a_stats, _ = accumulate_matmuls([chunk[i] for i in _ACT_IDX])
        rep += scale_for_bits(energy_of_stats(w_stats), bits)
        rep += energy_of_stats(a_stats)
        lat += latency_of_stats(w_stats, bits=bits, exposed_tunings=0)
        lat += latency_of_stats(a_stats, exposed_tunings=0)
    rep.optical_us, rep.epu_us, rep.memory_us = (
        lat.optical_us, lat.epu_us, lat.memory_us)
    return rep


def bucket_report(cfg: ArchConfig, bucket: int,
                  layer_bits: Iterable[int] | None = None) -> EnergyReport:
    """Per-frame accelerator-model report for one k-patch encode (backbone
    only): energy components + optical/EPU/memory latency. ``layer_bits``
    (one width per encoder layer — ``core.bitalloc.plan_layer_bits``)
    switches to the width-aware mixed-precision path."""
    n_patches = (cfg.img_size // cfg.patch) ** 2
    kept = None if bucket >= n_patches else bucket
    shapes = vit_matmul_shapes(cfg, kept_patches=kept)
    stats, tiles = accumulate_matmuls(shapes)
    nl = _nonlin_elems(cfg, bucket + 1)
    lb = tuple(int(b) for b in layer_bits) if layer_bits is not None else None
    if lb is not None and len(shapes) == 1 + 8 * cfg.n_layers:
        return _mixed_bits_report(cfg, shapes, nl, lb)
    rep = energy_of_stats(stats, nl)
    lat = latency_of_stats(stats, nl, n_tiles=tiles)
    rep.optical_us, rep.epu_us, rep.memory_us = (
        lat.optical_us, lat.epu_us, lat.memory_us)
    return rep


def mgnet_report(cfg: ArchConfig) -> EnergyReport:
    """Per-invocation MGNet report (the shapes ``include_mgnet`` appends
    after the backbone's)."""
    base = vit_matmul_shapes(cfg)
    full = vit_matmul_shapes(cfg, include_mgnet=True)
    stats, tiles = accumulate_matmuls(full[len(base):])
    rep = energy_of_stats(stats)
    lat = latency_of_stats(stats, n_tiles=tiles)
    rep.optical_us, rep.epu_us, rep.memory_us = (
        lat.optical_us, lat.epu_us, lat.memory_us)
    return rep


def retune_report(cfg: ArchConfig,
                  layer_bits: Iterable[int] | None = None) -> EnergyReport:
    """Energy of one full-model MR re-tuning pass (drift-triggered online
    recalibration): every weight-stationary bank's codes are re-driven once
    — one tuning event + one tuning-DAC conversion per MR, at the dense
    (full kept-patch) tile grid. The activation-activation score/PV
    matmuls are dynamically tuned every cycle anyway and pay nothing extra.
    ``layer_bits`` scales each layer's tuning energy to its planned width,
    mirroring ``_mixed_bits_report``."""
    from repro.core.photonic import PhotonicOpStats

    shapes = vit_matmul_shapes(cfg)

    def tune_only(sel_shapes):
        stats, _ = accumulate_matmuls(sel_shapes)
        t = stats.mr_tunings
        return energy_of_stats(PhotonicOpStats(mr_tunings=t,
                                               dac_conversions=t))

    rep = tune_only(shapes[:1])            # patch embed bank
    lb = (tuple(int(b) for b in layer_bits)
          if layer_bits is not None else None)
    per_layer = (len(shapes) == 1 + 8 * cfg.n_layers)
    if lb is not None and per_layer:
        for li, bits in enumerate(lb):
            chunk = shapes[1 + 8 * li: 1 + 8 * (li + 1)]
            rep += scale_for_bits(
                tune_only([chunk[i] for i in _WEIGHT_IDX]), bits)
    elif per_layer:
        for li in range(cfg.n_layers):
            chunk = shapes[1 + 8 * li: 1 + 8 * (li + 1)]
            rep += tune_only([chunk[i] for i in _WEIGHT_IDX])
    else:                                   # non-standard shape list
        rep += tune_only(shapes[1:])
    return rep


class StreamAccounting:
    """Accumulates per-frame EnergyReports bucket-by-bucket.

    ``layer_bits`` (a mixed-precision bit plan's energy view) scales each
    layer's *weight-stationary* matmul energy **and** its ADC/SRAM stage
    latencies by its actual width (``bucket_report`` above): a lower
    width now buys both energy per frame and modeled wall time, which is
    what lets the control-plane cost model rank bit plans honestly.
    The activation-activation score/PV matmuls and the patch embed stay
    at the default width.

    Measured flush wall times land here too (``add_flush_wall``): the
    modeled accelerator latency and the observed host latency live side
    by side, per bucket, so ``summary()`` and the autotune controller
    can compare them without a separate bookkeeping object."""

    _WEIGHT_IDX = _WEIGHT_IDX
    _ACT_IDX = _ACT_IDX

    def __init__(self, cfg: ArchConfig,
                 ladder_sizes: Iterable[int] | None = None,
                 layer_bits: Iterable[int] | None = None):
        self.cfg = cfg
        self.total = EnergyReport()
        self.frames = 0
        self.scored_frames = 0
        # per-bucket stream telemetry: frames routed (hits) and encode
        # launches (the first launch of a bucket is its jit compile)
        self.ladder_sizes = (tuple(int(k) for k in ladder_sizes)
                             if ladder_sizes is not None else None)
        self.layer_bits = (tuple(int(b) for b in layer_bits)
                           if layer_bits is not None else None)
        if (self.layer_bits is not None
                and len(self.layer_bits) != cfg.n_layers):
            raise ValueError(f"layer_bits has {len(self.layer_bits)} "
                             f"entries for {cfg.n_layers} layers")
        self.bucket_frames: Counter = Counter()
        self.bucket_launches: Counter = Counter()
        # measured per-flush wall seconds (sum + count per bucket) — the
        # observed numbers the cost-model calibration fits against
        self.flush_wall_s: dict[int, float] = {}
        self.flush_wall_n: Counter = Counter()
        self._per_bucket: dict[int, EnergyReport] = {}
        self._mgnet: EnergyReport | None = None
        self.recal_events = 0
        self._retune: EnergyReport | None = None

    def _bucket_report(self, k: int) -> EnergyReport:
        """Per-frame report for a k-patch encode, cached — the ladder is
        small so each bucket's report is computed once."""
        rep = self._per_bucket.get(k)
        if rep is None:
            rep = bucket_report(self.cfg, k, self.layer_bits)
            self._per_bucket[k] = rep
        return rep

    def _mgnet_report(self) -> EnergyReport:
        if self._mgnet is None:
            self._mgnet = mgnet_report(self.cfg)
        return self._mgnet

    def add_encode(self, bucket: int, n_frames: int) -> None:
        self.total += self._bucket_report(bucket).scaled(n_frames)
        self.frames += n_frames
        self.bucket_frames[int(bucket)] += n_frames
        self.bucket_launches[int(bucket)] += 1

    def add_mgnet(self, n_invocations: int) -> None:
        self.total += self._mgnet_report().scaled(n_invocations)
        self.scored_frames += n_invocations

    def add_recalibration(self) -> None:
        """Bill one drift-triggered MR re-tuning pass (the software
        recalibration's hardware analogue, ``retune_report``) to this
        stream's running energy total."""
        if self._retune is None:
            self._retune = retune_report(self.cfg, self.layer_bits)
        self.total += self._retune
        self.recal_events += 1

    def add_flush_wall(self, bucket: int, wall_s: float) -> None:
        """Record one flush's measured host wall seconds at this bucket.
        (A cross-session ``mix_streams`` flush is billed in full to every
        owning session — the per-session mean then reads as 'seconds of
        launch this stream's frames rode in', not exclusive time.)"""
        k = int(bucket)
        self.flush_wall_s[k] = self.flush_wall_s.get(k, 0.0) + float(wall_s)
        self.flush_wall_n[k] += 1

    def measured_flush_s(self, bucket: int) -> float | None:
        """Mean measured wall seconds per flush at this bucket (None
        before any timed flush landed there)."""
        k = int(bucket)
        n = self.flush_wall_n[k]
        return self.flush_wall_s[k] / n if n else None

    # -- checkpoint/migration ---------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot of the accumulated accounting (everything a
        restored session needs to keep billing where it left off; the
        per-bucket report caches rebuild lazily from cfg). Counter keys
        become strings here — JSON objects only key on strings — and
        ``load_state`` turns them back into ints."""
        return {
            "total": {f.name: getattr(self.total, f.name)
                      for f in _dc_fields(self.total)},
            "frames": self.frames,
            "scored_frames": self.scored_frames,
            "bucket_frames": {str(k): v
                              for k, v in self.bucket_frames.items()},
            "bucket_launches": {str(k): v
                                for k, v in self.bucket_launches.items()},
            "flush_wall_s": {str(k): v
                             for k, v in self.flush_wall_s.items()},
            "flush_wall_n": {str(k): v
                             for k, v in self.flush_wall_n.items()},
            "recal_events": self.recal_events,
        }

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict()`` output into this (freshly built)
        accounting; cfg/ladder/bit-plan identity is the caller's contract
        (the server's checkpoint compatibility check)."""
        self.total = EnergyReport(**{k: float(v)
                                     for k, v in state["total"].items()})
        self.frames = int(state["frames"])
        self.scored_frames = int(state["scored_frames"])
        self.bucket_frames = Counter(
            {int(k): int(v) for k, v in state["bucket_frames"].items()})
        self.bucket_launches = Counter(
            {int(k): int(v) for k, v in state["bucket_launches"].items()})
        self.flush_wall_s = {int(k): float(v)
                             for k, v in state["flush_wall_s"].items()}
        self.flush_wall_n = Counter(
            {int(k): int(v) for k, v in state["flush_wall_n"].items()})
        self.recal_events = int(state["recal_events"])

    def dead_buckets(self) -> tuple[int, ...]:
        """Ladder entries no frame was ever routed to (empty when no
        ladder was registered)."""
        if self.ladder_sizes is None:
            return ()
        return tuple(k for k in self.ladder_sizes
                     if self.bucket_frames[k] == 0)

    def summary(self, warn: bool = True) -> str:
        """Per-bucket hit/launch counts (plus measured ms per flush when
        the server timed them), warning on dead buckets.

        A launch is one encode flush; the first launch of a bucket paid
        that bucket's jit compile, so ``launches >= 1`` marks the bucket
        as compiled. Dead buckets compiled nothing *only if* the engine
        never warmed them — but their ladder slot still constrains
        routing, so the warning fires either way. ``warn=False`` keeps the
        ``[dead: ...]`` text but suppresses the UserWarning — fleet
        callers (serving/fleet.py) aggregate dead buckets across every
        worker and warn ONCE at the router instead of N identical times.
        """
        sizes = (self.ladder_sizes if self.ladder_sizes is not None
                 else tuple(sorted(self.bucket_frames)))
        parts = []
        for k in sizes:
            hits = self.bucket_frames[k]
            part = (f"k={k}: {hits} hits/"
                    f"{self.bucket_launches[k]} launches")
            meas = self.measured_flush_s(k)
            if meas is not None:
                part += f" ({meas * 1e3:.1f}ms/flush measured)"
            parts.append(part)
        dead = self.dead_buckets()
        if dead and warn:
            warnings.warn(
                f"dead ladder buckets {list(dead)}: no frame routed to "
                f"them in {self.frames} frames — every ladder entry costs "
                f"a compiled encode shape, retune the bucket fractions "
                f"(README 'Bucket-ladder tuning')", stacklevel=2)
        line = " | ".join(parts) if parts else "no encodes"
        if dead:
            line += f"  [dead: {', '.join(f'k={k}' for k in dead)}]"
        return f"buckets: {line}"

    @property
    def mean_frame(self) -> EnergyReport:
        return self.total.scaled(1.0 / self.frames if self.frames else 0.0)

    @property
    def kfps_per_watt(self) -> float:
        return kfps_per_watt(self.mean_frame) if self.frames else 0.0

    def dense_baseline_kfps_per_watt(self, with_mgnet: bool = True) -> float:
        """KFPS/W if every frame were encoded dense (and scored, if
        ``with_mgnet``) — the no-gating reference for the energy-saved %."""
        n = (self.cfg.img_size // self.cfg.patch) ** 2
        rep = self._bucket_report(n)
        if with_mgnet:
            rep = rep + self._mgnet_report()
        return kfps_per_watt(rep)
