"""Symmetric uniform quantization + quantization-aware training (QAT).

Implements the paper's §IV "Accuracy Analysis" scheme:
  * symmetric uniform quantization (zero-point = 0),
  * dynamic range from tensor statistics (per-tensor or per-channel absmax),
  * straight-through estimator (STE) for the non-differentiable round,
  * fake-quant (quantize -> dequantize) during training so low-precision
    inference behaviour is simulated while gradients flow in fp.

8-bit is the MR resolution limit of the photonic core (Q-factor ~= 5000,
see core/noise.py); the same machinery supports other bit-widths for
ablations.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "quant_range",
    "absmax_scale",
    "quantize",
    "dequantize",
    "fake_quant",
    "fake_quant_ste",
    "quantize_params",
    "QuantConfig",
]


def quant_range(bits: int) -> tuple[int, int]:
    """Integer range of a signed symmetric ``bits``-bit code, e.g. 8 -> (-127, 127).

    Symmetric quantization uses a balanced range (the paper's choice, after
    I-ViT [45]); -128 is excluded so that w and -w quantize symmetrically.
    """
    if bits < 2:
        raise ValueError(f"bits must be >= 2, got {bits}")
    qmax = 2 ** (bits - 1) - 1
    return -qmax, qmax


def absmax_scale(x: jax.Array, bits: int = 8, axis: int | Sequence[int] | None = None,
                 eps: float = 1e-8) -> jax.Array:
    """Dynamic symmetric scale s = absmax * (1/qmax) (per-tensor or
    per-channel).

    ``axis``: axes to *reduce over*. None reduces over everything
    (per-tensor). For a weight of shape (in, out), ``axis=0`` gives a
    per-output-channel scale of shape (1, out).

    The scale multiplies by a pre-rounded f32 reciprocal instead of
    dividing by qmax: XLA strength-reduces constant division to
    reciprocal-multiply under jit but not eagerly, which made the same
    tensor quantize to different codes inside vs outside jit — fatal for
    the cross-backend bit-parity contract (core/backend.py).
    """
    _, qmax = quant_range(bits)
    inv_qmax = jnp.float32(1.0 / qmax)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    amax = jnp.maximum(amax, eps)
    return (amax.astype(jnp.float32) * inv_qmax)


def quantize(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    """Real quantization to int8/int32 codes (used on the photonic path)."""
    qmin, qmax = quant_range(bits)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return q.astype(dtype)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@jax.custom_vjp
def _ste_round(x: jax.Array) -> jax.Array:
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    # Straight-through: d round(x)/dx := 1  (Bengio et al. [44])
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant_ste(x: jax.Array, bits: int = 8,
                   axis: int | Sequence[int] | None = None) -> jax.Array:
    """Fake-quant with STE: quantize->dequantize, gradient passes through.

    Values outside the clip range receive zero gradient (clip is handled by
    jnp.clip whose vjp is already the pass/zero mask), matching standard QAT
    practice (Jacob et al. [43]).
    """
    scale = jax.lax.stop_gradient(absmax_scale(x, bits=bits, axis=axis))
    qmin, qmax = quant_range(bits)
    clipped = jnp.clip(x / scale, qmin, qmax)
    return (_ste_round(clipped) * scale).astype(x.dtype)


def fake_quant(x: jax.Array, bits: int = 8,
               axis: int | Sequence[int] | None = None) -> jax.Array:
    """Fake-quant without gradient customization (inference path)."""
    scale = absmax_scale(x, bits=bits, axis=axis)
    qmin, qmax = quant_range(bits)
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return (q * scale).astype(x.dtype)


class QuantConfig:
    """Static quantization configuration threaded through model layers."""

    def __init__(self, bits_w: int = 8, bits_a: int = 8, enabled: bool = True,
                 per_channel: bool = True, quantize_activations: bool = True):
        self.bits_w = bits_w
        self.bits_a = bits_a
        self.enabled = enabled
        self.per_channel = per_channel
        self.quantize_activations = quantize_activations

    def __repr__(self):
        return (f"QuantConfig(w{self.bits_w}a{self.bits_a}, enabled={self.enabled}, "
                f"per_channel={self.per_channel})")


def quantize_params(params, bits: int = 8, min_size: int = 128):
    """Post-training weight quantization of a whole pytree (fake-quant).

    Leaves smaller than ``min_size`` elements (biases, norm scales) are kept
    in full precision, mirroring the paper's choice of quantizing only the
    optical-core operands (patch-embed / MHSA / FFN matmuls).
    """

    def _q(leaf):
        if leaf.ndim >= 2 and leaf.size >= min_size:
            return fake_quant(leaf, bits=bits, axis=tuple(range(leaf.ndim - 1)))
        return leaf

    return jax.tree_util.tree_map(_q, params)
