"""Fault tolerance: restartable training harness + straggler detection.

``run_with_restarts`` wraps a step loop with checkpoint/restore so that
any exception (preemption, device loss — simulated in tests via injected
faults) resumes from the last checkpoint with a bit-identical data stream
(the pipeline is (seed, step)-deterministic). This is the single-process
skeleton of the pod-level controller: on a real cluster the same logic
runs per-slice with the coordinator re-admitting restarted workers.

``StragglerDetector`` flags slow steps from a robust running estimate
(median + MAD); the launch loop logs flags and (on real pods) would
trigger hot-spare swap. On CPU we exercise the logic with synthetic
timings (tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint.checkpoint import CheckpointManager

__all__ = ["run_with_restarts", "StragglerDetector"]


def run_with_restarts(step_fn: Callable[[Any, int], Any], init_state: Any,
                      n_steps: int, manager: CheckpointManager,
                      like: Any | None = None, max_restarts: int = 10,
                      on_restart: Callable[[int], None] | None = None):
    """Run ``state = step_fn(state, step)`` for n_steps with auto-restart.

    On exception: restore the latest checkpoint and continue from its step.
    Checkpoints via ``manager`` (periodic); a final checkpoint is always
    written. Returns (state, restarts_used).
    """
    restarts = 0
    state = init_state
    step = 0
    restored, s0 = manager.restore_latest(like if like is not None
                                          else init_state)
    if restored is not None:
        state, step = restored, s0
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            manager.maybe_save(step, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(step)
            restored, s0 = manager.restore_latest(
                like if like is not None else init_state)
            if restored is None:
                state, step = init_state, 0
            else:
                state, step = restored, s0
    manager.maybe_save(step, state, force=True)
    manager.wait()
    return state, restarts


@dataclass
class StragglerDetector:
    """Robust slow-step detector: flag when duration > median + k * MAD."""

    k: float = 5.0
    window: int = 50
    _durations: list = field(default_factory=list)
    flags: list = field(default_factory=list)

    def record(self, step: int, duration_s: float) -> bool:
        ds = self._durations
        flagged = False
        if len(ds) >= 10:
            srt = sorted(ds)
            med = srt[len(srt) // 2]
            mad = sorted(abs(d - med) for d in srt)[len(srt) // 2]
            if duration_s > med + self.k * max(mad, 1e-6):
                flagged = True
                self.flags.append((step, duration_s, med))
        ds.append(duration_s)
        # keep only the newest ``window`` samples: the estimate was always
        # windowed, but the raw history grew without bound on a long-lived
        # server (the serving flush watchdog records forever)
        if len(ds) > self.window:
            del ds[: len(ds) - self.window]
        return flagged

    class timer:
        def __init__(self, det: "StragglerDetector", step: int):
            self.det, self.step = det, step

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            self.det.record(self.step, time.perf_counter() - self.t0)
