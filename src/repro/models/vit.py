"""Vision Transformer backbone + Opto-ViT integration (the paper's model).

Standard ViT (Dosovitskiy et al.) with the paper's co-design hooks:
  * every matmul (backbone, attention projections, FFN and MGNet) routes
    through ``linear`` -> the backend registry of core/backend.py
    (bf16 | qat | photonic_sim | photonic_pallas, selected by
    ArchConfig.matmul_backend / .quant_bits / .photonic); serve-time params
    can be pre-tuned once with ``core.backend.prepare_params``,
  * every attention core (standard and decomposed, masked and gathered)
    routes through ``attend`` -> the attention registry (xla materialized
    scores | fused RoI-masked flash Pallas kernel, selected by
    ArchConfig.attn_backend); with the int8 Pallas matmul backend + cached
    weights the whole MHSA block takes the one-jit serving hot path,
  * every GELU-MLP routes through ``core.backend.ffn`` -> the FFN registry
    (xla composed two-linear | fused int8 photonic FFN kernel, selected by
    ArchConfig.ffn_backend); in one-shape serving mode the encoder threads
    the static packed live-token count into the FFN so fully-pruned rows
    skip both matmuls, the GELU and the requantization,
  * on the fully-fused serving point (photonic_pallas + flash + fused with
    cached <= 8-bit weights — uniform or a mixed per-layer bit plan)
    ``encode_tokens`` routes through one cached jit: fused attention +
    fused FFN + both residual adds/LayerNorms compose into a single
    jitted per-layer step scanned over the stacked layer weights, mixed
    plans segmenting the stack into equal-bits runs (one scan per run,
    still one jit) — the encoder costs ~one dispatch total instead of ~4
    per layer, computing bit-identical numbers to the composed dispatch,
  * optional Eq. 2 decomposed attention dataflow (attn_impl="decomposed"),
  * optional MGNet RoI pruning: patches are scored by MGNet and only the
    top-k (static budget = ceil(keep_ratio * N)) enter encoder block 0 —
    all downstream compute scales linearly with kept patches (the paper's
    central energy lever). The [cls] token is always kept.

Variants (paper Table I): Tiny/Small/Base/Large at 96x96 and 224x224 are
built by ``configs.opto_vit``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import mgnet as mgnet_mod
from repro.core import noise as noise_mod
from repro.core.decomposed_attention import mhsa_decomposed, mhsa_standard
from repro.core.mgnet import MGNetConfig, mgnet_scores, patchify
from repro.distributed.sharding import current_ctx, shard
from repro.models import ffn as ffn_mod
from repro.models.layers import (ExecPolicy, QuantizedWeight, he_init,
                                 layernorm, linear)

__all__ = ["init_vit", "vit_logical_axes", "forward_vit", "embed_patches",
           "encode_tokens", "encoder_layer_step", "forward_vit_tokens",
           "forward_vit_masked", "vit_matmul_shapes"]


def _n_patches(cfg):
    return (cfg.img_size // cfg.patch) ** 2


def init_vit(key, cfg: ArchConfig, n_classes: int = 1000,
             dtype=jnp.float32) -> dict:
    d = cfg.d_model
    n_in = 3 * cfg.patch ** 2
    ks = jax.random.split(key, 6)

    def layer(k):
        kk = jax.random.split(k, 5)
        return {
            "ln1_g": jnp.ones((d,), dtype), "ln1_b": jnp.zeros((d,), dtype),
            "attn": {"wq": he_init(kk[0], (d, d), dtype),
                     "wk": he_init(kk[1], (d, d), dtype),
                     "wv": he_init(kk[2], (d, d), dtype),
                     "wo": he_init(kk[3], (d, d), dtype)},
            "ln2_g": jnp.ones((d,), dtype), "ln2_b": jnp.zeros((d,), dtype),
            "ffn": ffn_mod.init_mlp(kk[4], d, cfg.d_ff, dtype),
        }

    params = {
        "patch_embed": {"w": he_init(ks[0], (n_in, d), dtype),
                        "b": jnp.zeros((d,), dtype)},
        "cls": (jax.random.normal(ks[1], (1, 1, d), jnp.float32) * 0.02
                ).astype(dtype),
        "pos": (jax.random.normal(ks[2], (1, _n_patches(cfg) + 1, d),
                                  jnp.float32) * 0.02).astype(dtype),
        "blocks": jax.vmap(layer)(jax.random.split(ks[3], cfg.n_layers)),
        "final_ln_g": jnp.ones((d,), dtype),
        "final_ln_b": jnp.zeros((d,), dtype),
        "head": he_init(ks[4], (d, n_classes), dtype),
    }
    if cfg.mgnet:
        mcfg = MGNetConfig(patch=cfg.patch, img_size=cfg.img_size,
                           embed=cfg.mgnet_embed, heads=cfg.mgnet_heads)
        params["mgnet"] = mgnet_mod.init_mgnet(ks[5], mcfg)
    return params


def vit_logical_axes(cfg: ArchConfig) -> dict:
    from repro.models.transformer import _tree_prepend_axis
    layer = {"ln1_g": (None,), "ln1_b": (None,),
             # wq/wk/wv output columns are head-major, so a "model" mesh
             # axis splits them into whole head groups (the sharded
             # encoder's layout — MODEL_RULES maps p_heads there). wo is
             # deliberately NOT tagged p_heads on its (head-major) rows:
             # the sharded encoder consumes it whole after all-gathering
             # the merged head outputs (its dequant runs inside the
             # photonic matmul kernel, so a row split cannot reduce the
             # int32 accumulates before dequant without changing numerics)
             "attn": {"wq": ("p_embed", "p_heads"), "wk": ("p_embed", "p_heads"),
                      "wv": ("p_embed", "p_heads"), "wo": (None, "p_embed")},
             "ln2_g": (None,), "ln2_b": (None,),
             "ffn": ffn_mod.mlp_logical_axes()}
    ax = {"patch_embed": {"w": (None, "p_embed"), "b": ("p_embed",)},
          "cls": (None, None, None), "pos": (None, None, None),
          "blocks": _tree_prepend_axis(layer),
          "final_ln_g": (None,), "final_ln_b": (None,),
          "head": ("p_embed", None)}
    if cfg.mgnet:
        # structure-matching all-None (replicated) tree — an empty pytree
        # here would break annotation tree_maps against the real params.
        ax["mgnet"] = mgnet_mod.mgnet_logical_axes()
    return ax


def embed_patches(params: dict, images: jnp.ndarray, cfg: ArchConfig,
                  policy: ExecPolicy | None = None) -> jnp.ndarray:
    """images (B, H, W, 3) -> position-embedded patch tokens (B, N, d).

    The serving engine calls this once per ingested frame chunk, then
    gathers per-frame top-k subsets (bucket routing) — positional
    information must therefore already live in the tokens, which is why the
    pos table is added *before* any pruning (identical to the fused path).
    """
    policy = policy or ExecPolicy.from_cfg(cfg)
    pt = patchify(images, cfg.patch)                      # (B, N, p*p*3)
    x = linear(pt, params["patch_embed"]["w"], params["patch_embed"]["b"],
               policy)
    return x + params["pos"][:, 1: x.shape[1] + 1]


def encoder_layer_step(carry: jnp.ndarray, lp: dict, cfg: ArchConfig,
                       policy: ExecPolicy,
                       mask: jnp.ndarray | None = None,
                       attn_kv: int | None = None,
                       ffn_live: int | None = None) -> jnp.ndarray:
    """One encoder layer: LN -> MHSA -> residual -> LN -> FFN -> residual.

    ``lp`` is one layer's param slice (what ``lax.scan`` hands the body).
    On the fully-fused serving point this whole step is two kernel entries
    (``fused_roi_attention_prequant`` + the fused FFN) plus the norms and
    residual adds; ``ffn_live`` threads the packed live-row count so the
    fused FFN skips dead token rows the same way the flash kernel skips
    pruned KV blocks.
    """
    h = layernorm(carry, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
    if cfg.attn_impl == "decomposed":
        o = mhsa_decomposed(h, lp["attn"], cfg.n_heads, policy, mask,
                            attn_kv)
    else:
        o = mhsa_standard(h, lp["attn"], cfg.n_heads, policy, mask,
                          attn_kv)
    carry = carry + o.astype(carry.dtype)
    h2 = layernorm(carry, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
    return carry + ffn_mod.mlp(lp["ffn"], h2, policy, live_rows=ffn_live)


def _is_qw(a) -> bool:
    return isinstance(a, QuantizedWeight)


def _blocks_qw_leaves(blocks) -> list:
    return [a for a in jax.tree_util.tree_leaves(blocks, is_leaf=_is_qw)
            if _is_qw(a)]


def _blocks_bits_key(blocks) -> tuple:
    """Hashable per-leaf bits signature of the stacked blocks' cache —
    jit-cache key material alongside ``ExecPolicy.fingerprint()`` (the
    params treedef changing would retrace anyway; keying explicitly keeps
    one wrapper per plan in ``_FUSED_ENCODER_JITS``)."""
    return tuple(a.bits for a in _blocks_qw_leaves(blocks))


def _bit_segments(blocks, n_layers: int) -> list[tuple[int, int]]:
    """[lo, hi) runs of consecutive layers whose cached widths agree on
    every QuantizedWeight leaf — the units the segmented scan compiles.
    Uniform caches (every ``bits`` an int) are one run: today's path."""
    leaves = _blocks_qw_leaves(blocks)
    if not any(isinstance(a.bits, tuple) for a in leaves):
        return [(0, n_layers)]
    sig = [tuple(a.layer_bits(i) for a in leaves) for i in range(n_layers)]
    segs, lo = [], 0
    for i in range(1, n_layers + 1):
        if i == n_layers or sig[i] != sig[lo]:
            segs.append((lo, i))
            lo = i
    return segs


def _slice_blocks(blocks, lo: int, hi: int):
    """Layer-range slice of the stacked blocks. QuantizedWeight leaves
    keep codes/scales stacked but collapse ``bits`` to the run's single
    int width — what makes every 2-D in-scan slice carry the int the
    fused kernels and ``_weight_bits`` require."""
    def sl(a):
        if _is_qw(a):
            return QuantizedWeight(a.wq[lo:hi], a.scale[lo:hi],
                                   a.layer_bits(lo))
        return a[lo:hi]
    return jax.tree_util.tree_map(sl, blocks, is_leaf=_is_qw)


def _encode_tokens_impl(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
                        policy: ExecPolicy,
                        patch_mask: jnp.ndarray | None,
                        kv_len: int | None) -> jnp.ndarray:
    b, _, d = tokens.shape
    cls = jnp.broadcast_to(params["cls"], (b, 1, d)) + params["pos"][:, :1]
    x = jnp.concatenate([cls.astype(tokens.dtype), tokens], axis=1)
    x = shard(x, "batch", "seq", "embed")
    mask = None
    if patch_mask is not None:
        mask = jnp.concatenate(
            [jnp.ones((b, 1), patch_mask.dtype), patch_mask], axis=1)
    attn_kv = None if kv_len is None else int(kv_len) + 1   # + live [cls]
    # the packed live-row hint for skipping FFN backends: in one-shape
    # mode the first kv_len patch rows (+ cls) are the only live ones —
    # the same static count the flash attention backend skips with
    ffn_live = attn_kv

    noisy = policy.noise is not None

    def body(carry, lp):
        return encoder_layer_step(carry, lp, cfg, policy, mask, attn_kv,
                                  ffn_live), None

    def body_noisy(carry, lp_li):
        # the scan shares ONE traced body across layers, so without a
        # per-layer salt every layer would observe the same noise draws
        # (the frozen-pattern bug at scan granularity); folding the
        # scanned layer index into the scope keys decorrelates them
        lp, li = lp_li
        with noise_mod.scope_salt(li):
            return encoder_layer_step(carry, lp, cfg, policy, mask,
                                      attn_kv, ffn_live), None

    fn = jax.checkpoint(body_noisy if noisy else body) if cfg.remat \
        else (body_noisy if noisy else body)
    # segmented scan: runs of equal per-layer bit signature each scan as
    # one unit, so a mixed-precision plan still traces a handful of scans
    # inside ONE jit (uniform caches segment to today's single scan).
    # lax.scan slices the stacked leaves exactly like the [lo:hi] slicing
    # here, so the segmented walk is bitwise equal to the unrolled loop.
    for lo, hi in _bit_segments(params["blocks"], cfg.n_layers):
        seg = (params["blocks"] if (lo, hi) == (0, cfg.n_layers)
               else _slice_blocks(params["blocks"], lo, hi))
        if noisy:
            # xs gains the global layer index ONLY under noise, so the
            # clean graph (and its bitwise contract) is untouched
            x, _ = jax.lax.scan(fn, x, (seg, jnp.arange(lo, hi)))
        else:
            x, _ = jax.lax.scan(fn, x, seg)
    x = layernorm(x, params["final_ln_g"], params["final_ln_b"], cfg.norm_eps)
    return linear(x[:, 0], params["head"], policy=policy)


def _fused_encoder_ineligible_reason(params: dict, cfg: ArchConfig,
                                     policy: ExecPolicy) -> str | None:
    """None when the whole encoder can take the single-jit serving hot
    path — int8 Pallas matmuls + flash attention + fused FFN, standard
    dataflow, every per-layer matmul weight quantize-once cached at <= 8
    bits (uniform *or* a mixed per-layer plan: the segmented scan slices
    mixed stacks into equal-bits runs before the fused entries see them)
    — else a human-readable reason for the composed fallback."""
    if policy.noise is not None:
        return ("calibrated device noise is active (ExecPolicy.noise) — "
                "the fused single-jit encoder is the clean digital "
                "contract; noisy execution runs the composed analog "
                "dispatch")
    if not (policy.resolve_backend() == "photonic_pallas"
            and policy.resolve_attn_backend() == "flash"
            and policy.resolve_ffn_backend() == "fused"):
        return (f"backends ({policy.resolve_backend()!r}, "
                f"{policy.resolve_attn_backend()!r}, "
                f"{policy.resolve_ffn_backend()!r}) are not the fused "
                f"serving triple ('photonic_pallas', 'flash', 'fused')")
    if cfg.attn_impl != "standard":
        return f"attn_impl {cfg.attn_impl!r} (fused path needs 'standard')"
    blocks = params.get("blocks")
    if not isinstance(blocks, dict):
        return "params['blocks'] missing or not a dict"
    try:
        ws = ([blocks["attn"][n] for n in ("wq", "wk", "wv")]
              + [blocks["ffn"][n] for n in ("w1", "w2")])
    except (KeyError, TypeError):
        return "blocks missing attn/ffn weight entries"
    if not all(isinstance(w, QuantizedWeight) for w in ws):
        return ("block weights not quantize-once cached "
                "(run prepare_params)")
    widths = set()
    for w in ws:
        widths.update(w.bits if isinstance(w.bits, tuple) else (w.bits,))
    if not all(2 <= b <= 8 for b in widths):
        return f"cached bit widths {sorted(widths)} outside [2, 8]"
    return None


def _fused_encoder_eligible(params: dict, cfg: ArchConfig,
                            policy: ExecPolicy) -> bool:
    return _fused_encoder_ineligible_reason(params, cfg, policy) is None


# (cfg, policy fingerprint, blocks bits signature, kv_len, has_mask) ->
# jitted encode entry. The serving engine holds one cfg/policy per stream
# and the ladder is small, so this stays a handful of entries per process.
_FUSED_ENCODER_JITS: dict = {}


def _fused_encoder_jit(cfg: ArchConfig, policy: ExecPolicy, bits_key: tuple,
                       kv_len: int | None, has_mask: bool):
    key = (cfg, policy.fingerprint(), bits_key, kv_len, has_mask)
    fn = _FUSED_ENCODER_JITS.get(key)
    if fn is None:
        fn = jax.jit(lambda p, t, m: _encode_tokens_impl(p, t, cfg, policy,
                                                         m, kv_len))
        _FUSED_ENCODER_JITS[key] = fn
    return fn


def encode_tokens(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
                  policy: ExecPolicy | None = None,
                  patch_mask: jnp.ndarray | None = None,
                  kv_len: int | None = None) -> jnp.ndarray:
    """Encoder trunk on pre-embedded patch tokens -> logits (B, n_classes).

    tokens: (B, k, d) position-embedded patch tokens (any k <= N — the
    serving buckets call this with k in the ladder); the [cls] token is
    prepended here. ``patch_mask`` (B, k) optionally removes tokens from
    every attention key axis without changing shapes (RoI mask mode; cls is
    always kept). ``kv_len`` is the packed alternative for score-ordered
    tokens (one-shape serving mode): only the first ``kv_len`` patch
    tokens are live, a static count the flash attention backend skips the
    dead tail for — and the fused FFN backend skips those rows' FFN tiles.
    Kept-token activations are identical between a masked dense call and a
    gathered top-k call because attention is the only cross-token operator
    in the trunk.

    On the fully-fused serving point (photonic_pallas + flash + fused, all
    weights cached at <= 8 bits — uniform or a mixed per-layer bit plan)
    the call routes through a cached jit of the whole trunk — fused
    attention + fused FFN + norms/residuals as one jitted per-layer step
    scanned over the stacked layer weights (mixed plans scan each
    equal-bits run), one dispatch total. The jit computes the same graph
    this function traces everywhere else, so serving callers that wrap
    their own jit around it simply inline it. When the policy requests
    the fused point but the params are ineligible, a one-time
    ``UserWarning`` names the reason before the composed fallback runs.
    """
    policy = policy or ExecPolicy.from_cfg(cfg)
    if patch_mask is not None and kv_len is not None:
        raise ValueError("give patch_mask or kv_len, not both")
    reason = _fused_encoder_ineligible_reason(params, cfg, policy)
    if reason is None:
        ctx = current_ctx()
        if ctx is not None and "model" in ctx.mesh.axis_names \
                and ctx.mesh.shape["model"] > 1:
            # 2-D serving mesh: try the model-sharded twin of the fused
            # jit (same graph under shard_map — bitwise-equal logits);
            # ineligible combos warn once and keep the unsharded jit.
            from repro.models import sharded_encoder
            sreason = sharded_encoder.sharded_encode_ineligible_reason(
                params, cfg, policy, ctx)
            if sreason is None:
                return sharded_encoder.sharded_encode(
                    params, tokens, cfg, policy, patch_mask,
                    None if kv_len is None else int(kv_len), ctx)
            from repro.core.backend import warn_fused_fallback
            warn_fused_fallback("sharded encoder", policy, sreason)
        fn = _fused_encoder_jit(cfg, policy,
                                _blocks_bits_key(params["blocks"]),
                                None if kv_len is None else int(kv_len),
                                patch_mask is not None)
        return fn(params, tokens, patch_mask)
    if policy.resolve_ffn_backend() == "fused":
        # the policy asked for the fused serving point: name the cause of
        # the composed-dispatch cliff once (core.backend keys the set)
        from repro.core.backend import warn_fused_fallback
        warn_fused_fallback("encoder", policy, reason)
    return _encode_tokens_impl(params, tokens, cfg, policy, patch_mask,
                               kv_len)


def forward_vit(params: dict, images: jnp.ndarray, cfg: ArchConfig,
                policy: ExecPolicy | None = None):
    """images (B, H, W, 3) -> (logits (B, n_classes), kept_patches int).

    With cfg.mgnet, MGNet scores patches and a static top-k budget of
    ceil(keep_ratio * N) enters the encoder — paper's masked inference.
    """
    policy = policy or ExecPolicy.from_cfg(cfg)
    x = embed_patches(params, images, cfg, policy)
    n = x.shape[1]

    kept = n
    if cfg.mgnet and cfg.mgnet_keep_ratio < 1.0:
        mcfg = MGNetConfig(patch=cfg.patch, img_size=cfg.img_size,
                           embed=cfg.mgnet_embed, heads=cfg.mgnet_heads)
        # MGNet shares the optical cores with the backbone: same policy
        # (modulo the gate's default-clean noise stance — gate_policy).
        scores = mgnet_scores(params["mgnet"], images, mcfg,
                              policy.gate_policy())  # (B, N)
        kept = max(1, int(cfg.mgnet_keep_ratio * n))
        x, _ = mgnet_mod.select_topk_patches(scores, x, kept)

    return encode_tokens(params, x, cfg, policy), kept


def forward_vit_tokens(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
                       policy: ExecPolicy | None = None,
                       kv_len: int | None = None):
    """Pre-gathered token forward: tokens (B, k, d) -> (logits, kept).

    The serving engine's bucketed encode path — the gate/gather already
    happened upstream (possibly against a *cached* RoI mask), so every call
    at a given bucket size k is shape-static and jit-cache-hits. In
    one-shape mode the engine instead passes all N score-ordered tokens
    plus a static ``kv_len``: one compiled token shape, per-bucket
    kv_len-specialized variants, and the flash attention backend skips the
    pruned tail's score FLOPs.
    """
    kept = tokens.shape[1] if kv_len is None else kv_len
    return encode_tokens(params, tokens, cfg, policy, kv_len=kv_len), kept


def forward_vit_masked(params: dict, images: jnp.ndarray,
                       patch_mask: jnp.ndarray, cfg: ArchConfig,
                       policy: ExecPolicy | None = None):
    """Mask-mode dense forward: all N patches enter the encoder but
    ``patch_mask`` (B, N) removes dropped ones from every attention key
    axis. Compute is *not* reduced — this is the accuracy-study / baseline
    path the bucketed top-k engine is benchmarked against."""
    policy = policy or ExecPolicy.from_cfg(cfg)
    x = embed_patches(params, images, cfg, policy)
    return encode_tokens(params, x, cfg, policy, patch_mask), x.shape[1]


def vit_matmul_shapes(cfg: ArchConfig, kept_patches: int | None = None,
                      include_mgnet: bool = False) -> list[tuple[int, int, int]]:
    """(M, K, N) list of every MatMul in one ViT forward — feeds the
    optical-core energy/latency model (benchmarks/fig8..11).

    kept_patches: post-MGNet token count (None = all patches).
    """
    n = (kept_patches if kept_patches is not None else _n_patches(cfg)) + 1
    d, dff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    n_in = 3 * cfg.patch ** 2
    shapes = [( _n_patches(cfg) if kept_patches is None else kept_patches,
               n_in, d)]                                    # patch embed
    per_layer = [
        (n, d, d), (n, d, d), (n, d, d),                    # q, k, v
        (n, d, n),                                          # scores (per-head agg)
        (n, n, d),                                          # attn @ v
        (n, d, d),                                          # out proj
        (n, d, dff), (n, dff, d),                           # mlp
    ]
    shapes += per_layer * L
    if include_mgnet:
        mcfg = MGNetConfig(patch=cfg.patch, img_size=cfg.img_size,
                           embed=cfg.mgnet_embed, heads=cfg.mgnet_heads)
        nm = mcfg.n_patches + 1
        dm = mcfg.embed
        shapes += [
            (mcfg.n_patches, 3 * mcfg.patch ** 2, dm),      # mgnet patch embed
            (nm, dm, 3 * dm), (nm, dm, nm), (nm, nm, dm), (nm, dm, dm),
            (nm, dm, 4 * dm), (nm, 4 * dm, dm),
            (1, dm, dm), (mcfg.n_patches, dm, mcfg.n_patches),  # scoring
        ]
    return shapes
