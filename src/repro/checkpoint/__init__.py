"""checkpoint substrate."""
