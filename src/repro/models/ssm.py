"""Mamba-2: state-space duality (SSD) block, chunked matmul formulation.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the selective
state-space recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t  (x)  x_t)
    y_t = C_t . h_t + D * x_t

computed chunk-parallel: within a chunk of Q timesteps the recurrence
unrolls into masked matmuls (the "attention-like" dual form), across chunks
a short scan carries the (H, P, N) state. All heavy ops are einsums that
map onto the MXU; the chunk size trades VMEM footprint vs parallelism.

B/C are shared across heads (single group, MQA-style), A is a scalar per
head — the Mamba-2 defaults.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import ExecPolicy, causal_conv1d, he_init, linear, rmsnorm

__all__ = ["init_ssd", "ssd_forward", "ssd_decode_step", "ssd_logical_axes",
           "ssd_state_shape"]


def init_ssd(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * n + h          # z, x, B, C, dt
    return {
        "in_proj": he_init(k1, (d, proj_out), dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, di + 2 * n),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": he_init(k3, (di, d), dtype),
    }


def ssd_logical_axes(cfg) -> dict:
    return {
        "in_proj": ("p_embed", "p_mlp"),
        "conv_w": (None, None),
        "A_log": (None,), "D": (None,), "dt_bias": (None,),
        "norm_g": (None,),
        "out_proj": ("p_mlp", "p_embed"),
    }


def ssd_state_shape(cfg, batch: int) -> dict:
    """Decode-state ShapeDtypeStruct shapes (per layer)."""
    return {
        "h": (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
        "conv": (batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.ssm_state),
    }


def _split_proj(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di: 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _segsum_decay(da_chunk):
    """da_chunk: (..., Q) per-step log-decay -> L (..., Q, Q) with
    L[i, j] = exp(sum_{k=j+1..i} da_k) for i >= j else 0."""
    q = da_chunk.shape[-1]
    cs = jnp.cumsum(da_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # sum_(j+1..i) = cs_i - cs_j
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_forward(params: dict, x: jnp.ndarray, cfg,
                policy: ExecPolicy | None = None,
                initial_state=None):
    """Full-sequence SSD. x: (B, S, d_model) -> (y, final_state dict)."""
    b, s, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    proj = linear(x, params["in_proj"], policy=policy)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    conv_state0 = None if initial_state is None else initial_state["conv"]
    xbc, conv_state = causal_conv1d(xbc, params["conv_w"], conv_state0)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(b, s, h, p)
    bmat = xbc[..., di:di + n]                        # (B, S, N)
    cmat = xbc[..., di + n:]                          # (B, S, N)

    a = -jnp.exp(params["A_log"])                     # (H,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    da = dt * a                                       # log-decay per step

    # chunked views
    xc = xs.reshape(b, nc, q, h, p).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    dac = da.reshape(b, nc, q, h)

    # ---- intra-chunk (dual "attention" form) ----
    l = _segsum_decay(jnp.moveaxis(dac, -1, -2))      # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)    # shared across heads
    xdt = xc * dtc[..., None]                         # dt folded into x
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, l, xdt)

    # ---- chunk states ----
    cum = jnp.cumsum(dac, axis=2)                     # (B, nc, Q, H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (B, nc, Q, H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_to_end * dtc, xc)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # (B, nc, H)

    def scan_fn(hprev, inp):
        st, dec = inp
        hnew = dec[..., None, None] * hprev + st
        return hnew, hprev

    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state["h"].astype(jnp.float32))
    hlast, hprevs = jax.lax.scan(scan_fn,
                                 h0,
                                 (jnp.moveaxis(states, 1, 0),
                                  jnp.moveaxis(chunk_decay, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)               # (B, nc, H, P, N)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum)                           # decay from chunk start
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, hprevs, in_decay)

    y = (y_diag + y_inter).reshape(b, s, h, p)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)

    # gated output norm (Mamba-2 uses RMSNorm(y * silu(z)))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, params["norm_g"], cfg.norm_eps)
    out = linear(y, params["out_proj"], policy=policy)
    return out, {"h": hlast, "conv": conv_state}


def ssd_decode_step(params: dict, x: jnp.ndarray, state: dict, cfg,
                    policy: ExecPolicy | None = None):
    """Single-token recurrence. x: (B, 1, d_model) -> (y, new_state)."""
    b = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim

    proj = linear(x, params["in_proj"], policy=policy)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc, conv_state = causal_conv1d(xbc, params["conv_w"], state["conv"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :di].reshape(b, 1, h, p).astype(jnp.float32)
    bvec = xbc[..., di:di + n].astype(jnp.float32)    # (B, 1, N)
    cvec = xbc[..., di + n:].astype(jnp.float32)

    a = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    decay = jnp.exp(dt * a)                           # (B, H)

    hs = state["h"].astype(jnp.float32)               # (B, H, P, N)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xs[:, 0], bvec[:, 0])
    hnew = decay[..., None, None] * hs + upd
    y = jnp.einsum("bn,bhpn->bhp", cvec[:, 0], hnew)
    y = y + params["D"][None, :, None] * xs[:, 0]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, params["norm_g"], cfg.norm_eps)
    out = linear(y, params["out_proj"], policy=policy)
    return out, {"h": hnew, "conv": conv_state}
