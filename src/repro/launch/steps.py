"""jit step builders: train_step / prefill_step / serve_step per arch.

This is the single place where model code, optimizer, sharding rules and
the mesh meet. Every builder returns

    (jitted_fn, arg_specs, arg_shardings)

where ``arg_specs`` are ShapeDtypeStruct pytrees suitable for
``jitted.lower(*arg_specs)`` (the dry-run path) and ``arg_shardings`` the
matching NamedSharding pytrees (also installed as jit in_shardings).

Train step semantics:
  state = {"params", "opt": {m, v, count}, "step"}
  * microbatch gradient accumulation: cfg.microbatch_steps k splits the
    global batch into k sequential microbatches inside a lax.scan; grads
    accumulate in f32 (memory policy for the 405B-scale cells),
  * grad clip (global norm) + warmup-cosine LR + AdamW (bf16 m/v when
    cfg.use_fp32_master is False),
  * optional int8 gradient-compression hook (cfg-independent knob, see
    distributed/collectives.py; measured in EXPERIMENTS.md §Perf).

Serve step semantics: one token for the whole batch against a KV/state
cache of seq_len (flash-decoding layout: KV seq sharded over "model").
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (ShardingCtx, named_sharding,
                                        use_sharding)
from repro.models import api as model_api
from repro.models.layers import ExecPolicy
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, warmup_cosine)

__all__ = ["abstract_params", "abstract_state", "state_logical_axes",
           "tree_shardings", "tree_specs", "batch_arg_specs",
           "make_train_step", "make_prefill_step", "make_serve_step",
           "build_cell"]


# --------------------------------------------------------------------------
# abstract state + shardings
# --------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: model_api.init_model(key, cfg, dtype)
                          if cfg.family != "vit"
                          else model_api.init_model(key, cfg))


def abstract_state(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the full train state."""
    params = abstract_params(cfg, dtype)
    ocfg = AdamWConfig(low_mem=not cfg.use_fp32_master)
    opt = jax.eval_shape(lambda: adamw_init(
        jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), params),
        ocfg))
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_logical_axes(cfg: ArchConfig):
    """Logical-axis pytree matching abstract_state (opt m/v mirror params)."""
    pax = model_api.model_logical_axes(cfg)
    return {"params": pax, "opt": {"m": pax, "v": pax, "count": ()},
            "step": ()}


def _is_axes_leaf(x):
    return isinstance(x, tuple)


def tree_shardings(axes_tree, shape_tree, ctx: ShardingCtx):
    """NamedSharding pytree from (logical axes, ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(
        lambda ax, s: named_sharding(s.shape, ax, ctx),
        axes_tree, shape_tree, is_leaf=_is_axes_leaf)


def tree_specs(shape_tree, sharding_tree):
    """Attach shardings onto ShapeDtypeStructs (dry-run input stand-ins)."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree)


def batch_arg_specs(cfg: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx):
    """(specs, shardings) dicts for the batch of one cell."""
    raw = model_api.batch_specs(cfg, shape)
    specs, shards = {}, {}
    for k, (shp, dt, axes) in raw.items():
        ns = named_sharding(shp, axes, ctx)
        shards[k] = ns
        specs[k] = jax.ShapeDtypeStruct(shp, dt, sharding=ns)
    return specs, shards


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def make_train_fn(cfg: ArchConfig, grad_compression: bool = False):
    """Pure train_step(state, batch) -> (state, metrics). Not yet jitted."""
    ocfg = AdamWConfig(low_mem=not cfg.use_fp32_master)
    policy = ExecPolicy.from_cfg(cfg, training=True)
    k = max(cfg.microbatch_steps, 1)

    def loss(params, batch):
        return model_api.loss_fn(params, batch, cfg, policy)

    def grads_of(params, batch):
        if k == 1:
            return jax.value_and_grad(loss)(params, batch)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
        acc_dt = jnp.bfloat16 if cfg.grad_accum_dtype == "bf16" \
            else jnp.float32
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)

        def body(acc, mb):
            l_acc, g_acc = acc
            l, g = jax.value_and_grad(loss)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(acc_dt), g_acc, g)
            return (l_acc + l, g_acc), None

        (l_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
        g = jax.tree_util.tree_map(lambda x: (x / k), g_sum)
        return l_sum / k, g

    def train_step(state, batch):
        params = state["params"]
        l, g = grads_of(params, batch)
        g, gnorm = clip_by_global_norm(g, 1.0)
        # step counts *completed* steps; warmup_cosine(0) == 0 would make
        # the first step a no-op, so schedule on step + 1.
        lr = warmup_cosine(state["step"] + 1, warmup=cfg.lr_warmup,
                           total=cfg.lr_total)
        new_params, new_opt = adamw_update(g, state["opt"], params, ocfg, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": l, "grad_norm": gnorm}

    return train_step


def make_train_step(cfg: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx,
                    donate: bool = True):
    """Returns (jitted_step, (state_specs, batch_specs))."""
    st_abs = abstract_state(cfg)
    st_ax = state_logical_axes(cfg)
    st_sh = tree_shardings(st_ax, st_abs, ctx)
    st_specs = tree_specs(st_abs, st_sh)
    b_specs, b_sh = batch_arg_specs(cfg, shape, ctx)

    rep = NamedSharding(ctx.mesh, P())
    fn = make_train_fn(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, {"loss": rep, "grad_norm": rep}),
        donate_argnums=(0,) if donate else ())
    return jitted, (st_specs, b_specs)


# --------------------------------------------------------------------------
# prefill step
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx):
    """Returns (jitted_prefill, (param_specs, batch_specs))."""
    p_abs = abstract_params(cfg)
    p_ax = model_api.model_logical_axes(cfg)
    p_sh = tree_shardings(p_ax, p_abs, ctx)
    p_specs = tree_specs(p_abs, p_sh)
    b_specs, b_sh = batch_arg_specs(cfg, shape, ctx)

    policy = ExecPolicy.from_cfg(cfg, training=False)
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vit":
        logits_sh = named_sharding((b, 1000), ("batch", None), ctx)
    else:
        # logical_spec applies the divisibility fallback (odd vocabs like
        # 50280 / tiny batches replicate instead of erroring)
        logits_sh = named_sharding((b, s, cfg.vocab),
                                   ("batch", "seq", "vocab"), ctx)

    def prefill(params, batch):
        return model_api.prefill_fn(params, batch, cfg, policy)

    jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                     out_shardings=logits_sh)
    return jitted, (p_specs, b_specs)


# --------------------------------------------------------------------------
# serve (decode) step
# --------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig, shape: ShapeConfig, ctx: ShardingCtx,
                    donate: bool = True):
    """One-token decode against a seq_len cache.

    Returns (jitted_step, (param_specs, cache_specs, token_specs, pos_spec)).
    """
    p_abs = abstract_params(cfg)
    p_ax = model_api.model_logical_axes(cfg)
    p_sh = tree_shardings(p_ax, p_abs, ctx)
    p_specs = tree_specs(p_abs, p_sh)

    shapes, axes = model_api.cache_axes_spec(cfg, shape.global_batch,
                                             shape.seq_len)
    c_sh = {k: named_sharding(shp, axes[k], ctx)
            for k, (shp, dt) in shapes.items()}
    c_specs = {k: jax.ShapeDtypeStruct(shp, dt, sharding=c_sh[k])
               for k, (shp, dt) in shapes.items()}

    t_sh = named_sharding((shape.global_batch, 1),
                          model_api.BATCH_AXES["decode_tokens"], ctx)
    t_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                  sharding=t_sh)
    rep = NamedSharding(ctx.mesh, P())
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)

    policy = ExecPolicy.from_cfg(cfg, training=False)
    logits_sh = named_sharding((shape.global_batch, cfg.vocab),
                               ("batch", "vocab"), ctx)

    def serve_step(params, cache, tokens, pos):
        return model_api.decode_fn(params, cache, tokens, pos, cfg, policy)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, t_sh, rep),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(1,) if donate else ())
    return jitted, (p_specs, c_specs, t_spec, pos_spec)


# --------------------------------------------------------------------------
# one-call cell builder (dry-run entry)
# --------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               grad_compression: bool = False):
    """Build the jitted step + arg specs for one (arch x shape) cell.

    Must be called inside ``with mesh, use_sharding(mesh):`` — the model
    code's shard() annotations read the ambient context at trace time.
    """
    from repro.distributed.sharding import current_ctx
    ctx = current_ctx()
    assert ctx is not None, "build_cell requires an active use_sharding ctx"
    if shape.kind == "train":
        return make_train_step(cfg, shape, ctx)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, ctx)
    if shape.kind == "decode":
        return make_serve_step(cfg, shape, ctx)
    raise ValueError(shape.kind)
