"""Optical-core simulator tests (paper Figs 4/6 chunked MatMul)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # seed container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.photonic import (OpticalCoreConfig, matmul_stats,
                                 photonic_matmul_exact, photonic_matmul_sim)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.integers(1, 3),
       st.integers(0, 2**31 - 1))
def test_sim_matches_exact(mm, kk, nn, seed):
    """The tile-walking simulator == the one-shot integer-exact matmul
    (both w8a8): the chunk-accumulate order must not change the result."""
    m, k, n = mm * 7, kk * 33, nn * 65       # deliberately non-multiples
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    a = photonic_matmul_sim(x, w)
    b = photonic_matmul_exact(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_quantization_error_bounded():
    """w8a8 photonic matmul vs float matmul: error scales with the
    quantization steps of x and w."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    exact = x @ w
    phot = photonic_matmul_exact(x, w)
    rel = float(jnp.abs(phot - exact).max() / jnp.abs(exact).max())
    assert rel < 0.05, rel                     # 8-bit: ~1% typical


def test_noise_injection_increases_error():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    clean = photonic_matmul_sim(x, w)
    noisy = photonic_matmul_sim(
        x, w, OpticalCoreConfig(apply_noise=True, fpv_sigma=0.05),
        noise_key=jax.random.PRNGKey(2))
    assert float(jnp.abs(noisy - clean).max()) > 0


def test_apply_noise_requires_explicit_key():
    """Regression: ``noise_key=None`` used to silently default to
    ``PRNGKey(0)``, freezing one error pattern across every call — "drift"
    that never drifted. A missing key is now an error."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    with pytest.raises(ValueError, match="noise_key"):
        photonic_matmul_sim(x, w, OpticalCoreConfig(apply_noise=True))


def test_noisy_frames_differ_pinned_key_reproduces():
    """Two successive frames (distinct keys) draw fresh error patterns;
    the same key reproduces bitwise."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    cfg = OpticalCoreConfig(apply_noise=True, fpv_sigma=0.02)
    base = jax.random.PRNGKey(9)
    f0 = photonic_matmul_sim(x, w, cfg,
                             noise_key=jax.random.fold_in(base, 0))
    f1 = photonic_matmul_sim(x, w, cfg,
                             noise_key=jax.random.fold_in(base, 1))
    assert float(jnp.abs(f0 - f1).max()) > 0
    f0b = photonic_matmul_sim(x, w, cfg,
                              noise_key=jax.random.fold_in(base, 0))
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f0b))


def test_adc_quantize_output_differential():
    """Range-limited ADC readout vs the integer-exact matmul: the requant
    error is bounded by half an output quantization step."""
    from repro.core import quant

    x = jax.random.normal(jax.random.PRNGKey(0), (32, 96))
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 48))
    exact = photonic_matmul_exact(x, w)
    adc = photonic_matmul_sim(
        x, w, OpticalCoreConfig(adc_quantize_output=True))
    step = float(quant.absmax_scale(exact, bits=8))
    diff = float(jnp.abs(adc - exact).max())
    assert 0 < diff <= 0.5 * step + 1e-6, (diff, step)


def test_noisy_sim_jit_vs_eager_deterministic():
    """fpv_sigma > 0 under jit: repeated jitted calls are bitwise equal;
    jit-vs-eager agree to float tolerance (XLA fuses differently, so
    bitwise equality across compilation modes is not the contract)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    cfg = OpticalCoreConfig(apply_noise=True, fpv_sigma=0.02)
    key = jax.random.PRNGKey(5)
    fn = jax.jit(lambda a, b, k: photonic_matmul_sim(a, b, cfg,
                                                     noise_key=k))
    j1 = fn(x, w, key)
    j2 = fn(x, w, key)
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(j2))
    eager = photonic_matmul_sim(x, w, cfg, noise_key=key)
    np.testing.assert_allclose(np.asarray(j1), np.asarray(eager),
                               rtol=1e-5, atol=1e-5)


class TestMatmulStats:
    def test_single_tile(self):
        cfg = OpticalCoreConfig()
        s = matmul_stats(1, 32, 64, cfg)
        assert s.mr_tunings == 32 * 64            # one full tile tuned
        assert s.adc_conversions == 64            # one output row
        assert s.electronic_adds == 0             # single K chunk

    def test_k_chunking(self):
        cfg = OpticalCoreConfig()
        s = matmul_stats(1, 64, 64, cfg)          # 2 wavelength chunks
        assert s.mr_tunings == 2 * 32 * 64
        assert s.electronic_adds == 1 * 1 * 64    # (kc-1) partial merges

    def test_event_counts_scale_with_m(self):
        cfg = OpticalCoreConfig()
        s1 = matmul_stats(8, 128, 128, cfg)
        s2 = matmul_stats(16, 128, 128, cfg)
        assert s2.vcsel_cycles == 2 * s1.vcsel_cycles
        assert s2.adc_conversions == 2 * s1.adc_conversions
        assert s2.mr_tunings == s1.mr_tunings     # tuning is M-independent

    def test_core_parallelism_reduces_cycles(self):
        s1 = matmul_stats(64, 256, 256, OpticalCoreConfig(n_cores=1))
        s5 = matmul_stats(64, 256, 256, OpticalCoreConfig(n_cores=5))
        assert s5.cycles < s1.cycles
        assert s5.cycles >= s1.cycles // 5
