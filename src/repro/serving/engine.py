"""Single-session compatibility shell over the multi-stream StreamServer.

Historically this module *was* the serving engine; the implementation now
lives split across

  * ``repro.serving.session`` — per-stream state (``StreamSession``,
    ``ServingConfig``, ``StreamResult``),
  * ``repro.serving.server``  — shared state + the scheduling loop
    (``StreamServer``: prepared weight cache, warm-start jit ladder,
    cross-stream micro-batching, mesh-sharded encode).

``ServingEngine`` here is the migration path for single-stream callers: it
wraps one ``StreamServer`` (warm-start off, mesh off — the legacy lazy
single-device behaviour) and serves exactly one session per ``run``. Every
result is field-for-field what the pre-split engine produced, and the
pipeline it drives is the same five stages:

  1. **ingest** — chunks of consecutive frames from ``data.pipeline``
     (``VideoStream``), double-buffered to the device;
  2. **RoI gate** — MGNet region scores with temporal mask reuse
     (``TemporalMaskCache``);
  3. **token-budget bucketing** — ``BucketLadder`` routing + shared stable
     score order + same-bucket micro-batching (``MicroBatcher``);
  4. **encode** — ``forward_vit_tokens`` on the gathered tokens (with
     ``--attn-backend flash`` / ``--ffn-backend fused`` the fused Pallas
     hot path);
  5. **account** — per-flush ``EnergyReport``, live frames/s and KFPS/W.

New code should target ``StreamServer`` directly (multi-stream CLI:
``python -m repro.serving.server``). This CLI streams one session:

    PYTHONPATH=src python -m repro.serving.engine --smoke \\
        --backend photonic_pallas
"""

from __future__ import annotations

import argparse
import json

from repro.configs.base import ArchConfig, smoke_variant
from repro.core.backend import available_backends
from repro.data.pipeline import VideoStream
from repro.serving.server import (ServerConfig, StreamServer,
                                  _gather_topk_rows)
from repro.serving.session import ServingConfig, StreamResult

__all__ = ["ServingConfig", "StreamResult", "ServingEngine", "main"]


class ServingEngine:
    """Single-stream serving engine over one ViT + MGNet parameter set.

    A thin shell: one ``StreamServer`` built at construction (jits persist
    across ``run`` calls, exactly the old behaviour), one fresh session per
    ``run``. Warm-start and the device mesh stay off so cold-start cost and
    single-device numerics match the pre-split engine; use ``StreamServer``
    for eager warm-up, multi-stream multiplexing, or sharded serving.
    """

    def __init__(self, cfg: ArchConfig, serve_cfg: ServingConfig | None = None,
                 params: dict | None = None, n_classes: int = 10, seed: int = 0):
        sc = serve_cfg or ServingConfig()
        self.serve_cfg = sc
        # a plain ServingConfig gets the legacy defaults (lazy compile, no
        # mesh); an explicit ServerConfig is honored as-is — its deadline /
        # warm-start / mesh knobs are meaningful for one stream too
        server_cfg = (sc if isinstance(sc, ServerConfig)
                      else ServerConfig.from_serving(sc, warm_start=False,
                                                     mesh="off"))
        self.server = StreamServer(cfg, server_cfg, params=params,
                                   n_classes=n_classes, seed=seed)

    # legacy surface: the engine exposed these directly
    @property
    def cfg(self):
        return self.server.cfg

    @property
    def policy(self):
        return self.server.policy

    @property
    def params(self):
        return self.server.params

    @property
    def n_patches(self):
        return self.server.n_patches

    @property
    def ladder(self):
        return self.server.ladder

    @property
    def mcfg(self):
        return self.server.mcfg

    def run(self, stream: VideoStream, n_frames: int = 64, start: int = 0,
            verbose: bool = False) -> StreamResult:
        """Stream exactly ``n_frames`` frames through the bucketed path."""
        s = self.server.add_session(stream, n_frames=n_frames, start=start)
        res = self.server.serve(verbose=verbose)[s.sid]
        return res

    def run_dense(self, stream: VideoStream, n_frames: int = 64,
                  start: int = 0) -> StreamResult:
        """Mask-mode dense baseline: identical gating, every frame encoded
        at all N patches with the RoI mask on the attention key axis."""
        return self.server.run_dense(stream, n_frames=n_frames, start=start)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _smoke_cfg(backend: str, attn_backend: str = "",
               ffn_backend: str = "") -> ArchConfig:
    from repro.configs.opto_vit import get_config
    cfg = smoke_variant(get_config("tiny")).with_(
        mgnet=True, mgnet_keep_ratio=0.5, mgnet_embed=32, mgnet_heads=2)
    if backend:
        cfg = cfg.with_(matmul_backend=backend)
    if attn_backend:
        cfg = cfg.with_(attn_backend=attn_backend)
    if ffn_backend:
        cfg = cfg.with_(ffn_backend=ffn_backend)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU config (32x32 frames, 4 layers)")
    ap.add_argument("--variant", default="tiny")
    ap.add_argument("--img-size", type=int, default=96)
    ap.add_argument("--backend", default="photonic_pallas",
                    help=f"matmul backend ({', '.join(available_backends())})")
    ap.add_argument("--attn-backend", default="", choices=["", "xla", "flash"],
                    help="attention core: xla (materialized scores, default) "
                         "or flash (fused RoI-masked Pallas kernel)")
    ap.add_argument("--ffn-backend", default="", choices=["", "xla", "fused"],
                    help="GELU-MLP core: xla (composed two-linear, default) "
                         "or fused (fused int8 photonic FFN kernel — with "
                         "photonic_pallas + cached weights the hidden state "
                         "never leaves VMEM, and --one-shape prunes dead "
                         "token rows out of both FFN matmuls)")
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--mask-refresh", type=int, default=8)
    ap.add_argument("--delta-threshold", type=float, default=0.15)
    ap.add_argument("--buckets", default="0.25,0.5,0.75,1.0")
    ap.add_argument("--one-shape", action="store_true",
                    help="fixed-sensor-buffer mode: encode all frames at "
                         "the ladder cap with a static packed kept-count "
                         "per bucket (flash backend skips the dead tail)")
    ap.add_argument("--cut-every", type=int, default=32)
    ap.add_argument("--compare-dense", action="store_true",
                    help="also run the mask-mode dense baseline")
    ap.add_argument("--json", default="",
                    help="write the StreamResult to this path")
    args = ap.parse_args(argv)

    if args.backend and args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")
    if args.smoke:
        cfg = _smoke_cfg(args.backend, args.attn_backend, args.ffn_backend)
    else:
        from repro.configs.opto_vit import get_config
        cfg = get_config(args.variant, img_size=args.img_size,
                         mgnet=True).with_(matmul_backend=args.backend,
                                           attn_backend=args.attn_backend,
                                           ffn_backend=args.ffn_backend)

    serve_cfg = ServingConfig(
        bucket_fractions=tuple(float(f) for f in args.buckets.split(",")),
        microbatch=args.microbatch, chunk=args.chunk,
        mask_refresh=args.mask_refresh,
        delta_threshold=args.delta_threshold, one_shape=args.one_shape)
    engine = ServingEngine(cfg, serve_cfg)
    print(f"[serve] {cfg.name} {cfg.img_size}x{cfg.img_size} "
          f"backend={engine.policy.resolve_backend()} "
          f"attn={engine.policy.resolve_attn_backend()} "
          f"ffn={engine.policy.resolve_ffn_backend()} "
          f"ladder={list(engine.ladder.sizes)} of {engine.n_patches} patches")

    stream = VideoStream(img_size=cfg.img_size, patch=cfg.patch,
                         cut_every=args.cut_every)
    res = engine.run(stream, n_frames=args.frames, verbose=True)
    print("[serve]", res.summary())

    if args.compare_dense:
        dense = engine.run_dense(stream, n_frames=args.frames)
        print("[serve] dense baseline:", dense.summary())
        if dense.fps > 0:
            print(f"[serve] bucketed speedup: {res.fps / dense.fps:.2f}x "
                  "frames/s over mask-mode dense")

    if args.json:
        payload = {
            "frames": res.frames, "fps": res.fps,
            "kfps_per_watt": res.kfps_per_watt,
            "mean_frame_uj": res.mean_frame_uj,
            "bucket_hits": res.bucket_hits,
            "bucket_launches": res.bucket_launches,
            "scored_frames": res.scored_frames,
            "reused_frames": res.reused_frames,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve] wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
