"""Opto-ViT backbones (paper Table I): ViT Tiny/Small/Base/Large with the
paper's co-design: 8-bit QAT, photonic matmul execution, MGNet RoI
pruning, Eq. 2 decomposed attention. Defaults: 224x224, patch 16."""

from repro.configs.base import ArchConfig

_VARIANTS = {
    #          L   d     H   d_ff
    "tiny":  (12, 192,   3,  768),
    "small": (12, 384,   6, 1536),
    "base":  (12, 768,  12, 3072),
    "large": (24, 1024, 16, 4096),
}


def get_config(variant: str = "base", img_size: int = 224,
               quant_bits: int = 8, mgnet: bool = False,
               mgnet_keep_ratio: float = 0.33) -> ArchConfig:
    l, d, h, dff = _VARIANTS[variant]
    return ArchConfig(
        name=f"opto-vit-{variant}", family="vit",
        n_layers=l, d_model=d, n_heads=h, kv_heads=h,
        d_ff=dff, vocab=0,
        img_size=img_size, patch=16,
        quant_bits=quant_bits,
        mgnet=mgnet, mgnet_keep_ratio=mgnet_keep_ratio,
        remat=False,
    )
