"""whisper-medium [audio]: enc-dec, 24L decoder d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865; 24L encoder over 1500 stub frame embeddings
(arXiv:2212.04356). Conv/mel frontend is a STUB: input_specs supplies
precomputed frame embeddings (B, 1500, 1024)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, kv_heads=16,
        d_ff=4096, vocab=51865,
        enc_layers=24, enc_frames=1500, d_frontend=1024,
        rope_theta=10000.0,
        microbatch_steps=1,
    )
