"""Robustness differential suite: clean-vs-noisy serving under drift.

The calibrated noise layer (core/noise.py ``NoiseSpec`` + ``DriftState``)
models the paper's device reality at the Q = 5000 / 8-bit operating point:
the WDM crosstalk floor, ~1% fabrication-process variation, shot noise on
the balanced-photodetector readout, and thermal resonance drift that
accumulates per frame until an MR re-tune pulls the rings back on grid.
This bench gates the three claims that make that layer a *serving* feature
rather than a noise study:

  1. **Calibrated operating point is usable**: clean-vs-noisy prediction
     agreement at the static Q = 5000 point (no drift) is >= 95% on every
     backend combo the server dispatches — photonic_sim, photonic_pallas
     composed, and the fused flash+FFN path (which under noise falls back
     to the composed analog dispatch by design). Measured on a *trained*
     smoke model: random-init logits are near-tied and their argmax flips
     under any perturbation, so random-init "agreement" measures logit
     degeneracy, not robustness (the mixed_precision_bench lesson).
  2. **Drift degrades, monotonically in the large**: agreement and
     accuracy are swept over pinned common-mode drift values spanning the
     benign-to-catastrophic range of the Lorentzian linewidth
     (delta ~= 0.155 nm at Q = 5000); the endpoint (0.4 nm) must sit
     strictly below the on-resonance level.
  3. **Recalibration restores**: a served stream whose DriftState drifts
     past ``recal_bound_nm`` triggers the server's online re-tune
     (re-running the quantize-once ``prepare_params`` cache and resetting
     the drift). The gate: >= 1 recalibration fires, and post-recal
     agreement returns to within 1% of the pre-drift level — while the
     same stream served *without* recalibration decays in its late
     window. Clean and noisy servers share routing (the RoI gate stays
     clean by default), so agreement is frame-by-frame comparable.

Results merge into BENCH_serving.json under "robustness".

    PYTHONPATH=src python -m benchmarks.robustness_bench           # full
    PYTHONPATH=src python -m benchmarks.robustness_bench --smoke   # CI fast
"""

from __future__ import annotations

import argparse
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import prepare_params
from repro.core.noise import DriftState, NoiseSpec, scoped
from repro.data.pipeline import ImageStream, VideoStream, quadrant_labels
from repro.models.vit import forward_vit, init_vit
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer

AGREEMENT_GATE = 0.95
COMBOS = [("photonic_sim", "", ""),
          ("photonic_pallas", "", ""),
          ("photonic_pallas", "flash", "fused")]
DRIFTS = (0.0, 0.05, 0.1, 0.2, 0.4)
# static calibrated point (gate 1) and the drift sweep's wander (gate 2)
SPEC_CAL = NoiseSpec()
SPEC_CURVE = NoiseSpec(wander_sigma_nm=0.02)
# serving drift: 0.005 nm/frame against a 0.06 nm re-tune bound -> a
# recalibration every 12 frames, always inside the benign fraction of the
# linewidth (through-gain >= 0.87 at the bound)
SPEC_SERVE = NoiseSpec(drift_rate_nm=0.005, wander_sigma_nm=0.01,
                       recal_bound_nm=0.06)
TRAIN_STEPS = 300
EVAL_BATCHES = 8                # 8 x 32 = 256 frames per agreement gate
SERVE_FRAMES = 144              # recal gate: 12 re-tunes, 0.69%/frame
#                                 agreement granularity (the 1% restoration
#                                 gate needs sub-1% resolution)
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _train_smoke(steps=TRAIN_STEPS, seed=0):
    """Fit the planted-box quadrant task so predictions carry real margins.

    Params are initialized under the *serving* config (MGNet included) but
    trained dense on the bf16 backend: the gate's scores stay random-init
    (zero gradient), which is fine — the bench's metric is agreement, and
    the serving gate runs clean under noise either way."""
    cfg_mg = _smoke_cfg("photonic_pallas")
    cfg_tr = cfg_mg.with_(mgnet=False, matmul_backend="bf16")
    stream = ImageStream(img_size=cfg_mg.img_size, global_batch=32,
                         n_classes=8, patch=cfg_mg.patch, seed=seed)
    params = init_vit(jax.random.PRNGKey(seed), cfg_mg, n_classes=4)

    def loss_fn(p, images, labels):
        lg, _ = forward_vit(p, images, cfg_tr)
        lf = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, -1)
        gold = jnp.take_along_axis(lf, labels[:, None], -1)[:, 0]
        return (lse - gold).mean()

    @jax.jit
    def step(p, images, labels):
        _, g = jax.value_and_grad(loss_fn)(p, images, labels)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)

    for i in range(steps):
        b = stream.batch_at(i)
        params = step(params, b["images"], quadrant_labels(b["patch_mask"]))
    return params, stream


def _combo_cfg(backend, attn, ffn, spec=None):
    """Dense (gate-free) eval config for one backend combo."""
    cfg = _smoke_cfg(backend, attn, ffn).with_(mgnet=False)
    return cfg.with_(noise=spec) if spec is not None else cfg


def _eval(prep, cfg, stream, n_batches, spec=None, drift=None, seed=11):
    """Predictions (+ gold) over held-out batches; noisy when ``spec``."""
    if spec is None:
        fwd = jax.jit(lambda p, im: forward_vit(p, im, cfg)[0])

        def logits(im, j):
            return fwd(prep, im)
    else:
        nfwd = jax.jit(lambda p, im, ns: scoped(
            ns, lambda: forward_vit(p, im, cfg)[0]))
        state = DriftState.init(seed)
        if drift is not None:
            state = state.with_drift(drift)
        states = []
        for _ in range(n_batches):
            states.append(state)
            state = state.advance(spec, 32)
            if drift is not None:        # pinned sweep: fresh keys, fixed d
                state = state.with_drift(drift)

        def logits(im, j):
            return nfwd(prep, im, states[j])

    preds, gold = [], []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # fused->composed fallback notices
        for j in range(n_batches):
            b = stream.batch_at(1000 + j)        # held-out batches
            preds.append(np.argmax(np.asarray(logits(b["images"], j)), -1))
            gold.append(np.asarray(quadrant_labels(b["patch_mask"])))
    return np.concatenate(preds), np.concatenate(gold)


def _agreement_gates(params, stream, smoke) -> dict:
    """Gate 1: clean-vs-noisy agreement per backend combo."""
    prep = prepare_params(params, bits=8)
    combos = COMBOS[1:2] if smoke else COMBOS
    n_batches = 4 if smoke else EVAL_BATCHES
    rows = {}
    for backend, attn, ffn in combos:
        name = "+".join(x for x in (backend, attn, ffn) if x)
        cfg_c = _combo_cfg(backend, attn, ffn)
        p_c, gold = _eval(prep, cfg_c, stream, n_batches)
        p_n, _ = _eval(prep, _combo_cfg(backend, attn, ffn, SPEC_CAL),
                       stream, n_batches, spec=SPEC_CAL)
        agree = float((p_n == p_c).mean())
        acc_c = float((p_c == gold).mean())
        acc_n = float((p_n == gold).mean())
        print(f"  {name:<32} clean acc {acc_c:.3f} | noisy acc {acc_n:.3f} "
              f"| agreement {agree:.4f} ({len(p_c)} frames)")
        assert agree >= AGREEMENT_GATE, (
            f"clean-vs-noisy agreement on {name} at the calibrated Q=5000 "
            f"point must be >= {AGREEMENT_GATE:.0%}; measured {agree:.4f}")
        rows[name] = {"agreement": agree, "acc_clean": acc_c,
                      "acc_noisy": acc_n, "frames": int(len(p_c))}
    return rows


def _drift_curve(params, stream) -> dict:
    """Gate 2: agreement/accuracy under pinned common-mode drift."""
    prep = prepare_params(params, bits=8)
    cfg_c = _combo_cfg(*COMBOS[1][:3])
    cfg_n = _combo_cfg(*COMBOS[1][:3], spec=SPEC_CURVE)
    p_c, gold = _eval(prep, cfg_c, stream, EVAL_BATCHES)
    curve = {}
    for d in DRIFTS:
        p_n, _ = _eval(prep, cfg_n, stream, EVAL_BATCHES,
                       spec=SPEC_CURVE, drift=d)
        curve[d] = {"agreement": float((p_n == p_c).mean()),
                    "accuracy": float((p_n == gold).mean())}
        print(f"  drift {d:4.2f} nm: agreement {curve[d]['agreement']:.4f} "
              f"| accuracy {curve[d]['accuracy']:.3f}")
    assert curve[DRIFTS[-1]]["agreement"] < curve[0.0]["agreement"], (
        f"{DRIFTS[-1]} nm of uncompensated drift (beyond the Q=5000 "
        f"linewidth) must degrade agreement below the on-resonance level; "
        f"measured {curve[DRIFTS[-1]]['agreement']:.4f} vs "
        f"{curve[0.0]['agreement']:.4f}")
    return {str(d): v for d, v in curve.items()}


def _serve_preds(params, spec, n_frames, stream_seed=5):
    cfg = _smoke_cfg("photonic_pallas").with_(noise=spec)
    sc = ServerConfig(warm_start=False, mesh="off", chunk=8, microbatch=4)
    srv = StreamServer(cfg, sc, params=params, seed=0)
    st = VideoStream(img_size=cfg.img_size, patch=cfg.patch,
                     seed=stream_seed, cut_every=16)
    s = srv.add_session(st, n_frames=n_frames)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = srv.serve()[s.sid]
    return np.array([res.predictions[i] for i in range(n_frames)]), srv, res


def _recal_serving(params, smoke) -> dict:
    """Gate 3: drift past the bound fires the online re-tune and restores
    agreement; the same stream without recalibration decays.

    "Pre-drift level" is the *undrifted* noisy server's full-run agreement
    vs the clean server — the same fpv/shot/wander stochastics with the
    drift channel off, measured over all frames so the 1% restoration gate
    has sub-1% resolution (a per-cycle window of 12 frames quantizes
    agreement in 8.3% steps; a single thin-margin frame would swamp it)."""
    n = SERVE_FRAMES
    p_clean, _, _ = _serve_preds(params, None, n)
    spec_base = NoiseSpec(wander_sigma_nm=SPEC_SERVE.wander_sigma_nm)
    p_base, _, _ = _serve_preds(params, spec_base, n)
    a_pre = float((p_base == p_clean).mean())
    p_rec, srv, res = _serve_preds(params, SPEC_SERVE, n)
    agree = (p_rec == p_clean)
    a_rec = float(agree.mean())
    print(f"  recal serving ({n} frames, bound "
          f"{SPEC_SERVE.recal_bound_nm:g} nm): {srv.recalibrations} "
          f"re-tunes | pre-drift (undrifted) agreement {a_pre:.4f} | "
          f"drifting+recal {a_rec:.4f} | billed {res.recalibrations} "
          f"to the stream")
    assert srv.recalibrations >= 1, (
        "drift past recal_bound_nm must trigger at least one online "
        "recalibration")
    assert res.recalibrations == srv.recalibrations, (
        "every re-tune must be billed to the live stream's accounting")
    assert a_rec >= a_pre - 0.01 - 1e-9, (
        f"agreement under drift with recalibration must stay within 1% of "
        f"the pre-drift level; {a_rec:.4f} vs {a_pre:.4f}")
    out = {"frames": n, "recalibrations": int(srv.recalibrations),
           "agreement_pre_drift": a_pre, "agreement_recal": a_rec}

    if not smoke:
        # counterfactual: same stream, same drift, no re-tune bound — the
        # rings walk out to n * rate nm and the late window decays
        spec_off = NoiseSpec(drift_rate_nm=SPEC_SERVE.drift_rate_nm,
                             wander_sigma_nm=SPEC_SERVE.wander_sigma_nm)
        p_off, _, _ = _serve_preds(params, spec_off, n)
        off = (p_off == p_clean)
        off_full, off_late = float(off.mean()), float(off[-24:].mean())
        rec_late = float(agree[-24:].mean())
        print(f"  without recalibration: drift reaches "
              f"{n * spec_off.drift_rate_nm:.2f} nm, agreement "
              f"{off_full:.4f} full / {off_late:.4f} late window "
              f"(vs {rec_late:.4f} with re-tuning)")
        assert off_late < rec_late, (
            f"unbounded drift must decay the late window below the "
            f"recalibrated server's; {off_late:.4f} vs {rec_late:.4f}")
        out.update({"agreement_no_recal": off_full,
                    "agreement_late_no_recal": off_late,
                    "agreement_late_recal": rec_late,
                    "final_drift_no_recal_nm": n * spec_off.drift_rate_nm})
    return out


def run(smoke: bool = False) -> dict:
    print("\n== robustness: calibrated device noise, drift, recalibration ==")
    params, stream = _train_smoke(steps=150 if smoke else TRAIN_STEPS)
    payload = {"spec": {"q_factor": SPEC_CAL.q_factor,
                        "fpv_sigma": SPEC_CAL.fpv_sigma,
                        "shot_sigma": SPEC_CAL.shot_sigma,
                        "wander_sigma_nm": SPEC_CURVE.wander_sigma_nm}}
    payload["agreement"] = _agreement_gates(params, stream, smoke)
    if smoke:
        payload["recalibration"] = _recal_serving(params, smoke=True)
        print("  (smoke mode: drift curve + BENCH json skipped)")
        return payload
    payload["drift_curve"] = _drift_curve(params, stream)
    payload["recalibration"] = _recal_serving(params, smoke=False)

    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["robustness"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON} [robustness]")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one-combo agreement gate + short recal serving "
                         "(fast CI): skips the drift sweep, the "
                         "no-recalibration counterfactual and the JSON "
                         "merge")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
