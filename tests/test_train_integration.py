"""System integration: train loop + checkpoint resume + serve generate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig, smoke_variant
from repro.configs.registry import get_config
from repro.distributed.sharding import use_sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate, init_cache
from repro.launch.train import init_state, make_stream, train_loop
from repro.models import api as model_api


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


SHAPE = ShapeConfig("itest", seq_len=32, global_batch=4, kind="train")


def test_train_loss_decreases(mesh):
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2,
                                                        lr_warmup=5)
    shape = ShapeConfig("loss", seq_len=32, global_batch=8, kind="train")
    with mesh, use_sharding(mesh):
        _, losses, _ = train_loop(cfg, shape, 100, log_every=1000)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_resume_bit_identical(mesh, tmp_path):
    """Train 10 steps straight vs 5 + resume + 5: identical final loss
    (deterministic data + state restore)."""
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    with mesh, use_sharding(mesh):
        _, losses_straight, _ = train_loop(cfg, SHAPE, 10, log_every=1000)

        mgr = CheckpointManager(str(tmp_path), every=5, keep=2)
        train_loop(cfg, SHAPE, 5, ckpt=mgr, log_every=1000)
        _, losses_resumed, _ = train_loop(cfg, SHAPE, 10, ckpt=mgr,
                                          log_every=1000)
    np.testing.assert_allclose(losses_straight[5:], losses_resumed,
                               rtol=1e-5)


def test_microbatch_equivalence(mesh):
    """Gradient accumulation (k=2) must match the single-shot step within
    fp tolerance on the first step's loss and produce finite updates."""
    base = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    from repro.launch.steps import make_train_fn
    batch_at = None
    with mesh, use_sharding(mesh):
        state1 = init_state(base, seed=0)
        state2 = init_state(base.with_(microbatch_steps=2), seed=0)
        batch = make_stream(base, SHAPE, seed=0)(0)
        s1, m1 = jax.jit(make_train_fn(base))(state1, batch)
        s2, m2 = jax.jit(make_train_fn(
            base.with_(microbatch_steps=2)))(state2, batch)
    # same data, same params -> same mean loss; grads averaged vs summed
    # per-microbatch may differ slightly in clip norm
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-2)


def test_serve_generates_tokens(mesh):
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    with mesh, use_sharding(mesh):
        params = model_api.init_model(jax.random.PRNGKey(0), cfg)
        cache = init_cache(cfg, batch=2, seq_len=64)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    cfg.vocab, jnp.int32)
        toks, tps = generate(params, cache, prompt, 6, cfg)
    assert toks.shape == (2, 6)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab
    assert tps > 0


def test_ssm_serve(mesh):
    """Decode works for the recurrent-state family too (no KV cache)."""
    cfg = smoke_variant(get_config("mamba2-780m")).with_(n_layers=2)
    with mesh, use_sharding(mesh):
        params = model_api.init_model(jax.random.PRNGKey(0), cfg)
        cache = init_cache(cfg, batch=2, seq_len=64)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                    cfg.vocab, jnp.int32)
        toks, _ = generate(params, cache, prompt, 4, cfg)
    assert toks.shape == (2, 4)


def test_fault_injection_resume(mesh, tmp_path):
    """Injected fault mid-run + run_with_restarts-style retry via the
    train_loop checkpoint path."""
    cfg = smoke_variant(get_config("qwen2-1.5b")).with_(n_layers=2)
    mgr = CheckpointManager(str(tmp_path), every=3, keep=3)
    with mesh, use_sharding(mesh):
        with pytest.raises(RuntimeError, match="injected"):
            train_loop(cfg, SHAPE, 10, ckpt=mgr, log_every=1000,
                       inject_fault_at=7)
        # resume: restores from step 6 checkpoint and completes
        _, losses, _ = train_loop(cfg, SHAPE, 10, ckpt=mgr, log_every=1000)
    assert len(losses) == 4            # steps 6..9 re-run
