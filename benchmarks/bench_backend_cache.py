"""Quantize-once weight cache micro-benchmark (core/backend.py).

The paper's optical core tunes each MR weight tile once and streams
activations through it; the software analogue is ``prepare_params``, which
pre-computes int8 codes + per-out-channel scales for the whole param tree.
This benchmark times the same photonic ViT forward with raw params (weights
re-quantized inside every call) vs prepared params (activation quant +
integer matmul + dequant only) and asserts the cached path is strictly
faster — the dequant/requant work removed scales with sum(K*N) per forward,
which rivals the matmul itself at the paper's small serving M (37 tokens).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.backend import prepare_params
from repro.models.vit import forward_vit, init_vit

REPEATS = 5
ITERS = 20


def _time_forward(fwd, params, imgs) -> float:
    """Best (min) per-iteration wall-clock over REPEATS timed batches —
    min is the noise-robust statistic for microbenchmarks on a shared
    host (background load only ever adds time)."""
    jax.block_until_ready(fwd(params, imgs))          # compile + warm cache
    samples = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            out = fwd(params, imgs)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / ITERS)
    return float(np.min(samples))


def run() -> dict:
    print("\n== quantize-once weight cache: cached vs uncached photonic "
          "forward ==")
    cfg = smoke_variant(get_config("tiny")).with_(
        n_layers=4, matmul_backend="photonic_sim")
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    prepared = prepare_params(params, bits=8)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (8, cfg.img_size, cfg.img_size, 3))

    fwd = jax.jit(lambda p, im: forward_vit(p, im, cfg)[0])

    # numerics first: the cache leaves the integer accumulates untouched;
    # logits agree up to XLA reassociation of the f32 dequant epilogue.
    lg_raw = np.asarray(fwd(params, imgs))
    lg_cached = np.asarray(fwd(prepared, imgs))
    np.testing.assert_allclose(lg_raw, lg_cached, rtol=1e-5, atol=1e-5)
    print("  cached == uncached logits (up to fp reassociation)")

    t_raw = _time_forward(fwd, params, imgs)
    t_cached = _time_forward(fwd, prepared, imgs)
    speedup = t_raw / t_cached
    print(f"  uncached (per-call weight re-quant): {t_raw * 1e3:8.3f} ms")
    print(f"  cached   (quantize-once weights)   : {t_cached * 1e3:8.3f} ms")
    print(f"  speedup: {speedup:.2f}x")
    assert t_cached < t_raw, \
        f"cache must be strictly faster: {t_cached:.6f}s vs {t_raw:.6f}s"
    return {"uncached_s": t_raw, "cached_s": t_cached, "speedup": speedup}


if __name__ == "__main__":
    run()
