"""Unified matmul execution backend: registry + quantize-once weight cache.

The paper's optical core tunes each MR weight tile *once* and then streams
activations through it (Fig. 6); re-deriving weight quantization scales on
every forward call has no hardware analogue and wastes the dominant dataflow
lever (Lightening-Transformer makes the same observation for DPTC arrays).
This module is the software analogue of that design point:

  * ``ExecPolicy``        - execution-mode knobs threaded from ArchConfig
    into every layer (moved here from models/layers.py so core modules can
    route through the same dispatch without a models dependency),
  * a **backend registry** of interchangeable matmul implementations::

        bf16            plain MXU dot (f32 accumulate), the LM default
        qat             fake-quant w8a8 (STE in training) - paper SIV
        photonic_sim    chunk-walking w8a8 integer oracle (Fig. 6 schedule)
        photonic_pallas int8 Pallas MXU kernel (kernels/photonic_matmul.py)

    All photonic backends share one numerics contract: their int32
    accumulates are bit-identical to ``photonic_matmul_exact`` (enforced by
    tests/test_backend_parity.py),
  * ``QuantizedWeight`` + ``prepare_params``: the **quantize-once cache**.
    ``prepare_params`` walks a param pytree and replaces every matmul weight
    with its pre-computed int8 codes + per-output-channel scale (the MR
    tuning step). The per-call photonic path then does only activation
    quantization + integer matmul + dequant.

``linear`` is the single entry point every model matmul funnels through.

Attention has its own (smaller) registry: the score-softmax-PV core of
every MHSA dataflow funnels through ``attend``, dispatching between

    xla     materialized (Sq, Skv) scores + additive key-mask bias +
            jax.nn.softmax — the reference dataflow
    flash   fused RoI-masked streaming-softmax flash attention
            (kernels/flash_attention.py): pruned KV blocks are skipped, so
            masked patches cost zero score FLOPs on the serving hot path.
            Lowers to the Pallas kernel on TPU and to the XLA twin with
            static packed-skip on CPU hosts (``ExecPolicy.interpret``)

selected by ``ExecPolicy.attn_backend`` (ArchConfig.attn_backend). The two
backends agree to streaming-softmax reassociation noise (enforced per
dataflow by tests/test_differential.py).

The GELU-MLP has a third registry (``FFN_BACKENDS``) behind the same
policy object — ``ExecPolicy.ffn_backend`` / ``ArchConfig.ffn_backend``:

    xla     composed two-``linear`` dispatch with the float GELU
            round-trip between them — the reference dataflow, runs on
            every matmul backend
    fused   the fused int8 photonic FFN (kernels/fused_ffn.py): w1-matmul
            + bias + GELU + requant + w2-matmul in one kernel, the
            (B, S, d_ff) hidden state never reaching HBM; packed
            ``live_rows`` skips fully-pruned token rows. Requires the
            int8 Pallas matmul backend + quantize-once cached w1/w2 at
            <= 8-bit (possibly different — mixed-precision bit plans)
            widths — anything else falls back to the composed dispatch
            with a one-time warning (same auto-fallback contract as the
            fused MHSA hot path). Bit-identical to ``xla`` where both run.

``ffn`` is the dispatch point ``models/ffn.py::mlp`` funnels through.
"""

from __future__ import annotations

import math
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import quant

__all__ = [
    "ExecPolicy",
    "QuantizedWeight",
    "quantize_weight",
    "prepare_params",
    "warn_fused_fallback",
    "reset_fused_fallback_warnings",
    "register_backend",
    "get_backend",
    "available_backends",
    "register_attention_backend",
    "get_attention_backend",
    "available_attention_backends",
    "register_ffn_backend",
    "get_ffn_backend",
    "available_ffn_backends",
    "matmul",
    "linear",
    "attend",
    "ffn",
    "int_accumulate_exact",
    "int_accumulate_sim",
    "int_accumulate_pallas",
]

# photonic K-chunk width (32 WDM wavelength channels, paper Fig. 3b)
_WAVELENGTHS = 32


class ExecPolicy:
    """Execution-mode knobs threaded from ArchConfig into every layer.

    ``backend`` names a registry entry explicitly; when empty the legacy
    flags resolve it: photonic -> photonic_sim, quant_bits -> qat, else bf16.
    ``attn_backend`` names an attention-core registry entry ("" -> xla);
    ``ffn_backend`` an FFN registry entry ("" -> xla).
    ``interpret`` runs Pallas kernels in interpreter mode (CPU hosts); set
    False on a real TPU deployment.
    ``bit_plan`` is the hashable identity of the active mixed-precision
    plan (``core.bitalloc.plan_key`` output, or a bare per-layer tuple) —
    None means uniform ``quant_bits``. Setting it does two things: the
    plan joins ``fingerprint()`` (so jit caches key on it) and
    ``_weight_bits`` accepts cached widths that differ from
    ``quant_bits`` (deliberate per-layer divergence instead of a stale
    cache, which without a plan is an error).
    """

    __slots__ = ("quant_bits", "photonic", "training", "dot_out_native",
                 "backend", "interpret", "attn_backend", "ffn_backend",
                 "bit_plan", "noise")

    def __init__(self, quant_bits: int = 0, photonic: bool = False,
                 training: bool = True, dot_out_native: bool = False,
                 backend: str = "", interpret: bool = True,
                 attn_backend: str = "", ffn_backend: str = "",
                 bit_plan=None, noise=None):
        self.quant_bits = quant_bits
        self.photonic = photonic
        self.training = training
        self.dot_out_native = dot_out_native
        self.backend = backend
        self.interpret = interpret
        self.attn_backend = attn_backend
        self.ffn_backend = ffn_backend
        self.bit_plan = (tuple(bit_plan) if isinstance(bit_plan, list)
                         else bit_plan) or None
        # calibrated device-noise operating point (core/noise.py NoiseSpec,
        # hashable) — None is the clean path, bitwise identical to a policy
        # built before the field existed
        self.noise = noise

    @staticmethod
    def from_cfg(cfg, training: bool = True) -> "ExecPolicy":
        return ExecPolicy(getattr(cfg, "quant_bits", 0),
                          getattr(cfg, "photonic", False), training,
                          getattr(cfg, "dot_out_native", False),
                          getattr(cfg, "matmul_backend", "") or "",
                          getattr(cfg, "pallas_interpret", True),
                          getattr(cfg, "attn_backend", "") or "",
                          getattr(cfg, "ffn_backend", "") or "",
                          getattr(cfg, "bit_plan", None) or None,
                          getattr(cfg, "noise", None))

    def resolve_backend(self) -> str:
        if self.backend:
            return self.backend
        if self.photonic:
            return "photonic_sim"
        if self.quant_bits:
            return "qat"
        return "bf16"

    def resolve_attn_backend(self) -> str:
        return self.attn_backend or "xla"

    def resolve_ffn_backend(self) -> str:
        return self.ffn_backend or "xla"

    def is_photonic(self) -> bool:
        return self.resolve_backend().startswith("photonic")

    def without_noise(self) -> "ExecPolicy":
        """Clean copy of this policy (noise stripped); self when already
        clean, so clean policies keep object identity."""
        if self.noise is None:
            return self
        return ExecPolicy(self.quant_bits, self.photonic, self.training,
                          self.dot_out_native, self.backend, self.interpret,
                          self.attn_backend, self.ffn_backend, self.bit_plan,
                          None)

    def gate_policy(self) -> "ExecPolicy":
        """Policy for the MGNet RoI gate: by default the gate runs *clean*
        even under noise (its tiny electronic-side matmuls would otherwise
        make the routing — and hence every bucket shape — stochastic);
        ``NoiseSpec.noisy_gate`` opts the gate into the noise model."""
        if self.noise is None or self.noise.noisy_gate:
            return self
        return self.without_noise()

    def fingerprint(self) -> tuple:
        """Hashable identity of every dispatch-relevant knob — the jit
        cache key for policy-closing compiled entry points (models/vit.py
        keys its single-jit fused encoder on this)."""
        return (self.resolve_backend(), self.resolve_attn_backend(),
                self.resolve_ffn_backend(), self.quant_bits,
                bool(self.interpret), bool(self.training),
                bool(self.dot_out_native), self.bit_plan, self.noise)

    def __repr__(self):
        noise = ", noise=on" if self.noise is not None else ""
        return (f"ExecPolicy(backend={self.resolve_backend()!r}, "
                f"attn={self.resolve_attn_backend()!r}, "
                f"ffn={self.resolve_ffn_backend()!r}, "
                f"bits={self.quant_bits}, training={self.training}{noise})")


_DEFAULT = ExecPolicy()


# --------------------------------------------------------------------------
# quantize-once weight cache
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QuantizedWeight:
    """A matmul weight after MR tuning: int8 codes + per-out-channel scale.

    ``wq``: (..., K, N) int8 codes; ``scale``: (..., 1, N) f32. Leading dims
    carry scan-stacked layers — ``jax.lax.scan`` slices both leaves in step,
    so an in-scan slice is exactly the (K, N)/(1, N) pair the 2-D backends
    consume. Registered as a pytree so prepared params flow through jit/scan
    unchanged.

    ``bits`` is an int, or — for scan-stacked (L, K, N) weights under a
    mixed-precision bit plan — a length-L tuple of per-layer widths. The
    tuple lives in the pytree aux data, so a plan change retraces every
    jit that closes over the params (the treedef is the cache key). 2-D
    weights always carry an int; the scanned encoder slices stacked
    weights into equal-bits runs before any 2-D dispatch sees them
    (models/vit.py), so ``linear`` never meets a tuple.
    """

    def __init__(self, wq: jax.Array, scale: jax.Array, bits=8):
        self.wq = wq
        self.scale = scale
        self.bits = tuple(bits) if isinstance(bits, list) else bits

    def layer_bits(self, i: int) -> int:
        """Width of stacked layer ``i`` (an int ``bits`` is uniform)."""
        return self.bits[i] if isinstance(self.bits, tuple) else self.bits

    def uniform_bits(self) -> int | None:
        """The single width when uniform, else None (mixed stacked)."""
        if isinstance(self.bits, tuple):
            u = set(self.bits)
            return u.pop() if len(u) == 1 else None
        return self.bits

    @property
    def shape(self):
        return self.wq.shape

    @property
    def ndim(self):
        return self.wq.ndim

    def dequantize(self) -> jax.Array:
        return self.wq.astype(jnp.float32) * self.scale

    def tree_flatten(self):
        return (self.wq, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, bits=aux[0])

    def __repr__(self):
        return f"QuantizedWeight(shape={self.wq.shape}, bits={self.bits})"


def quantize_weight(w: jax.Array, bits=8) -> QuantizedWeight:
    """Pre-compute int8 codes + scale for one weight (the MR tuning step).

    The scale reduces only the contraction axis (-2), i.e. per output
    channel *per layer* for scan-stacked (L, K, N) weights — numerically
    identical to the per-call ``absmax_scale(w2d, axis=0)`` of the dynamic
    photonic path, which is what makes cached and uncached execution
    bit-identical.

    ``bits`` may be a per-layer sequence for a scan-stacked weight (one
    entry per leading-dim layer): each layer slice is quantized at its own
    width — bit-identical to quantizing the 2-D slices separately — and
    the codes/scales re-stacked into one cache entry.
    """
    w32 = w.astype(jnp.float32)
    if isinstance(bits, (tuple, list)):
        bt = tuple(int(b) for b in bits)
        if w32.ndim < 3 or w32.shape[0] != len(bt):
            raise ValueError(
                f"per-layer bits {bt} need a scan-stacked "
                f"(L={len(bt)}, K, N) weight, got shape {w.shape}")
        if len(set(bt)) == 1:
            bits = bt[0]                       # uniform plan: int fast path
        else:
            parts = [quantize_weight(w32[i], bt[i]) for i in range(len(bt))]
            return QuantizedWeight(jnp.stack([p.wq for p in parts]),
                                   jnp.stack([p.scale for p in parts]), bt)
    scale = quant.absmax_scale(w32, bits=bits, axis=-2)     # (..., 1, N)
    return QuantizedWeight(quant.quantize(w32, scale, bits=bits), scale, bits)


# param-tree keys whose leaves must stay raw arrays even when they look like
# matmul weights: class tokens / position tables (added, never contracted),
# embedding tables (gathered), depthwise-conv kernels (indexed), and the MoE
# expert subtree (einsum dispatch, not routed through ``linear``).
NON_MATMUL_KEYS = frozenset({
    "cls", "pos", "cls_token", "pos_embed", "embed", "embedding", "tok_embed",
    "wte", "conv_w", "moe",
    "w_a", "w_x",   # RG-LRU recurrence gates: consumed raw in the f32 scan
})

# leaf keys that name a ``linear`` weight without the conventional "w"
# prefix (w / w1 / wq / wqkv / w_gate / ... are matched by prefix).
MATMUL_WEIGHT_EXTRA = frozenset({
    "head", "head_w", "in_proj", "out_proj", "gate_proj",
})


def _is_matmul_weight_key(name: str) -> bool:
    return name.startswith("w") or name in MATMUL_WEIGHT_EXTRA


def _path_key(entry) -> str:
    # DictKey(key=...) / GetAttrKey(name=...) / SequenceKey(idx=...)
    return str(getattr(entry, "key", getattr(entry, "name", "")))


def prepare_params(params, bits: int = 8, min_size: int = 128,
                   exclude: frozenset = NON_MATMUL_KEYS,
                   bit_plan=None, n_layers: int | None = None):
    """Quantize every matmul weight of a param pytree once (MR tuning pass).

    A leaf is tuned iff its key names a ``linear`` weight (``w*`` prefix or
    ``MATMUL_WEIGHT_EXTRA``), no path component is in ``exclude``, and it is
    a float tensor of ndim >= 2 with at least ``min_size`` elements. Biases,
    norm scales, cls/pos tables and embeddings stay full precision —
    mirroring the paper's choice of quantizing only the optical-core
    operands. Key-based selection (rather than shape-based) is what keeps
    scan-stacked 1-D leaves like a (L, d) ``ln_g`` out of the cache.
    Idempotent: already-quantized leaves pass through.

    ``bit_plan`` assigns non-uniform widths (core/bitalloc.py): a
    per-layer sequence (one width per encoder block, applied to every
    matmul weight of the scan-stacked ``blocks`` subtree) or a dict with
    per-tensor path-suffix overrides (``{"attn/wq": 4, "ffn/w2": (8, 6,
    6, 8)}``) plus optional ``"layers"`` / ``"default"`` keys. Weights
    outside ``blocks`` (patch embed, head, MGNet) take the plan's default
    (= ``bits`` unless overridden). ``n_layers`` sizes per-layer
    sequences; it defaults to the leading dim of the stacked ``blocks``
    leaves.
    """
    plan = None
    if bit_plan is not None:
        from repro.core import bitalloc     # lazy: bitalloc imports us
        if n_layers is None:
            n_layers = _infer_n_layers(params)
        plan = bitalloc.normalize_bit_plan(bit_plan, n_layers,
                                           default=bits)

    def _leaf_bits(path):
        if plan is None:
            return bits
        from repro.core import bitalloc
        names = tuple(_path_key(e) for e in path)
        return bitalloc.resolve_bits(plan, names)

    def _prep(path, leaf):
        if isinstance(leaf, QuantizedWeight):
            return leaf
        if not _is_matmul_weight_key(_path_key(path[-1])):
            return leaf
        if any(_path_key(e) in exclude for e in path):
            return leaf
        if leaf.ndim < 2 or leaf.size < min_size:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        lb = _leaf_bits(path)
        if (isinstance(lb, tuple) and
                (leaf.ndim < 3 or leaf.shape[0] != len(lb))):
            lb = bits        # per-layer plan, non-stacked weight: default
        return quantize_weight(leaf, bits=lb)

    return jax.tree_util.tree_map_with_path(
        _prep, params, is_leaf=lambda x: isinstance(x, QuantizedWeight))


def place_params(params, logical_axes, ctx):
    """Pin a prepared param pytree onto a serving mesh with NamedSharding.

    ``logical_axes`` is the model's per-leaf logical-axis tree (e.g.
    ``models.vit.vit_logical_axes``); ``ctx`` a ShardingCtx whose rules
    map those axes to mesh axes (MODEL_RULES shards head-/d_ff-major dims
    over "model", replicates everything else). QuantizedWeight leaves
    place both the int8 codes and the f32 scales — the scale's size-1
    contraction dim falls back to replicated via the standard
    divisibility rule, so per-out-channel scales land wherever their
    columns do. Leaves whose rank does not match their axes entry (or
    with every axis unmapped) replicate.

    Placement is a *bandwidth* optimization: the sharded encoder's
    shard_map would resolve mismatched layouts with an automatic reshard,
    so correctness never depends on this — but placing the quantize-once
    cache at prepare time moves the weight bytes exactly once. Only call
    it when the sharded path will actually engage: committed model-axis
    shardings on params fed to the *unsharded* jit would make GSPMD weave
    collectives into a graph whose bitwise contract assumes none.
    """
    from repro.distributed.sharding import named_sharding

    def _place(w, ax):
        axt = tuple(ax)
        if isinstance(w, QuantizedWeight):
            wq = jax.device_put(w.wq, named_sharding(w.wq.shape, axt, ctx))
            sc = jax.device_put(w.scale,
                                named_sharding(w.scale.shape, axt, ctx))
            return QuantizedWeight(wq, sc, w.bits)
        if getattr(w, "ndim", -1) == len(axt):
            return jax.device_put(w, named_sharding(w.shape, axt, ctx))
        return w

    return jax.tree_util.tree_map(
        _place, params, logical_axes,
        is_leaf=lambda x: isinstance(x, QuantizedWeight))


def _infer_n_layers(params) -> int:
    """Leading dim of the scan-stacked ``blocks`` leaves (plan sizing)."""
    blocks = params.get("blocks") if isinstance(params, dict) else None
    if blocks is not None:
        for leaf in jax.tree_util.tree_leaves(blocks):
            if getattr(leaf, "ndim", 0) >= 1:
                return int(leaf.shape[0])
    raise ValueError("cannot infer n_layers for a per-layer bit plan: "
                     "no stacked 'blocks' subtree — pass n_layers=")


def _resolve_wq(w, bits: int):
    """(int8 codes (K, N), scale (1, N) f32) from raw or cached weight."""
    if isinstance(w, QuantizedWeight):
        return w.wq, w.scale
    w32 = w.astype(jnp.float32)
    sw = quant.absmax_scale(w32, bits=bits, axis=-2)
    return quant.quantize(w32, sw, bits=bits), sw


def _weight_bits(w, p: ExecPolicy) -> int:
    """Effective width for a 2-D dispatch: the cached width when the weight
    is quantize-once cached, else ``policy.quant_bits``. A cached width
    that *disagrees* with an explicit ``quant_bits`` is an error unless a
    bit plan is active (``policy.bit_plan``) — silently preferring the
    cache hid stale-cache bugs (params prepared at one width, policy
    asking another)."""
    if isinstance(w, QuantizedWeight):
        if isinstance(w.bits, tuple):
            raise ValueError(
                f"stacked mixed-bits QuantizedWeight (bits={w.bits}) "
                f"reached a 2-D matmul dispatch; slice it to one layer "
                f"first (the segmented-scan encoder in models/vit.py does "
                f"this — see QuantizedWeight.layer_bits)")
        if p.quant_bits and p.bit_plan is None and w.bits != p.quant_bits:
            raise ValueError(
                f"cached QuantizedWeight.bits={w.bits} disagrees with "
                f"ExecPolicy.quant_bits={p.quant_bits} and no bit plan is "
                f"active — re-run prepare_params at the policy's width, "
                f"set quant_bits=0 to defer to the cache, or set "
                f"ExecPolicy.bit_plan for deliberate mixed precision")
        return w.bits
    return p.quant_bits or 8


def _out_dim(w) -> int:
    return w.shape[-1]


# --------------------------------------------------------------------------
# fused-path fallback warnings (the 12x cliff should never be invisible)
# --------------------------------------------------------------------------

# (component, fingerprint, reason) triples already warned about — one
# warning per distinct cause per policy, not one per forward call.
_FUSED_FALLBACK_WARNED: set = set()


def warn_fused_fallback(component: str, p: ExecPolicy, reason: str) -> None:
    """One-time ``UserWarning`` when a *requested* fused path (encoder /
    FFN / attention) silently takes composed dispatch instead. Keyed by
    (component, policy fingerprint, reason) so a steady-state serving loop
    warns exactly once per cause; silent when the fused path actually
    runs. Call sites only invoke this when the policy asked for the fused
    path (``ffn_backend="fused"`` / ``attn_backend="flash"``)."""
    key = (component, p.fingerprint(), reason)
    if key in _FUSED_FALLBACK_WARNED:
        return
    _FUSED_FALLBACK_WARNED.add(key)
    warnings.warn(
        f"fused {component} path fell back to composed dispatch: {reason} "
        f"(policy {p!r}) — expect ~an-order-of-magnitude slower serving; "
        f"see README 'Fused-path eligibility'", UserWarning, stacklevel=3)


def reset_fused_fallback_warnings() -> None:
    """Forget which fallbacks have been warned about (test isolation)."""
    _FUSED_FALLBACK_WARNED.clear()


# --------------------------------------------------------------------------
# integer-accumulate primitives (the cross-backend numerics contract)
# --------------------------------------------------------------------------

def int_accumulate_exact(xq: jax.Array, wq: jax.Array) -> jax.Array:
    """One-shot int32 accumulate — photonic_matmul_exact's inner product."""
    return jax.lax.dot_general(xq.astype(jnp.int32), wq.astype(jnp.int32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def int_accumulate_sim(xq: jax.Array, wq: jax.Array,
                       chunk: int = _WAVELENGTHS) -> jax.Array:
    """Chunk-walking int32 accumulate over K in ``chunk``-wide wavelength
    groups (Fig. 6 schedule). Integer addition is associative, so this is
    bit-identical to ``int_accumulate_exact`` — the oracle the Pallas
    kernel's K-grid walk must also match."""
    m, k = xq.shape
    n = wq.shape[1]
    rem = (-k) % chunk
    if rem:
        xq = jnp.pad(xq, ((0, 0), (0, rem)))
        wq = jnp.pad(wq, ((0, rem), (0, 0)))
    nk = xq.shape[1] // chunk
    x_chunks = xq.astype(jnp.int32).reshape(m, nk, chunk).transpose(1, 0, 2)
    w_chunks = wq.astype(jnp.int32).reshape(nk, chunk, n)

    def step(acc, xw):
        xc, wc = xw
        acc = acc + jax.lax.dot_general(xc, wc, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.int32)
        return acc, None

    acc, _ = jax.lax.scan(step, jnp.zeros((m, n), jnp.int32),
                          (x_chunks, w_chunks))
    return acc


def int_accumulate_pallas(xq: jax.Array, wq: jax.Array,
                          interpret: bool = True) -> jax.Array:
    """Int32 accumulate through the Pallas kernel (unit scales make the f32
    output the raw accumulate; exact for |acc| < 2^24, i.e. K <= 1040 at
    8 bits — every ViT shape in this repo)."""
    from repro.kernels.ops import pad_to
    from repro.kernels.photonic_matmul import photonic_matmul_int8

    m, k = xq.shape
    n = wq.shape[1]
    xp = pad_to(pad_to(xq, 128, 0), 128, 1)
    wp = pad_to(pad_to(wq, 128, 0), 128, 1)
    out = photonic_matmul_int8(xp, wp, jnp.float32(1.0),
                               jnp.ones((wp.shape[1],), jnp.float32),
                               interpret=interpret)
    return out[:m, :n].astype(jnp.int32)


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------

BACKENDS: dict[str, Callable] = {}


def register_backend(name: str):
    def deco(fn):
        BACKENDS[name] = fn
        return fn
    return deco


def get_backend(name: str) -> Callable:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown matmul backend {name!r}; "
                       f"available: {available_backends()}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(BACKENDS))


@register_backend("bf16")
def _bf16_matmul(x, w, p: ExecPolicy):
    """Plain MXU dot: f32 accumulate (or operand-dtype out, §Perf knob)."""
    if isinstance(w, QuantizedWeight):
        w = w.dequantize().astype(x.dtype)
    if p.dot_out_native:
        return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               ).astype(x.dtype)


@register_backend("qat")
def _qat_matmul(x, w, p: ExecPolicy):
    """QAT fake-quant: weights per-out-channel + activations per-tensor,
    STE in training so gradients flow (paper §IV Accuracy Analysis)."""
    bits = p.quant_bits or 8
    fq = quant.fake_quant_ste if p.training else quant.fake_quant
    if isinstance(w, QuantizedWeight):
        wq = w.dequantize().astype(x.dtype)     # cache already quantized it
    else:
        wq = fq(w, bits=bits, axis=tuple(range(w.ndim - 1)))
    xq = fq(x, bits=bits, axis=None)
    return jax.lax.dot_general(xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               ).astype(x.dtype)


def _photonic_prologue(x, w, p: ExecPolicy):
    bits = _weight_bits(w, p)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    sx = quant.absmax_scale(x2, bits=bits)
    xq = quant.quantize(x2, sx, bits=bits)
    wq, sw = _resolve_wq(w, bits)
    return lead, xq, wq, sx, sw


@register_backend("photonic_sim")
def _photonic_sim_matmul(x, w, p: ExecPolicy):
    """Chunk-walking w8a8 oracle: integer accumulate over 32-wavelength
    K-chunks, then the dequant epilogue (ADC + scale restore)."""
    lead, xq, wq, sx, sw = _photonic_prologue(x, w, p)
    acc = int_accumulate_sim(xq, wq)
    y = acc.astype(jnp.float32) * sx * sw.reshape(1, -1)
    return y.reshape(*lead, _out_dim(w)).astype(x.dtype)


@register_backend("photonic_pallas")
def _photonic_pallas_matmul(x, w, p: ExecPolicy):
    """Int8 Pallas MXU kernel (interpret=True on CPU hosts). With a cached
    ``QuantizedWeight`` only the activations are quantized per call."""
    from repro.kernels import ops as kernel_ops   # lazy: pulls in pallas

    bits = _weight_bits(w, p)
    wq, sw = _resolve_wq(w, bits)
    y = kernel_ops.photonic_matmul_prequant(
        x.astype(jnp.float32), wq, sw.reshape(-1), bits=bits,
        interpret=p.interpret)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# calibrated device-noise dispatch (ExecPolicy.noise)
# --------------------------------------------------------------------------

def _noisy_matmul(x, w, p: ExecPolicy):
    """One noisy path for every backend — the registry entries stay the
    clean contract and the dispatch is not forked per backend.

    Weight-stationary MR banks take the transmission error (crosstalk floor
    + FPV + Lorentzian drift/wander, core/noise.py); the readout takes shot
    noise and an optional range-limited ADC. Photonic backends walk the
    analog float-code schedule (sub-LSB noise cannot ride through int8
    codes); bf16/qat apply the multiplier to their effective float weight.
    Keys come from the active noise scope — a DriftState installed by the
    serving entry points via ``noise.scoped`` — so successive frames draw
    fresh patterns and a pinned state reproduces bitwise.
    """
    from repro.core import noise as noise_mod

    spec = p.noise
    kc, kf, drift = noise_mod.next_call_keys(spec)
    backend = p.resolve_backend()
    mr = spec.mr()

    def _mult(shape):
        return noise_mod.transmission_error(
            kc, shape, mr, spec.fpv_sigma, fpv_key=kf, drift_nm=drift,
            wander_sigma_nm=spec.wander_sigma_nm)

    if backend.startswith("photonic"):
        bits = _weight_bits(w, p)
        wq, sw = _resolve_wq(w, bits)
        mult = _mult(wq.shape)
        if backend == "photonic_pallas":
            from repro.kernels import ops as kernel_ops
            y = kernel_ops.photonic_matmul_prequant_noisy(
                x.astype(jnp.float32), wq, sw.reshape(-1), mult,
                noise_mod.shot_key(kc), bits=bits,
                shot_sigma=spec.shot_sigma,
                adc_bits=bits if spec.adc_quantize_output else 0,
                chunk=_WAVELENGTHS)
            return y.astype(x.dtype)
        from repro.core.photonic import analog_accumulate
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        sx = quant.absmax_scale(x2, bits=bits)
        xq = quant.quantize(x2, sx, bits=bits)
        acc = analog_accumulate(xq, wq.astype(jnp.float32) * mult,
                                chunk=_WAVELENGTHS)
        y = acc * sx * sw.reshape(1, -1)
        y = noise_mod.readout_noise(y, spec, kc, bits=bits)
        return y.reshape(*lead, _out_dim(w)).astype(x.dtype)

    # bf16 / qat: transmission error on the effective float weight
    bits = p.quant_bits or 8
    if isinstance(w, QuantizedWeight):
        wf = w.dequantize()
        xf = x.astype(jnp.float32)
    elif backend == "qat":
        fq = quant.fake_quant_ste if p.training else quant.fake_quant
        wf = fq(w.astype(jnp.float32), bits=bits,
                axis=tuple(range(w.ndim - 1)))
        xf = fq(x.astype(jnp.float32), bits=bits, axis=None)
    else:
        wf = w.astype(jnp.float32)
        xf = x.astype(jnp.float32)
    y = jax.lax.dot_general(xf, wf * _mult(wf.shape),
                            (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = noise_mod.readout_noise(y, spec, kc, bits=bits)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# the single matmul entry point
# --------------------------------------------------------------------------

def matmul(x: jnp.ndarray, w, policy: ExecPolicy | None = None) -> jnp.ndarray:
    """y = x @ w under the active execution policy.

    x: (..., d_in); w: (d_in, d_out) array or cached ``QuantizedWeight``.
    """
    p = policy or _DEFAULT
    if p.noise is not None:
        return _noisy_matmul(x, w, p)
    return get_backend(p.resolve_backend())(x, w, p)


def linear(x: jnp.ndarray, w, b: jnp.ndarray | None = None,
           policy: ExecPolicy | None = None) -> jnp.ndarray:
    """y = x @ w (+ b) under the active execution policy (see ``matmul``)."""
    y = matmul(x, w, policy)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# attention-core registry (score -> softmax -> PV under one dispatch point)
# --------------------------------------------------------------------------

ATTN_BACKENDS: dict[str, Callable] = {}


def register_attention_backend(name: str):
    def deco(fn):
        ATTN_BACKENDS[name] = fn
        return fn
    return deco


def get_attention_backend(name: str) -> Callable:
    try:
        return ATTN_BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"available: {available_attention_backends()}") from None


def available_attention_backends() -> tuple[str, ...]:
    return tuple(sorted(ATTN_BACKENDS))


@register_attention_backend("xla")
def _attend_xla(q, k, v, p: ExecPolicy, mask, kv_len, scale):
    """Materialized-score reference dataflow: the full (Sq, Skv) score
    matrix is computed, masked keys get a large negative additive bias
    (softmax assigns them exactly-zero weight — the serving parity
    contract), then softmax @ V. Runs in the operands' dtype, exactly the
    pre-registry mhsa numerics. A packed ``kv_len`` is expressed as a
    prefix mask — this backend is the post-hoc reference, it never skips."""
    from repro.kernels.ref import (expand_kv_heads,   # pure jnp, no pallas
                                   prefix_key_mask)

    h = q.shape[-3]
    if kv_len is not None:
        mask = prefix_key_mask(kv_len, 1, k.shape[-2])[0]
    s = (q @ jnp.swapaxes(expand_kv_heads(k, h), -1, -2)) * scale
    if mask is not None:
        s = s + ((mask.astype(jnp.float32) - 1.0) * 1e9
                 ).astype(s.dtype)[..., None, None, :]
    probs = jax.nn.softmax(s, axis=-1)
    o = probs @ expand_kv_heads(v, h)
    if mask is not None:
        # rows with zero live keys output exactly 0, not the uniform
        # average softmax degenerates to — the flash/oracle contract
        o = o * (mask.sum(-1) > 0)[..., None, None, None].astype(o.dtype)
    return o


@register_attention_backend("flash")
def _attend_flash(q, k, v, p: ExecPolicy, mask, kv_len, scale):
    """Fused RoI-masked flash dataflow: streaming softmax in VMEM, masked
    keys applied inside the update, fully-pruned KV blocks skipped — on
    TPU (``interpret=False``) the Pallas kernel; on CPU hosts the XLA
    lowering of the same contract (kernels/flash_attention.py). A static
    ``kv_len`` takes the packed-skip path: the dead KV tail costs zero
    score FLOPs."""
    from repro.kernels.flash_attention import fused_masked_attention

    lead = q.shape[:-3]
    b = 1
    for n in lead:
        b *= n
    h, sq, d = q.shape[-3:]
    qf = q.reshape(b, h, sq, d)
    kf = k.reshape((b,) + k.shape[-3:])
    vf = v.reshape((b,) + v.shape[-3:])
    mf = None
    if mask is not None:
        # accept the same lead-dim-elided masks the xla backend broadcasts
        mf = jnp.broadcast_to(mask, lead + mask.shape[-1:]).reshape(
            b, mask.shape[-1])
    out = fused_masked_attention(qf, kf, vf, mf, kv_len=kv_len, scale=scale,
                                 interpret=p.interpret)
    return out.reshape(*lead, h, sq, vf.shape[-1])


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           policy: ExecPolicy | None = None, *,
           mask: jnp.ndarray | None = None,
           kv_len: int | None = None,
           scale: float | None = None) -> jnp.ndarray:
    """softmax(q @ k^T * scale + key-mask bias) @ v under the active policy.

    q (..., H, Sq, D); k (..., Hk, Skv, D); v (..., Hv, Skv, Dv) ->
    (..., H, Sq, Dv); H a multiple of Hk and Hv. ``mask`` (..., Skv) is a
    {0,1} keep-mask on the key axis (RoI mask mode); ``kv_len`` is the
    packed alternative (key j kept iff j < kv_len — the one-shape serving
    layout; a static int lets the flash backend drop the dead tail before
    any score FLOP). Give at most one. ``scale`` defaults to 1/sqrt(D) —
    pass 1.0 when it is already folded into q (Eq. 2). The score and PV
    products are activation-activation matmuls (dynamically tuned cores on
    the photonic hardware), so they stay float on every matmul backend;
    only *which dataflow computes them* is dispatched here.
    """
    p = policy or _DEFAULT
    if mask is not None and kv_len is not None:
        raise ValueError("give mask or kv_len, not both")
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    return get_attention_backend(p.resolve_attn_backend())(q, k, v, p,
                                                           mask, kv_len,
                                                           scale)


# --------------------------------------------------------------------------
# FFN registry (w1 -> GELU -> w2 under one dispatch point)
# --------------------------------------------------------------------------

FFN_BACKENDS: dict[str, Callable] = {}


def register_ffn_backend(name: str):
    def deco(fn):
        FFN_BACKENDS[name] = fn
        return fn
    return deco


def get_ffn_backend(name: str) -> Callable:
    try:
        return FFN_BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown ffn backend {name!r}; "
                       f"available: {available_ffn_backends()}") from None


def available_ffn_backends() -> tuple[str, ...]:
    return tuple(sorted(FFN_BACKENDS))


@register_ffn_backend("xla")
def _ffn_xla(x, w1, b1, w2, b2, p: ExecPolicy, live_rows):
    """Composed reference dataflow: two independent ``linear`` dispatches
    with the float GELU round-trip between them — the hidden (B, S, d_ff)
    activation crosses the dispatch boundary at float precision twice.
    Runs on every matmul backend; exactly the pre-registry mlp numerics.
    ``live_rows`` is ignored — this backend is the post-hoc reference, it
    never skips (the same contract as the xla attention backend)."""
    from repro.distributed.sharding import shard   # lazy: keeps core free
    #                                                of a launch-layer dep
    h = linear(x, w1, b1, policy=p)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return linear(h, w2, b2, policy=p)


def _fused_ffn_ineligible_reason(w1, w2, p: ExecPolicy) -> str | None:
    """None when the block can take the fused int8 FFN kernel — int8
    Pallas matmul backend + both weights quantize-once cached at (possibly
    different) <= 8-bit widths — else a human-readable reason (mirrors
    ``_fused_prequant_eligible`` for the MHSA block). w1 and w2 may carry
    *different* widths: the kernel quantizes the input at w1's width and
    requantizes the hidden state at w2's, exactly the composed numerics."""
    if p.noise is not None:
        return ("calibrated device noise is active (ExecPolicy.noise) — "
                "the fused int8 kernel is the clean digital contract; "
                "noisy execution runs the composed analog dispatch")
    if p.resolve_backend() != "photonic_pallas":
        return (f"matmul backend is {p.resolve_backend()!r}, fused kernel "
                f"needs 'photonic_pallas'")
    if not (isinstance(w1, QuantizedWeight) and isinstance(w2, QuantizedWeight)):
        return "w1/w2 not quantize-once cached (run prepare_params)"
    if not (w1.ndim == 2 and w2.ndim == 2):
        return "w1/w2 still scan-stacked (ndim > 2), not per-layer slices"
    if not (isinstance(w1.bits, int) and isinstance(w2.bits, int)):
        return (f"w1/w2 carry stacked per-layer bits ({w1.bits}/{w2.bits}),"
                f" not a single width")
    if not (w1.bits <= 8 and w2.bits <= 8):
        return f"bit widths ({w1.bits}, {w2.bits}) above the int8 kernel max"
    return None


def _fused_ffn_eligible(w1, w2, p: ExecPolicy) -> bool:
    return _fused_ffn_ineligible_reason(w1, w2, p) is None


@register_ffn_backend("fused")
def _ffn_fused(x, w1, b1, w2, b2, p: ExecPolicy, live_rows):
    """Fused int8 photonic FFN (kernels/fused_ffn.py): both matmuls, the
    bias adds, the GELU and the hidden requantization run in one kernel
    over the cached weight tiles, the hidden state staying in VMEM. A
    static ``live_rows`` (one-shape serving mode) drops fully-pruned
    token rows before any FLOP, returning exact zeros for them (activation
    scales then reduce over live rows only — the packed-skip contract).
    w1 and w2 may be cached at different widths (a mixed-precision bit
    plan): the input is quantized at w1's width, the hidden state
    requantized at w2's — bit-identical to the composed two-``linear``
    dispatch under the same cache. Falls back to the composed dispatch
    (with a one-time warning) when the weights are not cached int8 or the
    matmul backend is not the Pallas kernel."""
    reason = _fused_ffn_ineligible_reason(w1, w2, p)
    if reason is not None:
        warn_fused_fallback("FFN", p, reason)
        return _ffn_xla(x, w1, b1, w2, b2, p, live_rows)
    from repro.kernels.fused_ffn import fused_ffn   # lazy: pulls in pallas

    return fused_ffn(x, w1.wq, w1.scale.reshape(-1), b1,
                     w2.wq, w2.scale.reshape(-1), b2,
                     bits=(w1.bits, w2.bits),
                     live_rows=live_rows, interpret=p.interpret)


def ffn(x: jnp.ndarray, w1, b1: jnp.ndarray, w2, b2: jnp.ndarray,
        policy: ExecPolicy | None = None, *,
        live_rows: int | None = None) -> jnp.ndarray:
    """y = gelu(x @ w1 + b1) @ w2 + b2 under the active execution policy.

    x (..., n, d_in); w1 (d_in, d_ff) / w2 (d_ff, d_out) raw arrays or
    cached ``QuantizedWeight``s. ``live_rows`` statically prunes the token
    axis on skipping backends (key j live iff j < live_rows — the packed
    one-shape serving layout); the xla reference computes every row.
    """
    p = policy or _DEFAULT
    return get_ffn_backend(p.resolve_ffn_backend())(x, w1, b1, w2, b2, p,
                                                    live_rows)
