"""Mixed-precision serving benchmark: per-layer bit plans on the fused path.

Opto-ViT's quantization story is co-designed with the photonic substrate:
every bit dropped from a weight-stationary matmul scales the dominant
SAR-ADC/DAC/SRAM/MR-tuning energy terms by ``bits/8`` (core/energy.py::
scale_for_bits), so a per-layer plan that keeps sensitive layers at 8 bits
and drops the insensitive middle to 6/4 buys frame energy at ~zero accuracy
cost. This bench gates the three claims that make that a *serving* feature
rather than a post-hoc analysis:

  1. **No fused-path tax** (tiny-224, 50% skip, one serving micro-batch):
     the fully-fused encoder (photonic_pallas + flash + fused, single-jit
     segmented scan) under a mixed 4/6/8 plan beats the composed dispatch
     under the *same plan* by >= 1.3x — mixing widths must not knock
     serving off the fast path (the pre-PR fallback did exactly that).
  2. **Energy**: model energy/frame of the mixed plan at the 50%-skip
     operating point is strictly below uniform int8 (same accounting the
     stream server reports per session).
  3. **Accuracy**: predictions under the mixed plan agree with uniform
     int8 on >= 99% of frames. Measured on a *trained* smoke model (the
     planted-box quadrant task of table1_qat — full dataset fine-tuning is
     out of scope on CPU): a randomly initialized head emits near-tied
     logits whose argmax flips under any perturbation, so random-init
     "agreement" measures logit degeneracy, not plan quality.

Numerics first, wall second: the mixed-plan fused forward must be
bit-identical to the composed dispatch on the smoke model before any gate
is evaluated; the tiny-224 programs hold the quant-step tolerance class
(corr bound) instead — at that scale XLA's fusion choices differ between
the fused and composed whole programs even under uniform int8, and at the
packed operating point the live-rows absmax scopes legally differ from
the composed full-row dispatch (the masked-vs-gathered noise class).

The sensitivity calibrator (core/bitalloc.py, ``--bit-budget`` on the
server CLI) is exercised on the trained model and its plan reported next
to the hand-written one.

Results merge into BENCH_serving.json under "mixed_precision".

    PYTHONPATH=src python -m benchmarks.mixed_precision_bench           # full
    PYTHONPATH=src python -m benchmarks.mixed_precision_bench --smoke   # CI fast
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import interleaved_best as _interleaved_best
from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core import bitalloc
from repro.core.backend import ExecPolicy, prepare_params
from repro.data.pipeline import ImageStream, quadrant_labels
from repro.models.vit import (embed_patches, forward_vit, forward_vit_tokens,
                              init_vit)
from repro.serving.accounting import StreamAccounting

BATCH = 16                      # serving_bench's tiny-224 micro-batch
SKIP = 0.5
SPEEDUP_GATE = 1.3
AGREEMENT_GATE = 0.99
TRIALS = 5
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")

# tiny-224 (12 layers): 8-bit head/tail, 6-bit shoulders, one 4-bit middle
# layer — mean 7.0 bits, all three supported widths exercised
T224_PLAN = (8, 8, 8, 6, 6, 4, 6, 6, 8, 8, 8, 8)
# smoke model (4 layers): same shape at depth 4
SMOKE_PLAN = (8, 6, 4, 8)
TRAIN_STEPS = 300
EVAL_BATCHES = 8                # 8 x 32 = 256 frames for the agreement gate


def _fused_cfg(cfg, plan=()):
    return cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                     attn_backend="flash", ffn_backend="fused",
                     bit_plan=tuple(plan))


def _train_smoke(cfg, steps=TRAIN_STEPS, seed=0):
    """Fit the planted-box quadrant task (table1_qat's mechanism-level
    stand-in for dataset fine-tuning) so predictions carry real margins."""
    stream = ImageStream(img_size=cfg.img_size, global_batch=32,
                         n_classes=8, patch=cfg.patch, seed=seed)
    params = init_vit(jax.random.PRNGKey(seed), cfg, n_classes=4)

    def loss_fn(p, images, labels):
        lg, _ = forward_vit(p, images, cfg)
        lf = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, -1)
        gold = jnp.take_along_axis(lf, labels[:, None], -1)[:, 0]
        return (lse - gold).mean()

    @jax.jit
    def step(p, images, labels):
        _, g = jax.value_and_grad(loss_fn)(p, images, labels)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)

    for i in range(steps):
        b = stream.batch_at(i)
        params = step(params, b["images"], quadrant_labels(b["patch_mask"]))
    return params, stream


def _eval_preds(prep, cfg, stream, n_batches=EVAL_BATCHES):
    preds, gold = [], []
    for j in range(n_batches):
        b = stream.batch_at(1000 + j)            # held-out batches
        lg, _ = forward_vit(prep, b["images"], cfg)
        preds.append(np.argmax(np.asarray(lg), -1))
        gold.append(np.asarray(quadrant_labels(b["patch_mask"])))
    return np.concatenate(preds), np.concatenate(gold)


def _agreement_and_energy(smoke: bool) -> dict:
    """Gates 2 + 3 on the trained smoke model + the tiny-224 energy model."""
    cfg = smoke_variant(get_config("tiny")).with_(n_layers=4, remat=False,
                                                  quant_bits=8)
    params, stream = _train_smoke(cfg)
    uni = prepare_params(params, bits=8)
    mix = prepare_params(params, bits=8, bit_plan=SMOKE_PLAN)
    cfg_uni = _fused_cfg(cfg)
    cfg_mix = _fused_cfg(cfg, SMOKE_PLAN)

    # numerics first: mixed-plan fused == mixed-plan composed, bit-for-bit
    # (the composed reference is jitted — the eager GELU compiles to
    # last-ulp-different code, the documented eager-context artifact the
    # differential suite pins separately)
    probe = stream.batch_at(999)["images"]
    cfg_comp = cfg_mix.with_(ffn_backend="")
    lg_fused, _ = forward_vit(mix, probe, cfg_mix)
    lg_comp = jax.jit(lambda im: forward_vit(mix, im, cfg_comp)[0])(probe)
    np.testing.assert_array_equal(
        np.asarray(lg_fused), np.asarray(lg_comp),
        err_msg="mixed-plan fused forward must be bit-identical to the "
                "composed dispatch under the same plan")

    p_uni, gold = _eval_preds(uni, cfg_uni, stream)
    p_mix, _ = _eval_preds(mix, cfg_mix, stream)
    acc_uni = float((p_uni == gold).mean())
    acc_mix = float((p_mix == gold).mean())
    agreement = float((p_mix == p_uni).mean())
    mean_bits = sum(SMOKE_PLAN) / len(SMOKE_PLAN)
    print(f"  trained smoke model ({len(p_uni)} frames): uniform-int8 acc "
          f"{acc_uni:.3f} | plan {SMOKE_PLAN} (mean {mean_bits:.2f} bits) "
          f"acc {acc_mix:.3f} | prediction agreement {agreement:.4f}")

    # the calibrator's own pick at the same mean budget, for the record
    toks = embed_patches(uni, stream.batch_at(998)["images"], cfg_uni)
    cal_plan = bitalloc.calibrate_bit_plan(
        params, toks, cfg, ExecPolicy.from_cfg(cfg_uni, training=False),
        target_mean_bits=mean_bits)
    print(f"  calibrator at the same {mean_bits:.2f}-bit budget picks "
          f"{cal_plan}")

    # tiny-224 model energy at the 50%-skip operating point
    cfg224 = get_config("tiny", img_size=224)
    n_patches = (cfg224.img_size // cfg224.patch) ** 2
    k = int(round((1.0 - SKIP) * n_patches))
    acct_uni = StreamAccounting(cfg224)
    acct_mix = StreamAccounting(cfg224, layer_bits=T224_PLAN)
    uj_uni = acct_uni._bucket_report(k).total_uj
    uj_mix = acct_mix._bucket_report(k).total_uj
    print(f"  tiny-224 energy/frame at {SKIP:.0%} skip: uniform-int8 "
          f"{uj_uni:.2f} uJ | plan (mean {sum(T224_PLAN) / 12:.2f} bits) "
          f"{uj_mix:.2f} uJ ({1 - uj_mix / uj_uni:.1%} saved)")

    assert uj_mix < uj_uni, (
        f"mixed-plan energy/frame must be below uniform int8; "
        f"{uj_mix:.2f} >= {uj_uni:.2f} uJ")
    assert agreement >= AGREEMENT_GATE, (
        f"mixed-plan predictions must agree with uniform int8 on >= "
        f"{AGREEMENT_GATE:.0%} of frames; measured {agreement:.4f}")
    return {
        "smoke_plan": list(SMOKE_PLAN), "t224_plan": list(T224_PLAN),
        "acc_uniform": acc_uni, "acc_mixed": acc_mix,
        "agreement": agreement, "agreement_frames": int(len(p_uni)),
        "calibrated_plan": list(cal_plan),
        "uniform_uj_per_frame": uj_uni, "mixed_uj_per_frame": uj_mix,
        "energy_saved": 1 - uj_mix / uj_uni,
    }


def _speedup_tiny224() -> dict:
    """Gate 1: fused vs composed under the same mixed plan, tiny-224."""
    cfg0 = get_config("tiny", img_size=224)
    params = init_vit(jax.random.PRNGKey(0), cfg0, n_classes=10)
    prep = prepare_params(params, bits=8, bit_plan=T224_PLAN)
    n_tokens = (cfg0.img_size // cfg0.patch) ** 2 + 1        # incl [cls]
    kept = int(round((1.0 - SKIP) * n_tokens))
    cfg_f = _fused_cfg(cfg0, T224_PLAN)
    cfg_c = cfg_f.with_(ffn_backend="")

    def fused(t):                    # encode_tokens holds its own jit
        return forward_vit_tokens(prep, t, cfg_f, kv_len=kept)[0]

    composed = jax.jit(
        lambda t: forward_vit_tokens(prep, t, cfg_c, kv_len=kept)[0])
    toks = embed_patches(prep, jax.random.normal(
        jax.random.PRNGKey(1), (BATCH, 224, 224, 3)), cfg_f)

    # numerics first. Bitwise fused==composed parity is pinned where it is
    # a contract: per-kernel (test_fused_ffn) and whole-encoder at smoke
    # scale (test_differential section e; this bench's trained-smoke gate).
    # At tiny-224/batch-16 the two whole programs compile with different
    # XLA fusion choices — measured to differ at last-ulp even under
    # *uniform* int8, pre-dating bit plans — and a last-ulp flip at a
    # 4-bit requant boundary is a full quant step, so the tiny-224 checks
    # here are the documented quant-step tolerance class (corr bound),
    # at full rows and at the packed operating point (whose live-rows
    # absmax scopes legally differ — the masked-vs-gathered noise class).
    full_fused = np.asarray(forward_vit_tokens(prep, toks, cfg_f)[0],
                            np.float32)
    full_comp = np.asarray(jax.jit(
        lambda t: forward_vit_tokens(prep, t, cfg_c)[0])(toks), np.float32)
    corr_full = float(np.corrcoef(full_fused.ravel(),
                                  full_comp.ravel())[0, 1])
    assert corr_full > 0.99, (
        f"tiny-224 mixed-plan fused encoder drifted off the composed "
        f"dispatch at full rows (corr {corr_full:.5f})")
    a = np.asarray(fused(toks), np.float32)
    b = np.asarray(composed(toks), np.float32)
    corr = float(np.corrcoef(a.ravel(), b.ravel())[0, 1])
    assert corr > 0.99, (
        f"fused one-shape output drifted off the composed dispatch "
        f"(corr {corr:.5f})")

    t_fused, t_comp = _interleaved_best(
        [(fused, (toks,)), (composed, (toks,))], trials=TRIALS)
    speedup = t_comp / t_fused
    print(f"  tiny-224, {SKIP:.0%} skip, batch {BATCH}, plan mean "
          f"{sum(T224_PLAN) / 12:.2f} bits: composed {t_comp * 1e3:8.1f} ms "
          f"| fused {t_fused * 1e3:8.1f} ms -> {speedup:.2f}x")
    assert speedup >= SPEEDUP_GATE, (
        f"fused mixed-plan serving must beat the composed dispatch under "
        f"the same plan by >= {SPEEDUP_GATE}x; measured {speedup:.2f}x")
    return {"composed_ms": t_comp * 1e3, "fused_ms": t_fused * 1e3,
            "speedup": speedup, "kept": kept, "batch": BATCH, "skip": SKIP,
            "corr_vs_composed": corr, "corr_full_rows": corr_full}


def run(smoke: bool = False) -> dict:
    print("\n== mixed-precision bit plans on the fused serving path ==")
    payload = _agreement_and_energy(smoke)
    if smoke:
        print("  (smoke mode: tiny-224 speedup gate + BENCH json skipped)")
        return payload
    payload.update(_speedup_tiny224())

    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["mixed_precision"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON} [mixed_precision]")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="agreement + energy gates only (fast CI): skips "
                         "the tiny-224 wall-clock gate and the JSON merge")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
