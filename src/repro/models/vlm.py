"""Vision-language decoder (Llama-3.2-Vision-style backbone).

The vision tower is a STUB per the assignment: ``input_specs`` supplies
precomputed image patch embeddings (B, n_img_tokens, d_frontend). The text
decoder inserts a gated image cross-attention layer every ``cross_every``
layers (Flamingo/Llama-3.2 pattern); layers scan over super-blocks of
``cross_every`` layers, the last of which carries the cross-attention.

This is also where the paper's MGNet applies naturally outside pure ViTs:
``mgnet_keep_ratio < 1`` prunes image tokens by MGNet-style scores before
the cross-attention K/V are formed (token-budget top-k, static shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import ffn as ffn_mod
from repro.models.attention import full_attention
from repro.models.layers import (ExecPolicy, embedding_lookup, he_init,
                                 linear, rmsnorm)
from repro.models.transformer import (attention_logical_axes, attn_decode,
                                      attn_forward, dense_layer_axes,
                                      dense_layer_fwd, init_attention,
                                      init_dense_layer, _tree_prepend_axis)

__all__ = ["init_vlm", "vlm_logical_axes", "forward_vlm", "vlm_cache_spec",
           "decode_step_vlm", "prune_image_tokens"]


def init_vlm(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    dfr = cfg.d_frontend or d
    ks = jax.random.split(key, 8)
    p_sb = cfg.cross_every                 # layers per super-block
    n_sb = cfg.n_layers // p_sb
    assert cfg.n_layers % p_sb == 0, (cfg.n_layers, p_sb)

    def super_block(k):
        kk = jax.random.split(k, p_sb + 1)
        return {
            "selfs": jax.vmap(lambda q: init_dense_layer(q, cfg, dtype))(
                kk[: p_sb]),
            "lnx": jnp.ones((d,), dtype),
            "xattn": init_attention(kk[p_sb], cfg, dtype),
            "xgate": jnp.zeros((), jnp.float32),     # tanh-gated (Flamingo)
        }

    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "img_proj": he_init(ks[1], (dfr, d), dtype),
        "img_score": he_init(ks[2], (d, 1), dtype),   # MGNet-style relevance
        "blocks": jax.vmap(super_block)(jax.random.split(ks[3], n_sb)),
        "final_ln": jnp.ones((d,), dtype),
        "lm_head": he_init(ks[4], (d, cfg.vocab), dtype),
    }


def vlm_logical_axes(cfg: ArchConfig) -> dict:
    sb = {"selfs": _tree_prepend_axis(dense_layer_axes(cfg)),
          "lnx": (None,),
          "xattn": attention_logical_axes(cfg),
          "xgate": ()}
    return {"embed": ("p_vocab", "p_embed"),
            "img_proj": (None, "p_embed"),
            "img_score": ("p_embed", None),
            "blocks": _tree_prepend_axis(sb),
            "final_ln": (None,),
            "lm_head": ("p_embed", "p_vocab")}


def prune_image_tokens(params, img_tokens: jnp.ndarray, keep_ratio: float):
    """MGNet-style static-budget pruning of image tokens (paper RoI idea
    applied to the VLM frontend). keep = ceil(ratio * n)."""
    n = img_tokens.shape[1]
    keep = max(1, int(keep_ratio * n))
    if keep >= n:
        return img_tokens
    scores = (img_tokens.astype(jnp.float32)
              @ params["img_score"].astype(jnp.float32))[..., 0]   # (B, N)
    _, idx = jax.lax.top_k(scores, keep)
    return jnp.take_along_axis(img_tokens, idx[..., None], axis=1)


def _img_kv(p, img, cfg, policy):
    b, t, _ = img.shape
    hkv, hd = cfg.kv_heads, cfg.head_dim
    k = linear(img, p["wk"], p.get("bk"), policy).reshape(b, t, hkv, hd)
    v = linear(img, p["wv"], p.get("bv"), policy).reshape(b, t, hkv, hd)
    return k, v


def _cross(p, gate, x, kv, cfg, policy):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq"), policy).reshape(b, s, h, hd)
    o = full_attention(q, kv[0], kv[1], causal=False)
    o = linear(o.reshape(b, s, h * hd), p["wo"], policy=policy)
    return jnp.tanh(gate) * o.astype(jnp.float32)


def forward_vlm(params: dict, tokens: jnp.ndarray, img_embeds: jnp.ndarray,
                cfg: ArchConfig, policy: ExecPolicy | None = None):
    """tokens (B, S); img_embeds (B, N_img, d_frontend) -> (logits, aux)."""
    policy = policy or ExecPolicy.from_cfg(cfg)
    img = linear(img_embeds, params["img_proj"], policy=policy)
    if cfg.mgnet and cfg.mgnet_keep_ratio < 1.0:
        img = prune_image_tokens(params, img, cfg.mgnet_keep_ratio)
    img = shard(img, "batch", None, "embed")
    x = embedding_lookup(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")
    p_sb = cfg.cross_every

    def body(carry, sb):
        def self_body(c, lp):
            return dense_layer_fwd(lp, c, cfg, policy), None
        fn = jax.checkpoint(self_body) if cfg.remat else self_body
        carry, _ = jax.lax.scan(fn, carry, sb["selfs"])
        kv = _img_kv(sb["xattn"], img, cfg, policy)
        hx = rmsnorm(carry, sb["lnx"], cfg.norm_eps)
        carry = carry + _cross(sb["xattn"], sb["xgate"], hx, kv, cfg,
                               policy).astype(carry.dtype)
        return shard(carry, "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = linear(x, params["lm_head"], policy=policy)
    return shard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def vlm_cache_spec(cfg: ArchConfig, batch: int, seq_len: int,
                   dtype=jnp.bfloat16):
    hkv, hd = cfg.kv_heads, cfg.head_dim
    n_sb = cfg.n_layers // cfg.cross_every
    p_sb = cfg.cross_every
    n_img = (int(cfg.mgnet_keep_ratio * cfg.n_img_tokens)
             if cfg.mgnet and cfg.mgnet_keep_ratio < 1.0 else cfg.n_img_tokens)
    shapes = {"k": ((n_sb, p_sb, batch, seq_len, hkv, hd), dtype),
              "v": ((n_sb, p_sb, batch, seq_len, hkv, hd), dtype),
              "xk": ((n_sb, batch, n_img, hkv, hd), dtype),
              "xv": ((n_sb, batch, n_img, hkv, hd), dtype)}
    axes = {"k": ("p_layers", None, "batch", "kv_seq", None, None),
            "v": ("p_layers", None, "batch", "kv_seq", None, None),
            "xk": ("p_layers", "batch", None, None, None),
            "xv": ("p_layers", "batch", None, None, None)}
    return shapes, axes


def decode_step_vlm(params: dict, cache: dict, tokens: jnp.ndarray, pos,
                    cfg: ArchConfig, policy: ExecPolicy | None = None):
    """One text-token step; image cross-KV precomputed in the cache."""
    policy = policy or ExecPolicy.from_cfg(cfg, training=False)
    x = embedding_lookup(params["embed"], tokens)

    def body(carry, xs):
        sb, ck, cv, xk, xv = xs

        def self_body(c, lxs):
            lp, k1, v1 = lxs
            h = rmsnorm(c, lp["ln1"], cfg.norm_eps)
            o, k1, v1 = attn_decode(lp["attn"], h, k1, v1, pos, cfg, policy)
            c = c + o
            c = c + ffn_mod.swiglu(lp["ffn"],
                                   rmsnorm(c, lp["ln2"], cfg.norm_eps), policy)
            return c, (k1, v1)

        carry, (ck, cv) = jax.lax.scan(self_body, carry, (sb["selfs"], ck, cv))
        hx = rmsnorm(carry, sb["lnx"], cfg.norm_eps)
        carry = carry + _cross(sb["xattn"], sb["xgate"], hx, (xk, xv), cfg,
                               policy).astype(carry.dtype)
        return carry, (ck, cv)

    x, (k2, v2) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = linear(x, params["lm_head"], policy=policy)[:, 0]
    return logits, {"k": k2, "v": v2, "xk": cache["xk"], "xv": cache["xv"]}
