"""Sharding context + logical-axis rules for the whole framework.

Model code annotates activations/params with *logical* axes ("batch", "seq",
"heads", "embed", "mlp", "vocab", "experts", "kv_seq", "stage", ...). A
rules table maps logical axes to mesh axes (or None = replicate). The launch
layer installs a ShardingCtx (mesh + rules); with no context installed, every
annotation is a no-op — so smoke tests and single-device examples run
unchanged.

This is the t5x/MaxText "logical axis rules" pattern, rebuilt minimally.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingCtx", "use_sharding", "current_ctx", "shard", "logical_spec",
           "DEFAULT_RULES", "MULTIPOD_RULES", "DATA_RULES", "MODEL_RULES",
           "named_sharding", "param_spec", "rules_for_mesh", "validate_rules"]

# Default logical->mesh axis rules, single-pod (data, model) mesh.
# FSDP: parameter "embed"/"mlp_in" dims shard over data; TP dims over model.
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    # activations
    "batch": "data",
    "seq": None,
    "kv_seq": "model",        # decode-time KV cache seq sharding (flash-decode)
    "embed": None,
    "heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    # parameters (FSDP axis = data; TP axis = model)
    "p_embed": "data",
    "p_heads": "model",
    "p_mlp": "model",
    "p_vocab": "model",
    "p_experts": "model",
    "p_layers": None,
    "p_state": None,
}

# Multi-pod: pod joins data-parallel batch + FSDP axes.
MULTIPOD_RULES = dict(DEFAULT_RULES)
MULTIPOD_RULES.update({
    "batch": ("pod", "data"),
    "p_embed": ("pod", "data"),
})

# Pure data parallelism over a 1-D ("data",) mesh: only the batch axis
# shards, every other logical axis replicates. This is the serving
# server's mesh (launch.mesh.make_serving_mesh) — micro-batched encodes
# split their frame axis across devices with zero model-code changes,
# params stay replicated (inference over one small prepared weight set).
DATA_RULES: dict[str, str | tuple[str, ...] | None] = {"batch": "data"}

# Model-sharded serving over a 2-D ("data", "model") mesh
# (launch.mesh.make_serving_mesh(model=M)): the encode batch axis still
# data-parallelizes, while attention heads and the FFN hidden dim split
# over "model" — wq/wk/wv/w1 column-shard and w2 row-shards (their output
# columns / input rows are the head / d_ff axis via the vit logical
# axes; wo stays whole — models/sharded_encoder.py all-gathers the merged
# head outputs instead, because wo's dequant runs inside the photonic
# matmul kernel). "p_embed" is deliberately unmapped: inference weights replicate
# on their embed dims (no FSDP — the prepared int8 cache is small), and
# the fused kernels' per-launch activation absmax scopes stay global via
# collectives.replicated_absmax_scale, keeping sharded predictions
# bitwise-identical to the unsharded fused path.
MODEL_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": "data",
    "heads": "model",
    "mlp": "model",
    "p_heads": "model",
    "p_mlp": "model",
}


def validate_rules(mesh: Mesh, rules: Mapping) -> None:
    """Raise when a mesh axis of size > 1 appears in no rule value — that
    axis would silently replicate everything, which is exactly the bug
    that made 2-D meshes fall back to batch-only sharding. Size-1 axes
    are exempt (replication over one device is a no-op by construction).
    """
    used: set[str] = set()
    for rule in rules.values():
        if rule is None:
            continue
        used.update(rule if isinstance(rule, tuple) else (rule,))
    unmapped = [ax for ax in mesh.axis_names
                if mesh.shape[ax] > 1 and ax not in used]
    if unmapped:
        raise ValueError(
            f"mesh axes {unmapped} (size > 1) are not mapped by any "
            f"sharding rule — everything would silently replicate over "
            f"them. Pass rules that use them (e.g. MODEL_RULES for a "
            f"('data','model') serving mesh) or shrink the mesh.")


def rules_for_mesh(mesh: Mesh | None) -> Mapping | None:
    """Explicit mesh-shape -> rules selection (no silent fallback):

      * ``None`` mesh            -> ``None`` (annotations disabled)
      * any mesh with a "pod"    -> MULTIPOD_RULES
      * 1-D ("data",)            -> DATA_RULES  (batch-only DP serving)
      * 2-D ("data", "model")    -> MODEL_RULES (model-sharded serving)
      * anything else            -> DEFAULT_RULES

    The chosen table is validated against the mesh: every size->1 mesh
    axis must be used by some rule, else ValueError.
    """
    if mesh is None:
        return None
    if "pod" in mesh.axis_names:
        rules = MULTIPOD_RULES
    elif tuple(mesh.axis_names) == ("data",):
        rules = DATA_RULES
    elif tuple(mesh.axis_names) == ("data", "model"):
        rules = MODEL_RULES
    else:
        rules = DEFAULT_RULES
    validate_rules(mesh, rules)
    return rules


@dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Mapping[str, str | tuple[str, ...] | None]

    def spec(self, *logical_axes: str | None) -> P:
        parts = []
        for ax in logical_axes:
            if ax is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(ax))
        return P(*parts)

    def sharding(self, *logical_axes: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_axes))


_local = threading.local()


def current_ctx() -> ShardingCtx | None:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh | None, rules: Mapping | None = None):
    """Install a sharding context (None mesh = disable all annotations)."""
    prev = current_ctx()
    if mesh is None:
        _local.ctx = None
    else:
        if rules is None:
            rules = rules_for_mesh(mesh)
        else:
            validate_rules(mesh, rules)
        _local.ctx = ShardingCtx(mesh, rules)
    try:
        yield _local.ctx
    finally:
        _local.ctx = prev


def _axis_size(mesh: Mesh, rule) -> int:
    if rule is None:
        return 1
    if isinstance(rule, tuple):
        n = 1
        for r in rule:
            n *= mesh.shape[r]
        return n
    return mesh.shape[rule]


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Annotate ``x`` with the sharding implied by its logical axes.

    No-op outside a sharding context. Axes whose mesh-rule does not divide
    the dimension evenly are dropped to replicated (jax rejects uneven
    shardings) — e.g. GQA KV heads (8) on a model axis of 16.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank mismatch: {x.shape} vs {logical_axes}")
    parts = []
    for dim, ax in zip(x.shape, logical_axes):
        rule = None if ax is None else ctx.rules.get(ax)
        if rule is not None and dim % _axis_size(ctx.mesh, rule) != 0:
            rule = None
        parts.append(rule)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*parts)))


def logical_spec(shape: Sequence[int], logical_axes: Sequence[str | None],
                 ctx: ShardingCtx) -> P:
    """PartitionSpec for a given shape under the ctx rules (with the same
    divisibility fallback as ``shard``)."""
    parts = []
    for dim, ax in zip(shape, logical_axes):
        rule = None if ax is None else ctx.rules.get(ax)
        if rule is not None and dim % _axis_size(ctx.mesh, rule) != 0:
            rule = None
        parts.append(rule)
    return P(*parts)


def named_sharding(shape: Sequence[int], logical_axes: Sequence[str | None],
                   ctx: ShardingCtx) -> NamedSharding:
    return NamedSharding(ctx.mesh, logical_spec(shape, logical_axes, ctx))


def param_spec(path: str, shape: tuple[int, ...], ctx: ShardingCtx) -> P:
    """Heuristic parameter PartitionSpec from a param path + shape.

    Rules (2D-sharded "FSDP x TP" layout, MaxText-style):
      * stacked-layer leading dim (path under 'layers/') -> p_layers (None)
      * token/vocab embedding (vocab, d)  -> (p_vocab, p_embed)
      * attention/mlp projections (d_in, d_out): the larger "model-parallel"
        dim goes to p_heads/p_mlp, the other to p_embed (FSDP)
      * 1-D params (norm scales, biases) -> replicated
    The concrete mapping is defined in configs via explicit per-leaf logical
    axes where the heuristic is not enough (MoE experts, conv kernels).
    """
    raise NotImplementedError("use configs.param_logical_axes instead")
