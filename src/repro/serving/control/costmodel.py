"""HLO-driven per-flush cost model for the bucket ladder.

Each ladder bucket's encode is priced by *compiling it*: the bucket's
jitted encode is lowered at its exact flush shape
(``jax.jit(...).lower(...).compile()``), the optimized HLO text goes
through ``roofline.hlo_analysis.analyze_module`` (scan-aware FLOPs, HBM
boundary bytes, int8 dot share), and the roofline terms on the pinned HW
constants give a predicted device time per flush. The photonic
accelerator model (``serving.accounting.bucket_report``) prices the same
flush in uJ and accelerator-us, so one table carries both views: what the
host simulation will cost (the number the controller calibrates against
wall clock) and what the modeled accelerator would cost (the number
KFPS/W is made of).

The compile is *not* thrown away: ``executables[k]`` keeps the AOT
executable, and ``StreamServer.autotune_prepare`` installs it as the
bucket's encode path — costing a bucket and warming it are the same
compile, so the autotuned server never pays a second trace of a function
the cost model already built. (The raw predicted seconds are TPU-class
roofline numbers; on any other host they are only a *ranking*. The
controller's calibration fit maps them to observed seconds — see
``controller.py``.)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.roofline.hlo_analysis import Cost, compile_and_cost
from repro.roofline.report import HW
from repro.serving.accounting import bucket_report

__all__ = ["BucketCost", "EncodeCostModel"]


@dataclass(frozen=True)
class BucketCost:
    """One (bucket, micro-batch shape, bit-plan signature) price row."""

    bucket: int                 # kept-patch count k
    microbatch: int             # flush batch rows
    kv_len: int                 # token rows the encode actually sees
    #                             (== bucket, or the ladder cap in
    #                             one-shape mode with kv_len pruning)
    flops: float                # per flush, from the optimized HLO
    hbm_bytes: float            # per flush, fusion-boundary model
    int8_flops: float           # w8a8 dot share (2x MXU peak)
    device_s: float             # roofline max(compute, memory) per flush
    energy_uj: float            # photonic model, per flush (mb frames)
    photonic_us: float          # photonic model latency, per frame
    bits_sig: tuple | None      # per-layer bit plan the price was cut at

    @property
    def per_frame_s(self) -> float:
        return self.device_s / max(self.microbatch, 1)


class EncodeCostModel:
    """Predicted per-flush latency/energy table over the bucket ladder.

    Construction is lazy per bucket: ``from_server`` registers a builder
    for every ladder size but only compiles the ones asked for
    (``ensure``) — probing showed which buckets the workload can hit, and
    pricing a bucket costs its full XLA compile.
    """

    def __init__(self, microbatch: int, hw: HW | None = None):
        self.microbatch = int(microbatch)
        self.hw = hw or HW()
        self.costs: dict[int, BucketCost] = {}
        self.executables: dict[int, Any] = {}
        self._builders: dict[int, Callable[[], tuple]] = {}

    # -- construction ------------------------------------------------------

    @classmethod
    def from_server(cls, server, buckets=None,
                    hw: HW | None = None) -> "EncodeCostModel":
        """Builders over ``server``'s jit ladder (duck-typed: anything with
        ``cfg``/``serve_cfg``/``params``/``ladder`` and the per-bucket
        encode jits). ``buckets`` (default: the whole ladder) are priced
        eagerly; the rest stay lazy."""
        import jax
        import jax.numpy as jnp

        from repro.models.vit import embed_patches

        sc, cfg = server.serve_cfg, server.cfg
        cm = cls(sc.microbatch, hw=hw)
        # token dtype without running the embed: eval_shape on the same
        # callable the server's ingest path jits. The shape probe strips
        # any noise spec — noise multiplies by f32, never changes avals,
        # and abstract tracing must not demand a live noise scope.
        spol = getattr(server.policy, "without_noise",
                       lambda: server.policy)()
        tok = jax.eval_shape(
            lambda p, f: embed_patches(p, f, cfg, spol),
            server.params,
            jax.ShapeDtypeStruct(
                (sc.chunk, cfg.img_size, cfg.img_size, 3), jnp.float32))
        d, dt = tok.shape[-1], tok.dtype
        layer_bits = getattr(server, "layer_bits", None)
        # noisy servers' encode jits take the DriftState as an extra
        # trailing arg — the AOT lowering must match the serve-time call
        # signature (duck-typed: fake test servers need no hook)
        extra_fn = getattr(server, "_encode_extra_args", None)

        def _builder(k: int):
            def build():
                kv = server.ladder.cap if sc.one_shape else k
                fn = (server._encode_one[k] if sc.one_shape
                      else server._encode)
                sds = jax.ShapeDtypeStruct((sc.microbatch, kv, d), dt)
                extra = tuple(extra_fn()) if extra_fn is not None else ()
                return fn, (server.params, sds) + extra, kv
            return build

        for k in server.ladder.sizes:
            cm._builders[int(k)] = _builder(int(k))
        cm._cfg = cfg
        cm._layer_bits = (tuple(int(b) for b in layer_bits)
                          if layer_bits else None)
        for k in (buckets if buckets is not None else server.ladder.sizes):
            cm.ensure(int(k))
        return cm

    def ensure(self, bucket: int) -> BucketCost:
        """Price ``bucket`` (compile + analyze) if not already priced."""
        k = int(bucket)
        if k in self.costs:
            return self.costs[k]
        if k not in self._builders:
            raise KeyError(f"bucket {k} is not on the registered ladder "
                           f"({sorted(self._builders)})")
        fn, args, kv = self._builders[k]()
        cost, compiled = compile_and_cost(fn, *args)
        self.executables[k] = compiled
        self.costs[k] = self._price(k, kv, cost)
        return self.costs[k]

    def _price(self, k: int, kv: int, cost: Cost) -> BucketCost:
        hw = self.hw
        t_c = ((cost.flops - cost.int8_flops) / hw.peak_flops
               + cost.int8_flops / (2.0 * hw.peak_flops))
        t_m = cost.bytes / hw.hbm_bw
        rep = bucket_report(self._cfg, k, self._layer_bits)
        return BucketCost(
            bucket=k, microbatch=self.microbatch, kv_len=kv,
            flops=cost.flops, hbm_bytes=cost.bytes,
            int8_flops=cost.int8_flops, device_s=max(t_c, t_m),
            energy_uj=rep.total_uj * self.microbatch,
            photonic_us=rep.total_us, bits_sig=self._layer_bits)

    # -- queries -----------------------------------------------------------

    def predicted_flush_s(self, bucket: int) -> float:
        """Raw (uncalibrated) predicted seconds for one flush — the
        feature the controller's linear fit maps to observed seconds."""
        return self.ensure(bucket).device_s

    def table(self) -> dict[int, BucketCost]:
        """Every bucket priced so far, ascending."""
        return {k: self.costs[k] for k in sorted(self.costs)}

    def render(self) -> str:
        lines = [f"{'bucket':>7} {'mb':>3} {'GFLOP/flush':>12} "
                 f"{'MB/flush':>9} {'pred us':>8} {'uJ/flush':>9} "
                 f"{'acc us/frame':>13}"]
        for k, c in self.table().items():
            lines.append(
                f"{k:>7} {c.microbatch:>3} {c.flops / 1e9:>12.3f} "
                f"{c.hbm_bytes / 1e6:>9.2f} {c.device_s * 1e6:>8.2f} "
                f"{c.energy_uj:>9.2f} {c.photonic_us:>13.2f}")
        return "\n".join(lines)
