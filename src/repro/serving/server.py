"""Multi-stream session server: many cameras, one photonic accelerator.

The paper's deployment target is a *fleet* of near-sensor streams, and the
throughput lever that Lightening-Transformer / ViTA both lean on is keeping
the accelerator array saturated across concurrent workloads. This module
multiplexes any number of ``StreamSession``\\ s (per-stream state:
``repro.serving.session``) over one shared ``StreamServer`` that owns every
resource the single-stream engine used to conflate with stream state:

  * **one prepared parameter set** — ``prepare_params`` (MR tuning) runs
    once per server, not once per stream;
  * **one per-bucket jit ladder, warmed eagerly at startup** —
    ``warm_start()`` compiles embed/score/order/gather and every bucket's
    encode before the first frame arrives, so first-flush compiles are a
    startup cost instead of being charged to some unlucky stream's fps;
  * **one cross-stream ``MicroBatcher``** — every session's routed frame
    groups land in the same scheduler, keyed ``(bucket, session)``; each
    scheduling round serves sessions in rotating round-robin order and
    executes ready flushes interleaved one-per-session (per-session
    fairness: a bursty stream's backlog cannot starve the others), with an
    optional ``max_wait_chunks`` deadline that pad-flushes partially
    filled micro-batches (``MicroBatcher.flush_stale``);
  * **the device mesh** — with more than one visible device, flushed
    (microbatch, k, d) encodes are placed with the existing ``"batch"``
    logical axis over a 1-D ``("data",)`` mesh (``launch.mesh.
    make_serving_mesh`` + ``distributed.sharding.DATA_RULES``), so the
    batch axis data-parallelizes with zero model-code changes.

**Why micro-batches are session-pure by default.** Every w8a8 backend
quantizes activations with a *per-launch, per-tensor* absmax
(``core/backend._photonic_prologue``), so all frames sharing an encode
launch share quantization scales: co-batching frames from different streams
would couple their numerics (stream A's predictions would depend on what
stream B happened to be looking at). Keyed ``(bucket, session)``, the
shared scheduler multiplexes *launch order* across streams while each
launch's absmax scope stays one stream — which is exactly what makes
round-robin interleaved serving bit-identical, per stream, to sequential
single-stream runs on every backend (enforced by tests/test_multistream.py).
``mix_streams=True`` opts into genuinely cross-session filling (maximum
saturation at partial ladder occupancy) and trades that reproducibility
away on quantized backends; zero padding is always safe — zeros never raise
an absmax.

CLI (4 interleaved streams on the fully fused Pallas path):

    PYTHONPATH=src python -m repro.serving.server --smoke --streams 4 \\
        --backend photonic_pallas --attn-backend flash --ffn-backend fused
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import shutil
import time
import warnings
from dataclasses import dataclass, fields as _dc_fields

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import latest_step, restore_flat
from repro.checkpoint.checkpoint import save as _ckpt_save
from repro.configs.base import ArchConfig
from repro.core.backend import (ExecPolicy, available_backends,
                                prepare_params)
from repro.core.mgnet import MGNetConfig, mask_budget, mgnet_scores
from repro.core.noise import DriftState, NoiseSpec
from repro.core.noise import scoped as _noise_scoped
from repro.data.pipeline import VideoStream, video_fleet
from repro.distributed.fault_tolerance import StragglerDetector
from repro.distributed.sharding import (ShardingCtx, named_sharding,
                                        rules_for_mesh, use_sharding)
from repro.launch.mesh import make_serving_mesh
from repro.models.vit import (embed_patches, forward_vit_masked,
                              forward_vit_tokens, init_vit)
from repro.serving.buckets import BucketLadder
from repro.serving.faults import (CheckpointFault, FatalFault, FaultInjector,
                                  FaultSpec, ServeError, ServerCrash,
                                  SessionFailure, TransientFault)
from repro.serving.mask_cache import TemporalMaskCache
from repro.serving.scheduler import MicroBatcher
from repro.serving.session import (ServingConfig, StreamResult,
                                   StreamSession)

__all__ = ["ServerConfig", "StreamServer", "interleave_rounds", "main"]


def _gather_topk_rows(tokens, order, keep: int):
    """(C, N, d) tokens + (C, N) descending score order -> (C, keep, d).

    The top-``keep`` prefix of the shared order is exactly what
    ``select_topk_patches`` would select (same stable argsort), without
    re-sorting per bucket.
    """
    return jnp.take_along_axis(tokens, order[:, :keep, None], axis=1)


def interleave_rounds(groups, depth: int = 1) -> list:
    """Round-robin merge: ``depth`` elements from each list per pass.

    [[a1, a2, a3], [b1]] -> [a1, b1, a2, a3] at depth 1 — the fairness
    order for executing ready flushes: a session with a backlog yields
    after every ``depth`` launches to every other session that has one
    ready. Depth > 1 (the controller's ``interleave_depth`` knob) trades
    a little per-session fairness for fewer rotation passes when every
    session has a deep ready backlog.
    """
    if depth < 1:
        raise ValueError("interleave depth must be >= 1")
    out, i = [], 0
    while True:
        row = [x for g in groups for x in g[i: i + depth]]
        if not row:
            return out
        out.extend(row)
        i += depth


@dataclass(frozen=True)
class ServerConfig(ServingConfig):
    """ServingConfig + the multi-stream knobs."""

    max_wait_chunks: int = 0     # > 0: pad-flush a partial micro-batch after
    #                              this many scheduling rounds (latency bound;
    #                              0 keeps frames queued until the bucket
    #                              fills or the stream ends — the bitwise-
    #                              reproducible default)
    mix_streams: bool = False    # fill one bucket's micro-batch from several
    #                              sessions (max saturation; couples w8a8
    #                              activation scales across streams — see
    #                              module docstring)
    warm_start: bool = True      # compile the whole jit ladder at startup
    mesh: str = "auto"           # "auto": shard the encode batch axis over a
    #                              1-D data mesh when > 1 device is visible;
    #                              "off": never
    model_shards: int = 0        # > 1: 2-D ("data", "model") serving mesh —
    #                              attention heads + d_ff shard over "model"
    #                              (MODEL_RULES), the fused encode runs under
    #                              shard_map (models/sharded_encoder.py),
    #                              bitwise-equal to unsharded. 0/1 = batch-only
    bit_plan: tuple = ()         # mixed-precision bit plan for the shared
    #                              weight cache (per-layer tuple or the dict
    #                              form — core/bitalloc.py); () = uniform
    #                              quant_bits. ``--bit-budget`` instead
    #                              calibrates one at startup
    autotune: bool = False       # serving control plane: route-probe the
    #                              ladder, price hit buckets with the HLO
    #                              cost model (the compiles double as AOT
    #                              encode executables), then run the online
    #                              controller (serving/control/)
    retune_every: int = 32       # frames between controller evaluations
    interleave_depth: int = 1    # default ready-flush launches per session
    #                              per rotation pass (the controller's
    #                              tunable counterpart)
    telemetry_window: int = 256  # flush-observation ring-buffer size
    faults: FaultSpec | None = None  # deterministic fault injection
    #                              (serving/faults.py); None keeps the loop
    #                              on the exact fault-free instruction
    #                              stream — zero overhead, zero RNG
    retry_limit: int = 3         # transient-fault retries per flush before
    #                              the owning session is quarantined
    retry_backoff_s: float = 0.002  # base of the bounded exponential
    #                              backoff between flush retries (doubles
    #                              per attempt, capped at 1s; 0 disables)
    watchdog: bool = False       # time every flush (block_until_ready —
    #                              costs the async overlap, like autotune)
    #                              and feed a StragglerDetector through the
    #                              telemetry ring: anomalously slow flushes
    #                              land in ``server.straggler_flags``
    max_pending_rows: int = 0    # > 0: bound the shared batcher; an ingest
    #                              chunk arriving above the bound is load-
    #                              shed (dropped, counted per session) —
    #                              the overload response that keeps queue
    #                              memory and latency bounded
    checkpoint_dir: str = ""     # root for periodic serve-loop snapshots
    checkpoint_every: int = 0    # > 0: checkpoint every N scheduling
    #                              rounds (needs checkpoint_dir)
    checkpoint_keep: int = 3     # newest snapshots retained per root

    @staticmethod
    def from_serving(sc: ServingConfig, **overrides) -> "ServerConfig":
        """ServerConfig carrying ``sc``'s fields plus ``overrides``. An
        ``sc`` that already is a ServerConfig keeps its server-specific
        knobs (deadline, mixing, mesh) — only the overrides change."""
        src = type(sc) if isinstance(sc, ServerConfig) else ServingConfig
        base = {f.name: getattr(sc, f.name) for f in _dc_fields(src)}
        base.update(overrides)
        return ServerConfig(**base)


class StreamServer:
    """Shared serving resources + the multi-stream scheduling loop."""

    def __init__(self, cfg: ArchConfig, server_cfg: ServerConfig | None = None,
                 params: dict | None = None, n_classes: int = 10,
                 seed: int = 0):
        if not cfg.mgnet:
            raise ValueError("serving engine needs cfg.mgnet=True "
                             "(the RoI gate is the pipeline's first stage)")
        self.cfg = cfg
        self.serve_cfg = server_cfg or ServerConfig()
        self.policy = ExecPolicy.from_cfg(cfg, training=False)
        # calibrated device noise (cfg.noise: core/noise.py NoiseSpec).
        # The DriftState is server-owned — one device, one thermal history
        # shared by every stream — and is threaded through the jit ladder
        # as an explicit traced argument (``_nargs``), so its per-flush
        # evolution never retraces anything.
        self.noise: NoiseSpec | None = getattr(cfg, "noise", None)
        self.drift = (DriftState.init(self.noise.seed)
                      if self.noise is not None else None)
        self._host_drift_nm = 0.0
        self.recalibrations = 0
        self._active_plan = None
        self.n_patches = (cfg.img_size // cfg.patch) ** 2
        self.ladder = BucketLadder.from_fractions(
            self.n_patches, self.serve_cfg.bucket_fractions)
        self.mcfg = MGNetConfig(patch=cfg.patch, img_size=cfg.img_size,
                                embed=cfg.mgnet_embed, heads=cfg.mgnet_heads)

        if params is None:
            params = init_vit(jax.random.PRNGKey(seed), cfg, n_classes)
        # the raw (pre-tuning) weights are kept: ``calibrate_bits`` re-tunes
        # the cache from them under the emitted plan
        self._raw_params = params
        self.layer_bits: tuple | None = None
        # control plane (populated by autotune_prepare): AOT executables
        # from the cost model's compiles, keyed by bucket
        self._encode_aot: dict[int, object] = {}
        self.cost_model = None
        self.telemetry = None
        self.controller = None
        if self.policy.is_photonic():
            # MR tuning happens once, before any stream starts — shared by
            # every session the server will ever serve.
            params = self._prepare(self.serve_cfg.bit_plan
                                   or getattr(cfg, "bit_plan", None) or None)
        self.params = params

        self.mesh = (make_serving_mesh(
                         model=max(1, self.serve_cfg.model_shards))
                     if self.serve_cfg.mesh == "auto" else None)
        self._rules = rules_for_mesh(self.mesh)
        self._ctx = (ShardingCtx(self.mesh, self._rules)
                     if self.mesh is not None else None)
        self.params = self._maybe_place(self.params)

        cfg_, pol = cfg, self.policy
        gpol = pol.gate_policy()
        if self.noise is None:
            self._embed = jax.jit(
                lambda p, f: embed_patches(p, f, cfg_, pol))
            self._encode = jax.jit(
                lambda p, t: forward_vit_tokens(p, t, cfg_, pol)[0])
            self._encode_dense = jax.jit(
                lambda p, f, m: forward_vit_masked(p, f, m, cfg_, pol)[0])
        else:
            # every noisy entry takes the DriftState as one extra traced
            # argument and installs the noise scope INSIDE the traced body
            # (`scoped`): the per-call-site key counter then restarts per
            # trace, so retraces, eager replays and cached executions all
            # assign identical keys for equal (params, inputs, DriftState)
            self._embed = jax.jit(lambda p, f, ns: _noise_scoped(
                ns, lambda: embed_patches(p, f, cfg_, pol)))
            self._encode = jax.jit(lambda p, t, ns: _noise_scoped(
                ns, lambda: forward_vit_tokens(p, t, cfg_, pol)[0]))
            self._encode_dense = jax.jit(lambda p, f, m, ns: _noise_scoped(
                ns, lambda: forward_vit_masked(p, f, m, cfg_, pol)[0]))
        if self.noise is not None and self.noise.noisy_gate:
            self._score = jax.jit(lambda p, f, ns: _noise_scoped(
                ns, lambda: mgnet_scores(p["mgnet"], f, self.mcfg, gpol)))
        else:
            # default: the RoI gate scores clean even under noise (see
            # ExecPolicy.gate_policy) — routing and bucket shapes stay
            # deterministic, so clean-vs-noisy runs compare frame-by-frame
            self._score = jax.jit(
                lambda p, f: mgnet_scores(p["mgnet"], f, self.mcfg, gpol))
        # one stable descending argsort per chunk (the ordering
        # select_topk_patches defines), then per-bucket static slices of it
        # — not a fresh full-chunk sort + gather per unique bucket
        self._order = jax.jit(
            lambda s: jnp.argsort(s, axis=-1, stable=True, descending=True))
        self._gather = {
            k: jax.jit(functools.partial(_gather_topk_rows, keep=k))
            for k in self.ladder.sizes}
        self._encode_one = {}
        if self.serve_cfg.one_shape:
            if self.noise is None:
                def _one(k: int):
                    return jax.jit(lambda p, t: forward_vit_tokens(
                        p, t, cfg_, pol, kv_len=k)[0])
            else:
                def _one(k: int):
                    return jax.jit(lambda p, t, ns: _noise_scoped(
                        ns, lambda: forward_vit_tokens(
                            p, t, cfg_, pol, kv_len=k)[0]))
            self._encode_one = {k: _one(int(k)) for k in self.ladder.sizes}

        self._sessions: list[StreamSession] = []
        self._next_sid = 0
        self.batcher: MicroBatcher | None = None
        self.flush_log: list[tuple] = []   # (owner sids, bucket k, n_real)
        self.warm_s = 0.0
        # fault tolerance: the injector exists only under a FaultSpec (the
        # fault-free loop must stay on the pre-fault-layer instruction
        # stream — see tests/test_serving_faults.py's bitwise pin)
        self.faults: FaultSpec | None = self.serve_cfg.faults
        self._injector = (FaultInjector(self.faults)
                          if self.faults is not None else None)
        self._watchdog = bool(self.serve_cfg.watchdog)
        if self._watchdog and not self.serve_cfg.autotune:
            self.telemetry = self._make_telemetry()
        self.checkpoint_failures = 0
        self._inflight: dict | None = None  # paused serve() loop state
        self._resume: tuple | None = None   # (rnd, offset) from a restore
        # autotune mode compiles its own (probed-only) jit set inside
        # autotune_prepare — an eager full-ladder warm-up would pay for
        # exactly the dead-bucket compiles the probe exists to skip
        if self.serve_cfg.warm_start and not self.serve_cfg.autotune:
            self.warm_start()

    def _maybe_place(self, params):
        """Pin the prepared weight cache onto a 2-D serving mesh — only
        when the model-sharded encoder will actually engage. Params fed to
        the *unsharded* jit must stay replicated: a committed model-axis
        sharding there would make GSPMD add collectives to a graph whose
        bitwise contract assumes none."""
        if (self._ctx is None or "model" not in self.mesh.axis_names
                or self.mesh.shape["model"] < 2):
            return params
        from repro.core.backend import place_params
        from repro.models import sharded_encoder, vit
        if vit._fused_encoder_ineligible_reason(
                params, self.cfg, self.policy) is not None:
            return params
        if sharded_encoder.sharded_encode_ineligible_reason(
                params, self.cfg, self.policy, self._ctx) is not None:
            return params
        return place_params(params, vit.vit_logical_axes(self.cfg),
                            self._ctx)

    def _prepare(self, plan):
        """MR-tune the shared cache from the raw weights under ``plan``
        (None = uniform ``quant_bits``), fold the plan into the policy
        fingerprint (every policy-keyed jit cache re-keys) and derive the
        per-layer energy view threaded to each session's accounting."""
        from repro.core import bitalloc
        bits = self.cfg.quant_bits or 8
        self._active_plan = plan      # recalibrate() re-tunes under it
        nplan = bitalloc.normalize_bit_plan(plan, self.cfg.n_layers,
                                            default=bits)
        self.policy.bit_plan = bitalloc.plan_key(nplan)
        self.layer_bits = (bitalloc.plan_layer_bits(nplan, self.cfg.n_layers)
                           if nplan is not None else None)
        # AOT executables were lowered against the *previous* params
        # pytree; a re-tuned cache may change avals/treedef, so they are
        # dropped (the jit ladder retraces on its own)
        self._encode_aot = {}
        return prepare_params(self._raw_params, bits=bits, bit_plan=plan,
                              n_layers=self.cfg.n_layers)

    # -- session registry --------------------------------------------------

    def add_session(self, stream: VideoStream, n_frames: int = 64,
                    start: int = 0) -> StreamSession:
        """Register a stream for the next ``serve()``; returns its session."""
        s = StreamSession(self._next_sid, stream, n_frames, start,
                          self.serve_cfg, self.cfg, ladder=self.ladder,
                          layer_bits=self.layer_bits)
        self._next_sid += 1
        self._sessions.append(s)
        return s

    def _score_fn(self, frames):
        if self.noise is not None and self.noise.noisy_gate:
            return self._score(self.params, frames, self.drift)
        return self._score(self.params, frames)

    # -- calibrated device noise + drift-triggered recalibration ----------

    def _nargs(self) -> tuple:
        """Extra trailing args for the embed/encode jits: the DriftState
        under noise, nothing otherwise — call sites stay unforked."""
        return (self.drift,) if self.noise is not None else ()

    # duck-typed hook for EncodeCostModel's builders: the AOT lowering must
    # match the serve-time call signature, extra noise args included
    _encode_extra_args = _nargs

    def inject_drift(self, nm: float) -> None:
        """Add ``nm`` of resonance drift on top of the accumulated state —
        a thermal step/transient for robustness experiments."""
        if self.noise is None:
            raise ValueError("inject_drift needs cfg.noise set")
        self.drift = self.drift.with_drift(
            self.drift.drift_nm + jnp.float32(nm))
        self._host_drift_nm += float(nm)

    def _advance_drift(self, frames: int, extra_sessions=()) -> None:
        if self.noise is None or frames <= 0:
            return
        self.drift = self.drift.advance(self.noise, frames)
        # host-side mirror of the deterministic (rate x frames) component:
        # the per-flush bound check must not sync the device
        self._host_drift_nm += frames * self.noise.drift_rate_nm
        if (self.noise.recal_bound_nm > 0.0
                and self._host_drift_nm >= self.noise.recal_bound_nm):
            self.recalibrate(extra_sessions)

    def recalibrate(self, extra_sessions=()) -> None:
        """Online MR re-tuning: re-run the quantize-once ``prepare_params``
        cache from the raw weights under the active plan and zero the
        accumulated drift — the software analogue of re-locking every MR
        bank onto its wavelength. Billed to every live session's energy
        accounting as one full-model tuning pass."""
        if self.policy.is_photonic():
            aot = self._encode_aot
            self.params = self._maybe_place(self._prepare(self._active_plan))
            # same raw weights + same plan -> identical codes, avals and
            # treedef: the cost model's AOT executables stay valid (unlike
            # calibrate_bits, which changes the plan and must drop them)
            self._encode_aot = aot
        if self.drift is not None:
            self.drift = self.drift.reset_drift()
        self._host_drift_nm = 0.0
        self.recalibrations += 1
        for s in list(self._sessions) + list(extra_sessions):
            if not s.finished:
                s.acct.add_recalibration()

    # -- warm-start jit ladder ---------------------------------------------

    def warm_start(self, buckets: tuple | None = None) -> float:
        """Eagerly compile every jit the serving loop can hit — embed,
        score, order, the per-bucket gathers and (by default) every
        bucket's encode at its exact flush shape — so streams never pay a
        compile. ``buckets`` restricts the encode warm-up to a subset of
        ladder sizes (``autotune_prepare`` passes the probe's hit set);
        buckets already backed by an AOT executable from the cost model
        are skipped — their compile already happened. Returns the warm-up
        wall seconds (also kept as ``self.warm_s``)."""
        sc, cfg = self.serve_cfg, self.cfg
        targets = tuple(k for k in self.ladder.sizes
                        if buckets is None or k in buckets)
        t0 = time.time()
        with use_sharding(self.mesh, self._rules):
            zf = jnp.zeros((sc.chunk, cfg.img_size, cfg.img_size, 3),
                           jnp.float32)
            toks = self._embed(self.params, zf, *self._nargs())  # (C, N, d)
            self._score_fn(zf).block_until_ready()
            zs = jnp.asarray(np.zeros((sc.chunk, self.n_patches),
                                      np.float32))
            order = self._order(zs)                        # (C, N) i32
            warm_gathers = ((self.ladder.cap,) if sc.one_shape
                            else targets)
            pruned = {k: self._gather[k](toks, order) for k in warm_gathers}
            for k in targets:
                if k in self._encode_aot:
                    continue
                src = pruned[self.ladder.cap if sc.one_shape else k]
                zt = jnp.zeros((sc.microbatch,) + src.shape[1:], src.dtype)
                zt = self._place(zt)
                enc = (self._encode_one[k] if sc.one_shape else self._encode)
                enc(self.params, zt, *self._nargs()).block_until_ready()
        self.warm_s = time.time() - t0
        return self.warm_s

    # -- dead-bucket trimming ----------------------------------------------

    def trim(self, dead, keep_cap: bool = True) -> tuple[int, ...]:
        """Drop ladder sizes (``StreamAccounting.dead_buckets()`` output)
        and their per-bucket jits; un-started sessions are re-pointed at
        the trimmed ladder. ``keep_cap=False`` lets the ladder cap go too
        — only safe when routing provably cannot exceed the surviving
        sizes (the ``force_bucket`` pin). Returns the sizes removed."""
        new = self.ladder.trim(dead, keep_cap=keep_cap)
        removed = tuple(sorted(set(self.ladder.sizes) - set(new.sizes)))
        self.ladder = new
        for k in removed:
            self._gather.pop(k, None)
            self._encode_one.pop(k, None)
            self._encode_aot.pop(k, None)
        # un-started sessions are replaced, not mutated: their histogram /
        # accounting must key the trimmed ladder (sids are stable, so
        # callers holding the old object still index serve() results)
        self._sessions = [
            s if s.finished or s.frames_seen > 0
            else StreamSession(s.sid, s.stream, s.n_frames, s.start,
                               self.serve_cfg, self.cfg, ladder=self.ladder,
                               layer_bits=self.layer_bits)
            for s in self._sessions]
        return removed

    def _route_probe(self, calib_frames: int | None = None) -> set[int]:
        """Which ladder buckets the registered sessions' leading frames
        route to — host-side scoring only (throwaway mask caches, no
        embeds/encodes, sessions untouched). Under a ``force_bucket`` pin
        the answer is exact by construction: every frame routes to the
        pinned size regardless of content."""
        sc = self.serve_cfg
        if sc.force_bucket > 0:
            return {self.ladder.route(
                int(round(sc.force_bucket * self.n_patches)))}
        calib = calib_frames or 2 * sc.chunk
        calib = ((calib + sc.chunk - 1) // sc.chunk) * sc.chunk
        hit: set[int] = set()
        for s in self._sessions:
            if s.finished:
                continue
            cache = TemporalMaskCache(sc.mask_refresh,
                                      sc.delta_threshold)
            for ofs in range(0, calib, sc.chunk):
                sub = s.stream.frames_at(s.start + ofs, sc.chunk)
                scores, _ = cache.gate(sub["frames"], sub["frame_idx"],
                                       self._score_fn)
                hit |= set(int(k) for k in self.ladder.route_many(
                    mask_budget(scores, self.mcfg.t_reg)))
        return hit

    def calibrate_trim(self, calib_frames: int | None = None
                       ) -> tuple[int, ...]:
        """Route-only calibration pass: score the first ``calib_frames`` of
        every registered session host-side (throwaway mask caches — the
        sessions themselves are untouched and will re-gate from scratch),
        collect which ladder buckets get hit, and ``trim`` the rest. Run
        *before* ``warm_start()`` so the warmed jit set shrinks too.

        Calibration only sees the window it scored: a later budget shift
        (e.g. the first scene cut past ``calib_frames``) whose frames
        would have routed to a trimmed bucket routes up to the next
        surviving size instead — those frames encode more tokens than an
        untrimmed run would, so the interleaved-vs-sequential bitwise
        contract only holds against an equally-trimmed solo server. A
        ``UserWarning`` spells this out whenever something is trimmed;
        size the window past the stream's budget churn (scene-cut period)
        to trim on a representative distribution."""
        sc = self.serve_cfg
        if not any(not s.finished for s in self._sessions):
            # nothing to calibrate against — an empty pass would declare
            # every non-cap bucket dead and collapse the ladder
            return ()
        hit = self._route_probe(calib_frames)
        dead = tuple(k for k in self.ladder.sizes if k not in hit)
        if not dead:
            return ()
        removed = self.trim(dead)
        if removed and sc.force_bucket <= 0:
            warnings.warn(
                f"calibrate_trim dropped buckets {list(removed)} from a "
                f"calibration window the streams may outgrow: budgets that "
                f"later route to a dropped size will route up to the next "
                f"surviving bucket (more tokens, possibly different "
                f"predictions than an untrimmed run)", stacklevel=2)
        return removed

    # -- sensitivity-driven bit allocation ---------------------------------

    def calibrate_bits(self, target_mean_bits: float,
                       calib_frames: int | None = None,
                       candidates: tuple = (6, 4)) -> tuple:
        """Emit a per-layer bit plan meeting ``target_mean_bits`` and
        re-tune the shared weight cache under it (core/bitalloc.py).

        The calibration batch is the first registered unfinished session's
        leading ``calib_frames`` (default one ingest chunk), embedded on
        the server's own policy — the sensitivity ranking then reflects
        the numerics the streams will actually serve at. Re-tuning swaps
        ``self.params`` (treedef change: every params-taking jit retraces
        on its next call) and updates ``policy.bit_plan``; run *before*
        ``warm_start()`` so the warmed jits compile the final plan.
        Un-started sessions are re-pointed so their energy accounting
        carries the plan's per-layer widths. Returns the plan tuple."""
        from repro.core import bitalloc
        if not self.policy.is_photonic():
            raise ValueError("bit allocation needs a photonic backend "
                             "(the plan drives the quantize-once cache)")
        src = next((s for s in self._sessions if not s.finished), None)
        if src is None:
            raise ValueError("register at least one session before "
                             "calibrate_bits (it provides the calibration "
                             "frames)")
        n = calib_frames or self.serve_cfg.chunk
        frames = jnp.asarray(
            src.stream.frames_at(src.start, n)["frames"], jnp.float32)
        # sensitivity calibration runs clean even under noise: the plan
        # should rank layers by their quantization sensitivity, not by one
        # arbitrary noise draw
        cpol = self.policy.without_noise()
        tokens = embed_patches(self.params, frames, self.cfg, cpol)
        plan = bitalloc.calibrate_bit_plan(
            self._raw_params, tokens, self.cfg, cpol,
            target_mean_bits=target_mean_bits, candidates=candidates,
            default=self.cfg.quant_bits or 8)
        self.params = self._maybe_place(self._prepare(plan))
        self._sessions = [
            s if s.finished or s.frames_seen > 0
            else StreamSession(s.sid, s.stream, s.n_frames, s.start,
                               self.serve_cfg, self.cfg, ladder=self.ladder,
                               layer_bits=self.layer_bits)
            for s in self._sessions]
        return plan

    # -- serving control plane ---------------------------------------------

    def autotune_prepare(self, calib_frames: int | None = None):
        """Stand up the serving control plane (``serving/control/``):

        1. **Route probe** — host-side scoring of each session's leading
           frames finds which ladder buckets the workload can hit. Under
           a ``force_bucket`` pin the unreachable sizes are trimmed
           outright (provably route-invariant — every frame routes to the
           pin either way; without ``one_shape`` even the cap can go).
           Otherwise the ladder is left intact: the probe only decides
           which buckets get *compiled*, never where frames route, so
           predictions stay bitwise identical to a statically-knobbed run.
        2. **Cost model** — each probed bucket's encode is lowered,
           compiled and priced (``EncodeCostModel``); off the mesh path
           the compiled executables are installed as the AOT encode set,
           so costing doubled as warm-up and dead buckets never compile.
        3. **Controller** — telemetry ring buffer + the calibrating,
           clamped knob tuner; the serve loop reads ``controller.knobs``
           every round and calls ``controller.step`` every
           ``retune_every`` frames.

        Returns the controller."""
        from repro.serving.control import (Controller, ControllerConfig,
                                           EncodeCostModel, TunedKnobs)
        sc = self.serve_cfg
        probed = self._route_probe(calib_frames)
        if sc.force_bucket > 0:
            dead = tuple(k for k in self.ladder.sizes if k not in probed)
            if dead:
                self.trim(dead, keep_cap=not sc.one_shape)
        self.cost_model = EncodeCostModel.from_server(
            self, buckets=tuple(sorted(probed & set(self.ladder.sizes))))
        if self.mesh is None:
            # the cost model's compiles were cut at the exact flush avals
            # the loop uses — reuse them as the AOT encode path. With a
            # mesh the serve-time shardings differ from the unsharded
            # lowering, so the jit ladder keeps ownership there.
            self._encode_aot = dict(self.cost_model.executables)
        self.warm_start(buckets=tuple(sorted(probed)))
        self.telemetry = self._make_telemetry()
        defaults = TunedKnobs(max_wait_chunks=sc.max_wait_chunks,
                              interleave_depth=sc.interleave_depth)
        self.controller = Controller(
            self.cost_model, self.telemetry, defaults,
            ControllerConfig(retune_every=sc.retune_every))
        return self.controller

    def _make_telemetry(self):
        """Flush-observation ring; with the watchdog on it carries a
        ``StragglerDetector`` so every timed flush feeds the median+MAD
        slow-flush estimate (``straggler_flags``)."""
        from repro.serving.control import FlushTelemetry
        det = StragglerDetector() if self._watchdog else None
        return FlushTelemetry(self.serve_cfg.telemetry_window,
                              straggler=det)

    @property
    def straggler_flags(self) -> list:
        """Flush observations the watchdog flagged as anomalously slow
        (empty without ``watchdog=True`` / ``autotune`` telemetry)."""
        return (list(self.telemetry.straggler_flags)
                if self.telemetry is not None else [])

    # -- the serving loop --------------------------------------------------

    def serve(self, verbose: bool = False,
              max_rounds: int = 0) -> dict[int, StreamResult]:
        """Serve every registered (unfinished) session to completion,
        interleaved round-robin; returns ``{sid: StreamResult}``. Wall
        time is shared: every result's ``wall_s`` is the loop's span, so
        per-session fps reflects multiplexed service and the *aggregate*
        fps is ``sum(frames) / wall``.

        ``max_rounds > 0`` **pauses** after that many scheduling rounds
        and returns ``{}`` with the loop state (sessions, queued rows,
        round/rotation cursors) held in flight — the deterministic stop
        the checkpoint/migration surfaces operate at; calling ``serve()``
        again resumes exactly where it paused.

        Failure semantics (README "Failure semantics & fault injection"):
        transient flush faults retry with bounded exponential backoff;
        fatal/exhausted failures quarantine only the owning session (its
        ``StreamResult`` comes back ``poisoned`` with the reason) while
        every other session serves to completion. Any *unexpected*
        exception still fails the whole serve, but re-raises as a
        ``ServeError`` attributing the failing bucket/sessions/round and
        carrying partial results for sessions that had fully drained."""
        sc = self.serve_cfg
        if self._inflight is None:
            live = [s for s in self._sessions if not s.finished]
            if not live:
                return {}
            for s in live:
                s.open()
            self.batcher = MicroBatcher(sc.microbatch)
            self.flush_log = []
            rnd, offset = self._resume if self._resume else (0, 0)
            self._resume = None
            st = {"live": live, "rnd": rnd, "offset": offset,
                  "wall_s": 0.0, "retuned_at": 0,
                  "early": self._restore_pending(live)}
            self._inflight = st
        else:
            st = self._inflight
        live = st["live"]
        by_sid = {s.sid: s for s in live}
        t0 = time.time()
        try:
            done = self._serve_loop(st, by_sid, t0, verbose, max_rounds)
        except BaseException as e:
            # an unexpected mid-serve failure poisons the half-served
            # sessions: their accounting/mask-cache state is partial, and
            # re-opening them on the next serve() would re-ingest from
            # frame 0 and double-count — they are abandoned. Sessions that
            # had already fully drained lose nothing: their finished
            # results ride out on the ServeError.
            st["wall_s"] += time.time() - t0
            wall = st["wall_s"]
            partial = {s.sid: s.finish(wall) for s in live
                       if s.drained and (s.failed_reason
                                         or s.acct.frames == s.frames_seen)}
            for s in live:
                s.finished = True
            self._inflight = None
            self._sessions = [s for s in self._sessions if not s.finished]
            if isinstance(e, ServeError):
                e.partial_results.update(partial)
                raise
            ctx = {"round": st["rnd"],
                   "sessions": [s.sid for s in live if not s.drained]}
            raise ServeError(
                f"serve() died at round {ctx['round']} (sessions "
                f"{ctx['sessions']} mid-stream): {e}", context=ctx,
                partial_results=partial) from e
        st["wall_s"] += time.time() - t0
        if not done:
            return {}           # paused by max_rounds; serve() resumes
        wall = st["wall_s"]
        results = {s.sid: s.finish(wall) for s in live}
        self._inflight = None
        # finished sessions leave the registry (long-lived servers and
        # the engine shim's run-per-session pattern stay bounded)
        self._sessions = [s for s in self._sessions if not s.finished]
        return results

    def _serve_loop(self, st, by_sid, t0, verbose, max_rounds) -> bool:
        """Run scheduling rounds until every live session drains (returns
        True) or ``max_rounds`` rounds elapse (returns False — paused).
        Cursors (round, rotation offset) persist in ``st`` across pauses
        and checkpoints."""
        sc = self.serve_cfg
        ctl = self.controller
        live = st["live"]
        rounds = 0
        with use_sharding(self.mesh, self._rules):
            early, st["early"] = st.get("early") or [], []
            if early:
                # flushes that became ready while re-queuing a restored
                # checkpoint's pending rows (cannot happen when the
                # snapshot respected the < microbatch queue invariant,
                # but a hand-edited snapshot must not lose frames)
                self._round = st["rnd"]
                for fb in early:
                    self._safe_finish(fb, by_sid)
            while any(not s.drained for s in live):
                if max_rounds and rounds >= max_rounds:
                    return False
                rnd = st["rnd"]
                # the controller owns the re-timing knobs when present;
                # kn is re-read every round so a step() lands immediately
                kn = ctl.knobs if ctl is not None else None
                max_wait = (kn.max_wait_chunks if kn is not None
                            else sc.max_wait_chunks)
                depth = (kn.interleave_depth if kn is not None
                         else sc.interleave_depth)
                offset = st["offset"]
                rot = live[offset:] + live[:offset]
                st["offset"] = (offset + 1) % len(live)
                per = {s.sid: [] for s in rot}
                late: list = []
                for s in rot:
                    if s.ingest_done:
                        continue
                    if (sc.max_pending_rows > 0 and self.batcher.pending
                            >= sc.max_pending_rows):
                        # load shedding: the queue bound is hit, so this
                        # chunk is pulled off the sensor and dropped whole
                        # (deferring it would deadlock: under max_wait=0 a
                        # partial queue only fills from its own session's
                        # future ingest)
                        batch = s.next_batch()
                        if batch is not None:
                            s.shed(int((np.asarray(batch["frame_idx"])
                                        < s.limit).sum()))
                        continue
                    if self._injector is not None:
                        # fault check BEFORE next_batch: a raised fault
                        # must never half-consume the prefetch iterator
                        try:
                            self._injector.ingest(s.sid, s.chunks_done,
                                                  attempt=s.ingest_attempts)
                        except TransientFault:
                            s.ingest_attempts += 1
                            s.retries += 1
                            continue          # same chunk retries next round
                        except FatalFault as e:
                            self._fail_sessions((s.sid,), str(e), by_sid)
                            continue
                        s.ingest_attempts = 0
                    batch = s.next_batch()
                    if batch is not None:
                        per[s.sid].extend(self._ingest_chunk(s, batch, rnd))
                if sc.mix_streams:
                    if all(s.ingest_done for s in live):
                        late.extend(self.batcher.drain())
                        for s in live:
                            s.drained = True
                else:
                    for s in rot:
                        if s.ingest_done and not s.drained:
                            per[s.sid].extend(self.batcher.drain(
                                select=lambda key, sid=s.sid:
                                key[1] == sid))
                            s.drained = True
                if max_wait > 0:
                    late.extend(self.batcher.flush_stale(rnd - max_wait))
                if kn is not None and kn.flush_threshold:
                    late.extend(self.batcher.flush_filled(
                        lambda key: kn.flush_threshold.get(
                            key[0] if isinstance(key, tuple) else key,
                            self.batcher.microbatch)))
                self._round = rnd
                for fb in interleave_rounds([per[s.sid] for s in rot],
                                            depth):
                    self._safe_finish(fb, by_sid)
                for fb in late:
                    self._safe_finish(fb, by_sid)
                st["rnd"] = rnd + 1
                rounds += 1
                if self._injector is not None:
                    self._injector.round_tick(rnd)   # may raise ServerCrash
                if (sc.checkpoint_every > 0 and sc.checkpoint_dir
                        and st["rnd"] % sc.checkpoint_every == 0):
                    try:
                        self.checkpoint()
                    except CheckpointFault as e:
                        # checkpoint I/O loss degrades gracefully: serving
                        # continues on the last good snapshot
                        self.checkpoint_failures += 1
                        warnings.warn(f"checkpoint skipped: {e}",
                                      stacklevel=2)
                if ctl is not None:
                    done = sum(s.acct.frames for s in live)
                    if done - st["retuned_at"] >= sc.retune_every:
                        ctl.step(self.batcher.queue_stats(), done,
                                 time.time() - t0)
                        st["retuned_at"] = done
                if verbose and st["rnd"] % sc.report_every == 0:
                    dt = time.time() - t0
                    done = sum(s.acct.frames for s in live)
                    print(f"[server] round {st['rnd']:>4d}  {done:>5d} "
                          f"frames  {done / dt:7.1f} frames/s aggregate  "
                          f"(pending {self.batcher.pending}, "
                          f"{sum(not s.ingest_done for s in live)} "
                          f"streams ingesting)")
        if verbose:
            for s in live:
                print(f"[server] session {s.sid}:", s.acct.summary())
        return True

    def _ingest_chunk(self, s: StreamSession, batch: dict, rnd: int) -> list:
        """Gate one session chunk through *its* mask cache, embed on the
        shared jit, route on the shared ladder, and push per-bucket groups
        into the shared batcher. Returns flushes that became ready."""
        sc = self.serve_cfg
        frames = batch["frames"]                           # device view
        idxs = batch["frame_idx"]
        valid = idxs < s.limit
        scores_np, n_scored = s.cache.gate(batch["frames_host"], idxs,
                                           self._score_fn, eligible=valid)
        s.acct.add_mgnet(n_scored)
        toks = self._embed(self.params, frames,
                           *self._nargs())                 # (C, N, d)
        # budget decision on host: scores are already host-resident from
        # the mask cache, and mask_budget stays in numpy for them
        if sc.force_bucket > 0:
            pin = self.ladder.route(
                int(round(sc.force_bucket * self.n_patches)))
            routes = np.full(frames.shape[0], pin)
        else:
            routes = self.ladder.route_many(
                mask_budget(scores_np, self.mcfg.t_reg))

        order = self._order(jnp.asarray(scores_np))        # (C, N), shared
        permuted = (self._gather[self.ladder.cap](toks, order)
                    if sc.one_shape else None)             # (C, cap, d)
        out = []
        for k in np.unique(routes[valid]):
            k = int(k)
            sel = np.flatnonzero((routes == k) & valid)
            # one-shape mode ships the shared cap-size permutation and
            # prunes via the static per-bucket kv_len at encode time
            pruned = (permuted if sc.one_shape
                      else self._gather[k](toks, order))   # (C, k, d)
            s.record_route(k, len(sel))
            group = pruned if len(sel) == frames.shape[0] else pruned[sel]
            key = k if sc.mix_streams else (k, s.sid)
            out.extend(self.batcher.push_many(
                key, group, [(s.sid, int(idxs[i])) for i in sel], now=rnd))
        s.frames_seen += int(valid.sum())
        return out

    def _place(self, tokens):
        """Shard a flush's batch axis over the data mesh (no-op without)."""
        if self._ctx is None:
            return tokens
        return jax.device_put(tokens, named_sharding(
            tokens.shape, ("batch", None, None), self._ctx))

    def _safe_finish(self, fb, by_sid: dict[int, StreamSession]) -> None:
        """Execute one flush with per-session failure isolation. A
        ``SessionFailure`` (injected fatal fault or exhausted retries)
        quarantines only the owning sessions; any *other* exception means
        the shared serving machinery itself broke, and is re-raised as a
        ``ServeError`` attributing the failing bucket, sessions, frames
        and round — the blanket except that used to lose all of that."""
        owners = sorted({sid for sid, _ in fb.frame_idx})
        if owners and all(by_sid[sid].failed_reason for sid in owners
                          if sid in by_sid):
            return            # stale flush of already-quarantined sessions
        k = fb.bucket[0] if isinstance(fb.bucket, tuple) else fb.bucket
        try:
            self._finish(fb, by_sid)
        except SessionFailure as e:
            self._fail_sessions(e.sids, e.reason, by_sid)
        except ServerCrash:
            raise
        except Exception as e:
            rnd = getattr(self, "_round", 0)
            frames = [f"{sid}:{fi}" for sid, fi in fb.frame_idx]
            raise ServeError(
                f"flush failed at bucket k={k} (sessions {owners}, frames "
                f"{frames}, round {rnd}): {e}",
                context={"bucket": k, "sessions": owners,
                         "n_real": fb.n_real, "round": rnd}) from e

    def _fail_sessions(self, sids, reason: str,
                       by_sid: dict[int, StreamSession]) -> None:
        """Quarantine the named sessions: mark them failed (their
        ``StreamResult`` comes back ``poisoned`` with ``reason``), drop
        their queued-but-unflushed frames so no further launch is billed
        to them, and let every other session keep serving. Session-keyed
        batcher queues make the discard surgical; under ``mix_streams``
        queues are shared, so queued rows stay (their flushes skip the
        failed owners' bookkeeping via ``failed_reason``)."""
        fresh = [sid for sid in sids
                 if sid in by_sid and not by_sid[sid].failed_reason]
        if not fresh:
            return
        for sid in fresh:
            by_sid[sid].fail(reason)
        if not self.serve_cfg.mix_streams:
            doomed = set(fresh)
            self.batcher.discard(
                lambda key: isinstance(key, tuple) and key[1] in doomed)
        warnings.warn(f"quarantined session(s) {fresh}: {reason} — "
                      f"remaining sessions keep serving", stacklevel=3)

    def _finish(self, fb, by_sid: dict[int, StreamSession]) -> None:
        # scheduling round tag rides on an instance field, not a parameter:
        # the signature is a stable seam tests stub out
        rnd = getattr(self, "_round", 0)
        sc = self.serve_cfg
        k = fb.bucket[0] if isinstance(fb.bucket, tuple) else fb.bucket
        inj = self._injector
        tag = fb.frame_idx[0] if fb.frame_idx else (0, 0)
        timed = self.controller is not None or self._watchdog
        attempt = 0
        while True:
            try:
                if inj is not None:
                    inj.flush(k, tag, attempt=attempt)
                t0 = time.perf_counter() if timed else 0.0
                tokens = self._place(fb.tokens)
                aot = self._encode_aot.get(k)
                if aot is not None:
                    logits = aot(self.params, tokens, *self._nargs())
                elif sc.one_shape:
                    logits = self._encode_one[k](self.params, tokens,
                                                 *self._nargs())
                else:
                    logits = self._encode(self.params, tokens,
                                          *self._nargs())
                # encodes are billed at bucket k: the packed prefix is
                # contiguous, so the accelerator's static schedule streams
                # only the k live rows through every core. Padded rows
                # ([n_real:]) are never predicted or accounted.
                preds = jnp.argmax(logits[:fb.n_real], -1)
                if inj is not None:
                    stall = inj.stall_s(k, tag)
                    if stall > 0:
                        # injected straggler: the flush completes but slow
                        # — the watchdog's detection target
                        preds.block_until_ready()
                        time.sleep(stall)
                break
            except TransientFault as e:
                attempt += 1
                for sid in {s for s, _ in fb.frame_idx}:
                    if sid in by_sid:
                        by_sid[sid].retries += 1
                if attempt > sc.retry_limit:
                    raise SessionFailure(
                        sorted({s for s, _ in fb.frame_idx}),
                        f"retry limit ({sc.retry_limit}) exhausted: {e}",
                    ) from e
                time.sleep(min(sc.retry_backoff_s * 2 ** (attempt - 1),
                               1.0))
            except FatalFault as e:
                raise SessionFailure(sorted({s for s, _ in fb.frame_idx}),
                                     str(e)) from e
        owners: dict[int, tuple[list, list]] = {}
        for row, (sid, fidx) in enumerate(fb.frame_idx):
            rows, fidxs = owners.setdefault(sid, ([], []))
            rows.append(row)
            fidxs.append(fidx)
        if timed:
            # observed flush latency: launch to materialized result. The
            # sync costs the autotuned path its async overlap — accepted,
            # it is what makes the telemetry the controller calibrates
            # against an honest per-flush number.
            preds.block_until_ready()
            wall = time.perf_counter() - t0
            if self.controller is not None:
                self.controller.record_flush(k, fb.n_real, len(owners),
                                             wall, rnd)
            elif self.telemetry is not None:
                # watchdog-only path: feed the straggler detector directly
                self.telemetry.record(k, fb.n_real, sc.microbatch,
                                      len(owners), wall, rnd)
        for sid, (rows, fidxs) in owners.items():
            sess = by_sid[sid]
            sess.record_flush(k, len(rows))
            if timed:
                sess.acct.add_flush_wall(k, wall)
            sess.add_deferred(fidxs, preds if len(owners) == 1
                              else preds[np.asarray(rows)])
        self.flush_log.append((tuple(sorted(owners)), k, fb.n_real))
        # the device ages by the frames this flush pushed through it; the
        # flush itself observed the pre-advance state
        self._advance_drift(fb.n_real)

    # -- checkpoint / restore / migration ----------------------------------

    def _compat(self) -> dict:
        """The configuration surface a snapshot is only valid under: any
        mismatch between writer and reader changes routing, shapes, or
        numerics, so restore refuses rather than silently diverging."""
        sc = self.serve_cfg
        return {
            "img_size": self.cfg.img_size, "patch": self.cfg.patch,
            "ladder": [int(k) for k in self.ladder.sizes],
            "chunk": sc.chunk, "microbatch": sc.microbatch,
            "mask_refresh": sc.mask_refresh,
            "delta_threshold": sc.delta_threshold,
            "one_shape": bool(sc.one_shape),
            "fingerprint": str(self.policy.fingerprint()),
            "noise": repr(self.noise),
        }

    def _check_compat(self, compat: dict) -> None:
        mine = self._compat()
        diffs = [f"{k}: snapshot={compat.get(k)!r} server={mine[k]!r}"
                 for k in mine if compat.get(k) != mine[k]]
        if diffs:
            raise ValueError("snapshot is incompatible with this server "
                             "(restore would not be bitwise): "
                             + "; ".join(diffs))

    def _pending_of(self, sid: int, remove: bool = False) -> list:
        """This session's queued-but-unflushed batcher entries as plain
        descriptors (tokens device->host). Exporting (not pad-flushing)
        them is what preserves the per-launch absmax scopes of the flushes
        they will eventually join — the bitwise-resume requirement."""
        if self.batcher is None:
            return []
        sel = lambda key: isinstance(key, tuple) and key[1] == sid
        out = []
        for key, t, ix, now, is_row in self.batcher.export(sel):
            out.append({"bucket": int(key[0]), "now": int(now),
                        "is_row": bool(is_row),
                        "fidx": [int(f) for _, f in ix],
                        "tokens": np.asarray(jax.device_get(t))})
        if remove and out:
            self.batcher.discard(sel)
        return out

    def _snapshot(self, live, rnd: int, offset: int) -> tuple[dict, dict]:
        """Flatten server + per-session state into (arrays, extra) for
        ``repro.checkpoint.save``. Controller/autotune state is *not*
        captured: a restored server re-warms and re-calibrates its control
        plane (documented in README) — only prediction-bearing state must
        round-trip bitwise."""
        arrays: dict = {}
        metas = []
        for s in live:
            s_arrays, meta = s.state_dict()
            pend = self._pending_of(s.sid)
            for j, p in enumerate(pend):
                arrays[f"s{s.sid}/pend{j}"] = p.pop("tokens")
            meta["pending"] = pend
            for key, a in s_arrays.items():
                arrays[f"s{s.sid}/{key}"] = a
            metas.append(meta)
        if self.drift is not None:
            arrays["drift/key"] = np.asarray(self.drift.key)
            arrays["drift/frame"] = np.asarray(self.drift.frame)
            arrays["drift/nm"] = np.asarray(self.drift.drift_nm)
        extra = {"sessions": metas, "rnd": int(rnd), "offset": int(offset),
                 "recalibrations": int(self.recalibrations),
                 "host_drift_nm": float(self._host_drift_nm),
                 "next_sid": int(self._next_sid),
                 "compat": self._compat()}
        return arrays, extra

    def checkpoint(self, root: str | None = None,
                   step: int | None = None) -> str:
        """Snapshot every live session (frame cursor, mask cache,
        accounting, deferred predictions, queued rows) plus the server's
        DriftState and loop cursors to ``root/step_<n>`` (atomic
        tmp+rename via ``repro.checkpoint``). Valid mid-serve (between
        rounds — ``serve(max_rounds=...)`` or the ``checkpoint_every``
        cadence) or between serves. Returns the written path."""
        sc = self.serve_cfg
        root = root or sc.checkpoint_dir
        if not root:
            raise ValueError("checkpoint needs a root (checkpoint_dir "
                             "config or the root argument)")
        if sc.mix_streams:
            raise ValueError(
                "checkpoint is unsupported under mix_streams: queued rows "
                "are cross-session, so per-session state cannot be "
                "snapshotted without changing absmax scopes")
        if self._inflight is not None:
            st = self._inflight
            live, rnd, offset = st["live"], st["rnd"], st["offset"]
        else:
            live = [s for s in self._sessions if not s.finished]
            rnd, offset = 0, 0
        arrays, extra = self._snapshot(live, rnd, offset)
        step = int(rnd if step is None else step)
        if self._injector is not None:
            self._injector.checkpoint_io(step)   # may raise CheckpointFault
        os.makedirs(root, exist_ok=True)
        path = os.path.join(root, f"step_{step}")
        _ckpt_save(path, arrays, step=step, extra=extra)
        self._ckpt_gc(root)
        return path

    def _ckpt_gc(self, root: str) -> None:
        keep = self.serve_cfg.checkpoint_keep
        if keep <= 0:
            return
        steps = sorted((int(d.split("_", 1)[1]), d)
                       for d in os.listdir(root)
                       if d.startswith("step_")
                       and d.split("_", 1)[1].isdigit())
        for _, d in steps[:-keep]:
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)

    def restore_checkpoint(self, path_or_root: str,
                           streams: dict | None = None) -> dict:
        """Rebuild sessions from a snapshot written by ``checkpoint()``
        into this (fresh) server; the next ``serve()`` resumes at the
        snapshot's round/rotation cursors and produces the remaining
        predictions bitwise identically to the uninterrupted run.

        Accepts either a concrete ``step_<n>`` directory or a root (the
        newest step is taken). ``streams`` maps sid -> VideoStream for
        frame sources that did not serialize (a snapshot of a plain
        ``VideoStream`` dataclass restores without it). Returns the
        restored ``{sid: StreamSession}``."""
        if self._inflight is not None:
            raise ValueError("cannot restore into a mid-serve server")
        if any(not s.finished for s in self._sessions):
            raise ValueError("cannot restore into a server with live "
                             "sessions (would collide with their sids)")
        path = path_or_root
        if not os.path.exists(os.path.join(path, "meta.json")):
            step = latest_step(path_or_root)
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint under {path_or_root}")
            path = os.path.join(path_or_root, f"step_{step}")
        arrays, _, extra = restore_flat(path)
        self._check_compat(extra.get("compat", {}))
        streams = streams or {}
        sessions: dict[int, StreamSession] = {}
        for meta in extra["sessions"]:
            sid = int(meta["sid"])
            pre = f"s{sid}/"
            sub = {k[len(pre):]: v for k, v in arrays.items()
                   if k.startswith(pre)}
            s = StreamSession.from_state(
                sub, meta, self.serve_cfg, self.cfg, ladder=self.ladder,
                layer_bits=self.layer_bits,
                stream=streams.get(sid, streams.get(str(sid))))
            sessions[sid] = s
            self._sessions.append(s)
        self._next_sid = max(int(extra.get("next_sid", 0)),
                             max(sessions, default=-1) + 1)
        if self.noise is not None and "drift/key" in arrays:
            self.drift = DriftState(jnp.asarray(arrays["drift/key"]),
                                    jnp.asarray(arrays["drift/frame"]),
                                    jnp.asarray(arrays["drift/nm"]))
            self._host_drift_nm = float(extra.get("host_drift_nm", 0.0))
        self.recalibrations = int(extra.get("recalibrations", 0))
        self._resume = (int(extra["rnd"]), int(extra["offset"]))
        return sessions

    def _restore_pending(self, live) -> list:
        """Re-queue restored sessions' exported batcher rows (same groups,
        same ``now`` ticks — see ``MicroBatcher.export``). Any flush that
        becomes ready immediately is returned for execution before the
        first resumed round (cannot happen for a snapshot that respected
        the < microbatch queue invariant, but is handled anyway)."""
        early = []
        for s in live:
            pend = getattr(s, "_pending_restore", None)
            if not pend:
                continue
            for bucket, toks, fidx, now, is_row in pend:
                key = (bucket, s.sid)
                pairs = [(s.sid, int(f)) for f in fidx]
                toks = jnp.asarray(toks)
                if is_row:
                    early.extend(self.batcher.push(key, toks, pairs[0],
                                                   now=now))
                else:
                    early.extend(self.batcher.push_many(key, toks, pairs,
                                                        now=now))
            s._pending_restore = None
        return early

    # -- session migration -------------------------------------------------

    def export_session(self, sid: int) -> dict:
        """Extract one live session — its full state plus its queued
        batcher rows — as a host-side snapshot dict for ``adopt_session``
        on another server. The session leaves this server (its queues are
        discarded after export; it is marked finished). Legal mid-serve
        only while paused (``serve(max_rounds=...)`` returned ``{}``)."""
        if self.serve_cfg.mix_streams:
            raise ValueError("migration is unsupported under mix_streams")
        s = next((s for s in self._sessions
                  if s.sid == sid and not s.finished), None)
        if s is None:
            raise KeyError(f"no live session {sid}")
        arrays, meta = s.state_dict()
        meta["pending"] = self._pending_of(sid, remove=True)
        if self._inflight is not None:
            self._inflight["live"] = [x for x in self._inflight["live"]
                                      if x.sid != sid]
        self._sessions = [x for x in self._sessions if x.sid != sid]
        s.finished = True
        return {"arrays": arrays, "meta": meta, "compat": self._compat()}

    def adopt_session(self, snapshot: dict, stream=None) -> StreamSession:
        """Adopt a session exported by another server mid-stream. The
        remaining predictions are bitwise identical to staying put:
        micro-batches are session-pure, so numerics depend only on the
        session's own frames and the (identical, compat-checked) weights
        — not on which server launches them. Exception: under ``noise``,
        the DriftState is server-owned shared thermal history, so a
        migrated session sees the *destination's* drift trajectory (real
        hardware would too — documented, not hidden)."""
        if self._inflight is not None:
            raise ValueError("cannot adopt mid-serve (pause first)")
        if self.serve_cfg.mix_streams:
            raise ValueError("migration is unsupported under mix_streams")
        self._check_compat(snapshot["compat"])
        meta = snapshot["meta"]
        sid = int(meta["sid"])
        if any(s.sid == sid and not s.finished for s in self._sessions):
            raise ValueError(f"sid {sid} already live on this server")
        s = StreamSession.from_state(snapshot["arrays"], meta,
                                     self.serve_cfg, self.cfg,
                                     ladder=self.ladder,
                                     layer_bits=self.layer_bits,
                                     stream=stream)
        self._sessions.append(s)
        self._next_sid = max(self._next_sid, sid + 1)
        return s

    # -- single-stream dense baseline --------------------------------------

    def run_dense(self, stream: VideoStream, n_frames: int = 64,
                  start: int = 0) -> StreamResult:
        """Mask-mode dense baseline: identical gating, but every frame is
        encoded at all N patches with the RoI mask applied on the attention
        key axis — compute is *not* reduced. The bucketed path's frames/s
        win over this is the serving subsystem's raison d'etre."""
        s = StreamSession(-1, stream, n_frames, start, self.serve_cfg,
                          self.cfg, ladder=None, layer_bits=self.layer_bits)
        t0 = time.time()
        while True:
            batch = s.next_batch()
            if batch is None:
                break
            frames, idxs = batch["frames"], batch["frame_idx"]
            valid = idxs < s.limit
            scores_np, n_scored = s.cache.gate(batch["frames_host"], idxs,
                                               self._score_fn,
                                               eligible=valid)
            s.acct.add_mgnet(n_scored)
            mask = (jax.nn.sigmoid(jnp.asarray(scores_np))
                    > self.mcfg.t_reg).astype(jnp.float32)
            logits = self._encode_dense(self.params, frames, mask,
                                        *self._nargs())
            s.acct.add_encode(self.n_patches, int(valid.sum()))
            s.add_deferred([int(i) for i in idxs],
                           jnp.argmax(logits, -1))
            self._advance_drift(int(valid.sum()), extra_sessions=(s,))
        res = s.finish(time.time() - t0)
        res.bucket_hits = {self.n_patches: res.frames}
        return res


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None):
    from repro.serving.engine import _smoke_cfg

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU config (32x32 frames, 4 layers)")
    ap.add_argument("--variant", default="tiny")
    ap.add_argument("--img-size", type=int, default=96)
    ap.add_argument("--backend", default="photonic_pallas",
                    help=f"matmul backend ({', '.join(available_backends())})")
    ap.add_argument("--attn-backend", default="", choices=["", "xla", "flash"])
    ap.add_argument("--ffn-backend", default="", choices=["", "xla", "fused"])
    ap.add_argument("--streams", type=int, default=4,
                    help="number of concurrent camera sessions")
    ap.add_argument("--frames", type=int, default=64,
                    help="frames per stream")
    ap.add_argument("--phase", type=int, default=16,
                    help="per-stream start offset (stream i starts at i*phase)")
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--mask-refresh", type=int, default=8)
    ap.add_argument("--delta-threshold", type=float, default=0.15)
    ap.add_argument("--buckets", default="0.25,0.5,0.75,1.0")
    ap.add_argument("--one-shape", action="store_true")
    ap.add_argument("--cut-every", type=int, default=32)
    ap.add_argument("--max-wait", type=int, default=0,
                    help="pad-flush partial micro-batches after this many "
                         "scheduling rounds (0: wait for fill or stream end)")
    ap.add_argument("--mix-streams", action="store_true",
                    help="fill micro-batches across sessions (max "
                         "saturation; couples w8a8 activation scales "
                         "across streams)")
    ap.add_argument("--trim-dead-buckets", action="store_true",
                    help="route-only calibration pass, then drop ladder "
                         "buckets no stream hits before warming the jit set")
    ap.add_argument("--calib-frames", type=int, default=0,
                    help="frames per stream for --trim-dead-buckets "
                         "calibration (default 2 chunks)")
    ap.add_argument("--bit-plan", default="",
                    help="mixed-precision bit plan: comma per-layer widths "
                         "('8,6,4,8'), a JSON literal, or a JSON file path "
                         "(core/bitalloc.py formats)")
    ap.add_argument("--bit-budget", type=float, default=0.0,
                    help="> 0: calibrate a per-layer plan to this target "
                         "mean bit width at startup (sensitivity-driven, "
                         "overrides --bit-plan)")
    ap.add_argument("--no-warm-start", action="store_true",
                    help="skip the eager jit-ladder warm-up (first flushes "
                         "then pay their compiles)")
    ap.add_argument("--autotune", action="store_true",
                    help="serving control plane: route-probe the ladder, "
                         "price hit buckets with the HLO cost model (the "
                         "compiles double as AOT encode executables), then "
                         "re-tune the scheduling knobs online with "
                         "hysteresis + safety clamp")
    ap.add_argument("--retune-every", type=int, default=32,
                    help="frames between controller evaluations")
    ap.add_argument("--assert-converged", action="store_true",
                    help="exit nonzero unless the controller calibrated "
                         "and settled (the CI smoke gate)")
    ap.add_argument("--mesh", default="auto", choices=["auto", "off"],
                    help="shard the encode batch axis over visible devices")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="> 1: 2-D (data, model) serving mesh — attention "
                         "heads + d_ff shard over the model axis and the "
                         "fused encode runs under shard_map, bitwise-equal "
                         "to unsharded (needs n_heads and d_ff divisible)")
    ap.add_argument("--noise", action="store_true",
                    help="run with calibrated device noise (FPV + shot + "
                         "MR drift, core/noise.py NoiseSpec); off = clean, "
                         "bitwise-identical dispatch")
    ap.add_argument("--fpv-sigma", type=float, default=0.01,
                    help="fabrication process variation sigma (static "
                         "per-trace multiplicative weight noise)")
    ap.add_argument("--shot-sigma", type=float, default=0.005,
                    help="per-readout shot/thermal noise sigma")
    ap.add_argument("--q-factor", type=float, default=5000.0,
                    help="MR quality factor of the noise operating point")
    ap.add_argument("--drift-rate-nm", type=float, default=0.0,
                    help="resonance drift accumulated per served frame (nm)")
    ap.add_argument("--wander-sigma-nm", type=float, default=0.0,
                    help="per-element resonance wander sigma around the "
                         "common-mode drift (nm)")
    ap.add_argument("--recal-bound-nm", type=float, default=0.0,
                    help="> 0: trigger online recalibration (requantize + "
                         "drift reset, billed as an MR re-tune) when "
                         "accumulated drift crosses this bound")
    ap.add_argument("--adc-quant", action="store_true",
                    help="quantize noisy readouts through the 8-bit ADC "
                         "transfer function")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="seed of the device-noise RNG lineage")
    ap.add_argument("--flush-fault-rate", type=float, default=0.0,
                    help="probability a flush site raises a (retryable) "
                         "transient device fault")
    ap.add_argument("--flush-fatal-rate", type=float, default=0.0,
                    help="probability a flush site raises a fatal fault "
                         "(quarantines the owning session)")
    ap.add_argument("--ingest-fault-rate", type=float, default=0.0,
                    help="probability an ingest chunk raises a transient "
                         "fault (chunk retried next round)")
    ap.add_argument("--stall-rate", type=float, default=0.0,
                    help="probability a flush stalls (injected straggler)")
    ap.add_argument("--stall-s", type=float, default=0.05,
                    help="injected stall duration (seconds)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the fault-injection RNG lineage")
    ap.add_argument("--hard-fail-session", type=int, default=-1,
                    help=">= 0: hard-fail this session id at its first "
                         "ingest (isolation demo)")
    ap.add_argument("--retry-limit", type=int, default=3,
                    help="transient-fault retries per flush before the "
                         "owning session is quarantined")
    ap.add_argument("--watchdog", action="store_true",
                    help="flush watchdog: median+MAD straggler detection "
                         "over per-flush wall times")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="> 0: bound on queued micro-batch rows; ingest "
                         "chunks arriving over the bound are shed")
    ap.add_argument("--checkpoint-dir", default="",
                    help="root directory for session checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="> 0: snapshot every N scheduling rounds to "
                         "--checkpoint-dir")
    ap.add_argument("--json", default="",
                    help="write per-session + aggregate results to this path")
    args = ap.parse_args(argv)

    if args.backend and args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")
    if args.smoke:
        cfg = _smoke_cfg(args.backend, args.attn_backend, args.ffn_backend)
    else:
        from repro.configs.opto_vit import get_config
        cfg = get_config(args.variant, img_size=args.img_size,
                         mgnet=True).with_(matmul_backend=args.backend,
                                           attn_backend=args.attn_backend,
                                           ffn_backend=args.ffn_backend)
    if args.noise:
        cfg = cfg.with_(noise=NoiseSpec(
            q_factor=args.q_factor, fpv_sigma=args.fpv_sigma,
            shot_sigma=args.shot_sigma, drift_rate_nm=args.drift_rate_nm,
            wander_sigma_nm=args.wander_sigma_nm,
            recal_bound_nm=args.recal_bound_nm,
            adc_quantize_output=args.adc_quant, seed=args.noise_seed))

    bit_plan = ()
    if args.bit_plan:
        from repro.core.bitalloc import parse_bit_plan
        bit_plan = parse_bit_plan(args.bit_plan) or ()
    faults = None
    if (args.flush_fault_rate > 0 or args.flush_fatal_rate > 0
            or args.ingest_fault_rate > 0 or args.stall_rate > 0
            or args.hard_fail_session >= 0):
        faults = FaultSpec(flush_fault_rate=args.flush_fault_rate,
                           flush_fatal_rate=args.flush_fatal_rate,
                           ingest_fault_rate=args.ingest_fault_rate,
                           stall_rate=args.stall_rate, stall_s=args.stall_s,
                           hard_fail_session=args.hard_fail_session,
                           seed=args.fault_seed)
    server_cfg = ServerConfig(
        bucket_fractions=tuple(float(f) for f in args.buckets.split(",")),
        microbatch=args.microbatch, chunk=args.chunk,
        mask_refresh=args.mask_refresh,
        delta_threshold=args.delta_threshold, one_shape=args.one_shape,
        max_wait_chunks=args.max_wait, mix_streams=args.mix_streams,
        warm_start=False, mesh=args.mesh, model_shards=args.model_shards,
        bit_plan=bit_plan,
        autotune=args.autotune, retune_every=args.retune_every,
        faults=faults, retry_limit=args.retry_limit,
        watchdog=args.watchdog, max_pending_rows=args.max_pending,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    server = StreamServer(cfg, server_cfg)
    print(f"[server] {cfg.name} {cfg.img_size}x{cfg.img_size} "
          f"backend={server.policy.resolve_backend()} "
          f"attn={server.policy.resolve_attn_backend()} "
          f"ffn={server.policy.resolve_ffn_backend()} "
          f"bits={list(server.layer_bits) if server.layer_bits else (cfg.quant_bits or 8)} "
          f"ladder={list(server.ladder.sizes)} of {server.n_patches} patches "
          f"mesh={'x'.join(str(n) for n in server.mesh.devices.shape) if server.mesh else 'off'}"
          + (f" noise=Q{server.noise.q_factor:g}"
             f"/fpv{server.noise.fpv_sigma:g}/shot{server.noise.shot_sigma:g}"
             if server.noise is not None else ""))

    streams = video_fleet(args.streams, img_size=cfg.img_size,
                          patch=cfg.patch, cut_every=args.cut_every)
    sessions = [server.add_session(st, n_frames=args.frames,
                                   start=i * args.phase)
                for i, st in enumerate(streams)]

    if args.trim_dead_buckets:
        removed = server.calibrate_trim(args.calib_frames or None)
        print(f"[server] calibration trimmed buckets {list(removed)} -> "
              f"ladder {list(server.ladder.sizes)}")
    if args.bit_budget > 0:
        plan = server.calibrate_bits(args.bit_budget,
                                     args.calib_frames or None)
        print(f"[server] bit calibration -> per-layer plan {list(plan)} "
              f"(mean {sum(plan) / len(plan):.2f} bits, "
              f"target {args.bit_budget:g})")
    if args.autotune:
        server.autotune_prepare(args.calib_frames or None)
        print(f"[server] autotune: priced buckets "
              f"{sorted(server.cost_model.costs)} "
              f"(ladder {list(server.ladder.sizes)}), "
              f"{len(server._encode_aot)} AOT executables, "
              f"non-encode jits warmed in {server.warm_s:.2f}s")
        print(server.cost_model.render())
    elif not args.no_warm_start:
        server.warm_start()
        print(f"[server] jit ladder warmed in {server.warm_s:.2f}s "
              f"({len(server.ladder.sizes)} buckets)")

    results = server.serve(verbose=True)
    total = sum(r.frames for r in results.values())
    wall = max((r.wall_s for r in results.values()), default=0.0)
    for s in sessions:
        r = results[s.sid]
        tag = f" POISONED ({r.failure})" if r.poisoned else ""
        print(f"[server] session {s.sid}:", r.summary() + tag)
    agg_fps = total / wall if wall > 0 else 0.0
    print(f"[server] aggregate: {total} frames over {len(sessions)} streams "
          f"in {wall:.2f}s -> {agg_fps:.1f} frames/s "
          f"(warm-up {server.warm_s:.2f}s, "
          f"{len(server.flush_log)} encode launches)")
    if server.noise is not None:
        print(f"[server] noise: drift {server._host_drift_nm:.3f} nm "
              f"residual, {server.recalibrations} recalibrations")
    if server._injector is not None:
        print(f"[server] faults: {server._injector.report()}")
    if server._watchdog:
        print(f"[server] watchdog: {len(server.straggler_flags)} "
              f"straggler flushes flagged")
    if server.controller is not None:
        print("[server]", server.controller.report())
        assert server.controller.clamp_violations == 0, (
            "controller applied knobs outside the safety clamp: "
            f"{server.controller.clamp_violations} violations")
        if args.assert_converged:
            assert server.controller.converged, (
                "controller did not converge: "
                + server.controller.report())

    if args.json:
        payload = {
            "streams": len(sessions), "frames_total": total,
            "aggregate_fps": agg_fps, "warm_s": server.warm_s,
            "ladder": list(server.ladder.sizes),
            "layer_bits": (list(server.layer_bits)
                           if server.layer_bits else None),
            "noise": (None if server.noise is None else {
                "q_factor": server.noise.q_factor,
                "fpv_sigma": server.noise.fpv_sigma,
                "shot_sigma": server.noise.shot_sigma,
                "drift_rate_nm": server.noise.drift_rate_nm,
                "recal_bound_nm": server.noise.recal_bound_nm,
                "recalibrations": server.recalibrations,
            }),
            "faults": (None if server._injector is None
                       else dict(server._injector.injected)),
            "sessions": {
                str(s.sid): {
                    "frames": results[s.sid].frames,
                    "fps": results[s.sid].fps,
                    "kfps_per_watt": results[s.sid].kfps_per_watt,
                    "mean_bits": results[s.sid].mean_bits,
                    "recalibrations": results[s.sid].recalibrations,
                    "bucket_hits": results[s.sid].bucket_hits,
                    "predictions": results[s.sid].predictions,
                    "poisoned": results[s.sid].poisoned,
                    "failure": results[s.sid].failure,
                    "retries": results[s.sid].retries,
                    "shed_frames": results[s.sid].shed_frames,
                } for s in sessions},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[server] wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
