"""End-to-end driver (the paper's deployment scenario): a near-sensor
vision service. Batched image requests flow through

    MGNet region scoring -> static top-k patch pruning -> 8-bit ViT
    backbone (photonic execution mode) -> class logits

while the cross-layer energy model accounts every optical/electronic
event, reporting per-request energy and the KFPS/W the batch achieved —
with and without RoI pruning (paper Figs. 10/11 live).

    PYTHONPATH=src python examples/serve_masked_vit.py \\
        --requests 64 --batch 8 --keep 0.4
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import frame_report
from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.backend import (ExecPolicy, available_backends,
                                prepare_params)
from repro.core.energy import kfps_per_watt
from repro.data.pipeline import ImageStream
from repro.models.vit import forward_vit, init_vit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--keep", type=float, default=0.4,
                    help="MGNet keep ratio (1.0 = no pruning)")
    ap.add_argument("--photonic", action="store_true", default=True)
    ap.add_argument("--backend", default="photonic_sim",
                    help=f"matmul backend: {', '.join(available_backends())}")
    args = ap.parse_args()
    if args.backend and args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")

    cfg = smoke_variant(get_config("tiny")).with_(
        photonic=args.photonic, matmul_backend=args.backend,
        mgnet=True, mgnet_keep_ratio=args.keep)
    base_cfg = cfg.with_(mgnet=False, mgnet_keep_ratio=1.0)
    policy = ExecPolicy.from_cfg(cfg, training=False)

    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    if policy.is_photonic():
        # MR tuning happens once, before any request arrives: every matmul
        # weight (backbone + MGNet) is pre-quantized; the per-request path
        # quantizes only activations.
        params = prepare_params(params, bits=cfg.quant_bits or 8)
        print(f"[serve] backend={policy.resolve_backend()} "
              "(quantize-once weight cache active)")
    stream = ImageStream(img_size=cfg.img_size, global_batch=args.batch,
                         n_classes=8, patch=cfg.patch, seed=0)

    fwd_masked = jax.jit(lambda p, im: forward_vit(p, im, cfg)[0])
    fwd_full = jax.jit(lambda p, im: forward_vit(p, im, base_cfg)[0])

    n_batches = args.requests // args.batch
    served, agree = 0, 0
    t0 = time.time()
    for b in range(n_batches):
        batch = stream.batch_at(b)
        lg_m = fwd_masked(params, batch["images"])
        lg_f = fwd_full(params, batch["images"])
        served += args.batch
        agree += int((jnp.argmax(lg_m, -1) == jnp.argmax(lg_f, -1)).sum())
    wall = time.time() - t0

    # hardware-model accounting for the production-scale config (Tiny-224)
    n_patches = (224 // 16) ** 2
    kept = max(1, int(args.keep * n_patches))
    rep_full = frame_report("tiny", 224)
    rep_mask = frame_report("tiny", 224, kept_patches=kept,
                            include_mgnet=True)

    print(f"served {served} requests in {wall:.1f}s "
          f"(CPU functional sim, batch {args.batch})")
    print(f"masked-vs-full top-1 agreement: {agree / served:.1%} "
          f"(untrained net; trained nets retain accuracy — Table I bench)")
    print("\n-- accelerator model (Tiny-224 workload) --")
    print(f"full frame   : {rep_full.total_uj:7.1f} uJ  "
          f"{kfps_per_watt(rep_full):7.1f} KFPS/W")
    print(f"RoI @keep={args.keep:.0%}: {rep_mask.total_uj:7.1f} uJ  "
          f"{kfps_per_watt(rep_mask):7.1f} KFPS/W  "
          f"({1 - rep_mask.total_uj / rep_full.total_uj:.1%} energy saved)")


if __name__ == "__main__":
    main()
