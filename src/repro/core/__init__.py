"""Opto-ViT core: the paper's contributions as composable JAX modules.

  quant                 - symmetric 8-bit QAT with STE (paper S.IV Accuracy)
  backend               - matmul backend registry + quantize-once weight cache
  noise                 - MR crosstalk/resolution device model (paper S.IV MR)
  photonic              - optical-core WDM chunked MatMul simulator (Figs 4/6)
  mgnet                 - RoI mask generation network + patch pruning (Eq. 3)
  decomposed_attention  - Eq. 2 (Q W_K^T) X^T score dataflow
  energy                - cross-layer energy/latency model (Figs 8-11, Tab IV)
  schedule              - 5-core pipeline occupancy model (Fig. 5)
"""

from repro.core import (backend, decomposed_attention, energy, mgnet, noise,
                        photonic, quant, schedule)

__all__ = ["quant", "backend", "noise", "photonic", "mgnet",
           "decomposed_attention", "energy", "schedule"]
