"""Paper Table IV: KFPS/W efficiency vs SiPh accelerators + GPU/FPGA.

Our number is computed from the calibrated cross-layer model (Tiny-96x96
reference workload, as the paper's headline). Competitor rows carry the
paper's reported figures (the paper itself reconstructed those designs in
its proprietary simulator; we report its table verbatim as the
comparison baseline and validate OUR number against the model)."""

from __future__ import annotations

from benchmarks.common import frame_report
from repro.core.energy import kfps_per_watt

PAPER_TABLE = {          # KFPS/W as reported in Table IV
    "LightBulb [34]": 57.75,
    "HolyLight [33]": 3.3,
    "HQNNA [53]": 34.6,
    "Robin [26]": 46.5,
    "CrossLight [28]": 52.59,       # best case
    "Lightator [36]": 188.24,       # best case
    "Xilinx VCK190 (INT8)": 1.42,
    "NVIDIA A100 (INT8 TRT)": 0.86,
}


def run() -> list[dict]:
    print("\n== Table IV: KFPS/W comparison ==")
    rep = frame_report("tiny", 96)
    ours = kfps_per_watt(rep)
    rows = [{"design": "Opto-ViT (this work, model)", "kfps_w": ours}]
    print(f"  {'Opto-ViT (reproduced model)':<28} {ours:8.1f} KFPS/W "
          f"(paper: 100.4)")
    for k, v in PAPER_TABLE.items():
        rows.append({"design": k, "kfps_w": v})
        print(f"  {k:<28} {v:8.2f} KFPS/W "
              f"({ours / v:5.1f}x {'better' if ours > v else 'worse'})")
    assert abs(ours - 100.4) / 100.4 < 0.05, \
        f"calibration drifted: {ours} vs paper 100.4"
    # paper's ordering claims: beats everything except Lightator-best
    for k, v in PAPER_TABLE.items():
        if "Lightator" not in k:
            assert ours > v, (k, v)
    return rows
