"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  with c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence mode uses jax.lax.associative_scan on (A, B) pairs with the
affine composition (A2*A1, A2*B1 + B2) — log-depth, matmul-free. The block
wraps the recurrence Griffin-style: in-proj -> short conv -> RG-LRU, gated
by a parallel GeLU branch, then out-proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ExecPolicy, causal_conv1d, he_init, linear

__all__ = ["init_rglru", "rglru_forward", "rglru_decode_step",
           "rglru_logical_axes", "rglru_state_shape"]

_C = 8.0


def init_rglru(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, w = cfg.d_model, cfg.lru_dim
    ks = jax.random.split(key, 6)
    # Lambda init so that a spans ~(0.9, 0.999) at r=1 (Griffin appendix)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32)
    return {
        "in_proj": he_init(ks[0], (d, w), dtype),     # recurrent branch
        "gate_proj": he_init(ks[1], (d, w), dtype),   # GeLU gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_kernel, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "w_a": he_init(ks[3], (w, w), dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": he_init(ks[4], (w, w), dtype),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "out_proj": he_init(ks[5], (w, d), dtype),
    }


def rglru_logical_axes(cfg) -> dict:
    return {"in_proj": ("p_embed", "p_mlp"), "gate_proj": ("p_embed", "p_mlp"),
            "conv_w": (None, None),
            "w_a": ("p_mlp", None), "b_a": (None,),
            "w_x": ("p_mlp", None), "b_x": (None,),
            "lambda": (None,),
            "out_proj": ("p_mlp", "p_embed")}


def rglru_state_shape(cfg, batch: int) -> dict:
    return {"h": (batch, cfg.lru_dim),
            "conv": (batch, cfg.conv_kernel - 1, cfg.lru_dim)}


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(uf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_forward(params: dict, x: jnp.ndarray, cfg,
                  policy: ExecPolicy | None = None, initial_state=None):
    """x: (B, S, d_model) -> (y, final_state)."""
    u = linear(x, params["in_proj"], policy=policy)
    conv0 = None if initial_state is None else initial_state["conv"]
    u, conv_state = causal_conv1d(u, params["conv_w"], conv0)
    a, b = _gates(params, u)                          # (B, S, W) f32

    if initial_state is not None:
        # fold h0 into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * initial_state["h"].astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    hn = h[:, -1]

    gate = jax.nn.gelu(linear(x, params["gate_proj"], policy=policy)
                       .astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return linear(y, params["out_proj"], policy=policy), {
        "h": hn, "conv": conv_state}


def rglru_decode_step(params: dict, x: jnp.ndarray, state: dict, cfg,
                      policy: ExecPolicy | None = None):
    """x: (B, 1, d_model) -> (y, new_state)."""
    u = linear(x, params["in_proj"], policy=policy)
    u, conv_state = causal_conv1d(u, params["conv_w"], state["conv"])
    a, b = _gates(params, u)                          # (B, 1, W)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    gate = jax.nn.gelu(linear(x, params["gate_proj"], policy=policy)
                       .astype(jnp.float32))
    y = (h[:, None] * gate).astype(x.dtype)
    return linear(y, params["out_proj"], policy=policy), {
        "h": h, "conv": conv_state}
