"""Quickstart: the Opto-ViT stack in five snippets.

    PYTHONPATH=src python examples/quickstart.py

1. photonic w8a8 MatMul (behavioural sim + Pallas kernel, interpret mode)
2. MR device model: why 8-bit needs Q ~= 5000
3. Eq. 2 decomposed attention == standard attention
4. MGNet region scoring + static top-k patch pruning
5. an Opto-ViT forward in fp32 / QAT-8bit / photonic execution modes
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.decomposed_attention import (attention_scores_decomposed,
                                             attention_scores_standard)
from repro.core.mgnet import MGNetConfig, init_mgnet, mgnet_scores
from repro.core.noise import MRConfig, required_q_factor, resolution_bits
from repro.core.photonic import photonic_matmul_sim
from repro.kernels.ops import photonic_matmul
from repro.models.vit import forward_vit, init_vit

key = jax.random.PRNGKey(0)

# -- 1. photonic MatMul ----------------------------------------------------
x = jax.random.normal(key, (64, 200))
w = jax.random.normal(jax.random.PRNGKey(1), (200, 96))
y_exact = x @ w
y_sim = photonic_matmul_sim(x, w)            # WDM chunk-walk simulator
y_kern = photonic_matmul(x, w)               # Pallas int8 kernel (interpret)
print("1. photonic matmul: |sim-exact|/|exact| ="
      f" {float(jnp.abs(y_sim - y_exact).max() / jnp.abs(y_exact).max()):.4f}"
      f"  (8-bit quantization); kernel==sim: "
      f"{np.allclose(np.asarray(y_kern), np.asarray(y_sim), atol=1e-3)}")

# -- 2. MR resolution ------------------------------------------------------
q = required_q_factor(8.0)
print(f"2. MR model: 8-bit resolution needs Q >= {q:.0f} "
      f"(paper: ~5000); at Q=5000 resolution = "
      f"{resolution_bits(MRConfig(q_factor=5000)):.2f} bits")

# -- 3. Eq. 2 decomposition -------------------------------------------------
xx = jax.random.normal(key, (10, 48))
wq = jax.random.normal(jax.random.PRNGKey(2), (48, 16))
wk = jax.random.normal(jax.random.PRNGKey(3), (48, 16))
s1 = attention_scores_standard(xx, wq, wk, 0.25)
s2 = attention_scores_decomposed(xx, wq, wk, 0.25)
print(f"3. Eq. 2: max |standard - decomposed| = "
      f"{float(jnp.abs(s1 - s2).max()):.2e} (identical up to fp)")

# -- 4. MGNet --------------------------------------------------------------
mcfg = MGNetConfig(patch=8, embed=32, heads=2, img_size=32)
mparams = init_mgnet(jax.random.PRNGKey(4), mcfg)
imgs = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32, 3))
scores = mgnet_scores(mparams, imgs, mcfg)
print(f"4. MGNet: region scores {scores.shape} for {mcfg.n_patches} patches"
      f"; top-4 of img0: {np.asarray(jnp.argsort(scores[0])[-4:])}")

# -- 5. Opto-ViT modes -----------------------------------------------------
cfg = smoke_variant(get_config("tiny"))
params = init_vit(jax.random.PRNGKey(6), cfg, n_classes=10)
imgs = jax.random.normal(jax.random.PRNGKey(7),
                         (2, cfg.img_size, cfg.img_size, 3))
lg_fp, _ = forward_vit(params, imgs, cfg.with_(quant_bits=0))
lg_q, _ = forward_vit(params, imgs, cfg.with_(quant_bits=8))
lg_ph, _ = forward_vit(params, imgs, cfg.with_(photonic=True))
cor = np.corrcoef(np.asarray(lg_fp).ravel(), np.asarray(lg_ph).ravel())[0, 1]
print(f"5. Opto-ViT: fp32 vs photonic-execution logits corr = {cor:.4f} "
      f"(8-bit optical core preserves the function)")
print("\nquickstart OK")
