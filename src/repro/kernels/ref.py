"""Pure-jnp oracles for every Pallas kernel (the numerics contracts)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["photonic_matmul_ref", "flash_attention_ref"]


def photonic_matmul_ref(xq: jax.Array, wq: jax.Array, sx: jax.Array,
                        sw: jax.Array) -> jax.Array:
    """Integer-exact w8a8 matmul + dequant. xq (M,K) int8; wq (K,N) int8;
    sx () f32; sw (N,) f32 -> (M,N) f32. Must match the Pallas kernel
    bit-for-bit (integer accumulate is exact)."""
    acc = jax.lax.dot_general(xq.astype(jnp.int32), wq.astype(jnp.int32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw[None, :]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """Dense softmax attention oracle. q (B,H,Sq,D); k/v (B,Hkv,Skv,D)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window > 0:
        mask &= q_pos - kv_pos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
