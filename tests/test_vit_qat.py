"""Opto-ViT pipeline tests: QAT/photonic execution modes, MGNet pruning,
mechanism-level reproduction of the paper's accuracy claims (Table I shows
<=1.6% QAT degradation; we verify the *mechanism* on a synthetic separable
task — full ImageNet runs are out of scope on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.data.pipeline import ImageStream
from repro.models.vit import forward_vit, init_vit, vit_matmul_shapes


def _smoke_vit(**kw):
    return smoke_variant(get_config("tiny")).with_(**kw)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))


def test_execution_modes_agree(images):
    """fp32 / QAT-8bit / photonic-sim paths must agree closely (8-bit
    quantization error only)."""
    cfg_fp = _smoke_vit(quant_bits=0, photonic=False)
    params = init_vit(jax.random.PRNGKey(1), cfg_fp, n_classes=8)
    lg_fp, _ = forward_vit(params, images, cfg_fp)
    lg_q, _ = forward_vit(params, images, cfg_fp.with_(quant_bits=8))
    lg_ph, _ = forward_vit(params, images, cfg_fp.with_(photonic=True))
    assert np.corrcoef(np.asarray(lg_fp).ravel(),
                       np.asarray(lg_q).ravel())[0, 1] > 0.99
    assert np.corrcoef(np.asarray(lg_fp).ravel(),
                       np.asarray(lg_ph).ravel())[0, 1] > 0.99


def test_mgnet_pruning_reduces_tokens(images):
    cfg = _smoke_vit(mgnet=True, mgnet_keep_ratio=0.5)
    params = init_vit(jax.random.PRNGKey(1), cfg, n_classes=8)
    lg, kept = forward_vit(params, images, cfg)
    n_patches = (cfg.img_size // cfg.patch) ** 2
    assert kept == max(1, int(0.5 * n_patches))
    assert lg.shape == (2, 8)


def test_decomposed_attention_mode(images):
    """attn_impl='decomposed' (paper Eq. 2) must match standard: tightly in
    full precision; under 8-bit execution only up to quantization noise —
    the two dataflows quantize at different points (W_K^T/sqrt(d) is tuned
    as its own weight), so exact agreement is not expected there."""
    cfg_fp = _smoke_vit(quant_bits=0)
    params = init_vit(jax.random.PRNGKey(1), cfg_fp, n_classes=8)
    lg_std, _ = forward_vit(params, images, cfg_fp)
    lg_dec, _ = forward_vit(params, images,
                            cfg_fp.with_(attn_impl="decomposed"))
    np.testing.assert_allclose(np.asarray(lg_std), np.asarray(lg_dec),
                               rtol=5e-3, atol=5e-3)

    cfg_q = _smoke_vit()                       # quant_bits=8 (paper default)
    lg_qs, _ = forward_vit(params, images, cfg_q)
    lg_qd, _ = forward_vit(params, images, cfg_q.with_(attn_impl="decomposed"))
    corr = np.corrcoef(np.asarray(lg_qs).ravel(),
                       np.asarray(lg_qd).ravel())[0, 1]
    assert corr > 0.99, corr


def test_matmul_shapes_scale_with_pruning():
    cfg = get_config("tiny", img_size=96)
    full = vit_matmul_shapes(cfg)
    pruned = vit_matmul_shapes(cfg, kept_patches=12)   # of 36
    flops = lambda shapes: sum(2 * m * k * n for m, k, n in shapes)
    # FLOPs scale superlinearly down with patch pruning (attn is quadratic)
    assert flops(pruned) < 0.45 * flops(full)


def _train_acc(cfg, steps=150, seed=0):
    """Quadrant-classification accuracy after brief training (4 classes,
    strongly learnable from the planted box)."""
    from repro.data.pipeline import quadrant_labels
    stream = ImageStream(img_size=32, global_batch=32, n_classes=8,
                         patch=8, seed=seed)
    params = init_vit(jax.random.PRNGKey(seed), cfg, n_classes=4)

    def loss_fn(p, images, labels):
        lg, _ = forward_vit(p, images, cfg)
        lf = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, -1)
        gold = jnp.take_along_axis(lf, labels[:, None], -1)[:, 0]
        return (lse - gold).mean()

    @jax.jit
    def step(p, images, labels):
        l, g = jax.value_and_grad(loss_fn)(p, images, labels)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g), l

    for i in range(steps):
        b = stream.batch_at(i)
        params, _ = step(params, b["images"], quadrant_labels(b["patch_mask"]))

    correct = total = 0
    for j in range(3):
        b = stream.batch_at(1000 + j)
        lg, _ = forward_vit(params, b["images"], cfg)
        correct += int((jnp.argmax(lg, -1)
                        == quadrant_labels(b["patch_mask"])).sum())
        total += b["patch_mask"].shape[0]
    return correct / total


@pytest.mark.slow
def test_qat_accuracy_near_fp(subtests=None):
    """Paper Table I mechanism: 8-bit QAT accuracy within a few points of
    full-precision on a learnable synthetic task."""
    cfg_fp = _smoke_vit(n_layers=2, remat=False)
    acc_fp = _train_acc(cfg_fp)
    acc_q = _train_acc(cfg_fp.with_(quant_bits=8))
    assert acc_fp > 0.55, acc_fp                   # task is learnable
    assert acc_q > acc_fp - 0.15, (acc_fp, acc_q)  # QAT holds accuracy
