"""Sharding rules + context tests (single-device degenerate mesh; the
512-device production meshes are exercised by launch/dryrun.py only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (DEFAULT_RULES, MULTIPOD_RULES,
                                        ShardingCtx, current_ctx,
                                        logical_spec, named_sharding, shard,
                                        use_sharding)


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingCtx(mesh, DEFAULT_RULES)


def test_shard_noop_without_ctx():
    x = jnp.ones((4, 8))
    y = shard(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert current_ctx() is None


def test_ctx_installs_and_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert current_ctx() is None
    with use_sharding(mesh):
        assert current_ctx() is not None
        with use_sharding(None):
            assert current_ctx() is None
        assert current_ctx() is not None
    assert current_ctx() is None


def test_spec_mapping(ctx):
    assert ctx.spec("batch", "seq", "embed") == P("data", None, None)
    assert ctx.spec("batch", None, "mlp") == P("data", None, "model")
    assert ctx.spec("p_embed", "p_mlp") == P("data", "model")


def test_multipod_rules_add_pod_axis():
    assert MULTIPOD_RULES["batch"] == ("pod", "data")
    assert MULTIPOD_RULES["p_embed"] == ("pod", "data")
    assert MULTIPOD_RULES["p_mlp"] == "model"       # TP unchanged


def test_logical_spec_divisibility_fallback():
    """Rules whose axis size does not divide the dim drop to replicated —
    e.g. GQA kv_heads=8 on model=16, odd vocabs, batch=1 decode."""

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    fctx = ShardingCtx(FakeMesh(), DEFAULT_RULES)
    # 50280 % 16 != 0 -> vocab dim replicated
    spec = logical_spec((32, 50280), ("batch", "vocab"), fctx)
    assert spec == P("data", None)
    # batch=1 under data=16 -> replicated
    spec = logical_spec((1, 128), ("batch", "seq"), fctx)
    assert spec == P(None, None)
    # clean divisible case keeps both
    spec = logical_spec((32, 4096), ("batch", "mlp"), fctx)
    assert spec == P("data", "model")


def test_shard_applies_constraint_under_jit(ctx):
    with use_sharding(ctx.mesh, ctx.rules):
        @jax.jit
        def f(x):
            return shard(x, "batch", "embed") * 2

        y = f(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(y), 2.0)


def test_shard_rank_mismatch_raises(ctx):
    with use_sharding(ctx.mesh, ctx.rules):
        with pytest.raises(ValueError, match="rank"):
            shard(jnp.ones((4, 8)), "batch")


def test_named_sharding_roundtrip(ctx):
    ns = named_sharding((8, 16), ("batch", "mlp"), ctx)
    assert ns.spec == P("data", "model")
