"""Pallas TPU kernel: fused flash attention (GQA, causal/local).

Streaming-softmax attention with VMEM-resident running (max, sum, acc)
state — the (Sq, Skv) score matrix never reaches HBM. Grid layout:

    grid = (B * H, Sq/bq, Skv/bkv)

The innermost (KV) grid dimension accumulates into VMEM scratch; on the
last KV step the normalized block output is written. GQA is expressed in
the BlockSpec index maps: query row ``i`` reads KV row ``i // group`` —
no KV repetition materializes.

Causal + local-window masking is applied per element; fully-masked KV
blocks are skipped with ``pl.when`` (the kernel-level analogue of the
causal_block_skip hillclimb in the XLA path).

``flash_attention_masked`` is the RoI-aware variant the Opto-ViT serving
hot path runs: it takes a per-batch key keep-mask (or a packed kept-count
for the bucketed ladder), applies it inside the streaming-softmax update,
and skips KV blocks whose keys are *all* pruned — so non-RoI patches cost
zero score FLOPs instead of being masked after the full (Sq, Skv) compute
is paid. The per-(batch, kv-block) live counts are reduced once on the
XLA side and read from SMEM, mirroring flash_decode's ``len_ref``.

Validated in interpret mode against kernels/ref.py::flash_attention_ref
over shape/dtype/mask sweeps (tests/test_kernels_flash.py and the
hypothesis harness in tests/test_differential.py).

Bit widths: the score-softmax-PV core is float end to end (score and PV
products are activation-activation matmuls — dynamically tuned cores on
the photonic hardware), so this kernel is *width-agnostic*: under a
mixed-precision bit plan the per-projection widths live entirely in the
upstream int8 Q/K/V projections (kernels/ops.py::
fused_roi_attention_prequant quantizes each projection's activations at
its own cached weight's width); nothing here takes a ``bits`` parameter.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import expand_kv_heads, prefix_key_mask

__all__ = ["flash_attention_kernel", "flash_attention",
           "flash_attention_masked_kernel", "flash_attention_masked",
           "flash_attention_masked_xla", "fused_masked_attention"]

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                           *, scale: float, causal: bool, window: int,
                           bq: int, bkv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    kv_lo = ki * bkv

    # live = this KV block intersects the visible region of this Q block
    live = True
    if causal:
        live = kv_lo <= q_lo + bq - 1
    if window > 0:
        live = jnp.logical_and(live, kv_lo + bkv - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= q_pos >= kv_pos
        if window > 0:
            mask &= q_pos - kv_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B, H, Sq, D); k/v (B, Hkv, Skv, D) -> (B, H, Sq, D).

    H must be a multiple of Hkv (GQA group = H // Hkv); Sq % bq == 0,
    Skv % bkv == 0. D should be a multiple of 128 on real TPUs (lane
    alignment); interpret mode accepts any D.
    """
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0 and sq % bq == 0 and skv % bkv == 0, \
        (q.shape, k.shape, bq, bkv)
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(b * h, sq, dh)
    kf = k.reshape(b * hkv, skv, dh)
    vf = v.reshape(b * hkv, skv, dh)

    grid = (b * h, sq // bq, skv // bkv)
    kern = functools.partial(flash_attention_kernel, scale=scale,
                             causal=causal, window=window, bq=bq, bkv=bkv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bkv, dh), lambda i, qi, ki, g=g: (i // g, ki, 0)),
            pl.BlockSpec((1, bkv, dh), lambda i, qi, ki, g=g: (i // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, dh)


# --------------------------------------------------------------------------
# RoI-masked variant (key-axis keep-mask, fully-pruned KV blocks skipped)
# --------------------------------------------------------------------------

def flash_attention_masked_kernel(nlive_ref, q_ref, k_ref, v_ref, mask_ref,
                                  o_ref, m_ref, l_ref, acc_ref, *,
                                  scale: float):
    """One (bq, bkv) tile of key-masked bidirectional flash attention.

    ``nlive_ref`` (SMEM) holds the number of unmasked keys in this KV
    block; when zero the whole tile is skipped — no score dot, no softmax
    update, no PV dot. Inside a live tile masked keys get ``NEG_INF``
    scores so they carry exactly-zero probability weight. A live tile has
    >= 1 unmasked key, so every row max stays finite and the classic
    ``exp(NEG_INF - NEG_INF)`` poisoning cannot occur.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(nlive_ref[0, 0] > 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask_ref[...] > 0, s, NEG_INF)      # (1, bkv) bcast

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        # rows whose every key is masked (l == 0) output exactly zero
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def _fit_block(s: int, block: int) -> int:
    """Largest usable block size for a length-``s`` axis: ``block`` when the
    axis exceeds it, else the axis rounded up to the f32 sublane (8)."""
    return block if s > block else -(-s // 8) * 8


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    r = (-x.shape[axis]) % to
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


def flash_attention_masked(q: jax.Array, k: jax.Array, v: jax.Array,
                           key_mask: jax.Array | None = None, *,
                           kv_len: jax.Array | int | None = None,
                           scale: float | None = None,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = True) -> jax.Array:
    """Key-masked bidirectional flash attention (the RoI serving kernel).

    q (B, H, Sq, D); k (B, Hk, Skv, D); v (B, Hv, Skv, Dv) ->
    (B, H, Sq, Dv). H must be a multiple of both Hk and Hv (independent
    GQA groups, so the Eq. 2 decomposed dataflow — shared X as keys,
    per-head V — routes through the same kernel). D and Dv may differ.

    ``key_mask`` (B, Skv) keep-mask ({0,1}, any numeric dtype) prunes keys
    per batch row; ``kv_len`` (scalar or (B,)) is the packed alternative
    for the bucketed path — key j is kept iff j < kv_len. Give at most
    one. KV blocks with no kept key are skipped inside the kernel
    (``pl.when`` on an SMEM live-count), so a 50%-pruned packed stream
    pays ~50% of the score/PV FLOPs. ``scale`` defaults to 1/sqrt(D);
    pass 1.0 when the scale is already folded into Q (Eq. 2).

    Sq/Skv need not be block multiples: both are padded (padded keys are
    masked out, padded query rows sliced off). Rows with zero live keys
    return exactly 0 — matching kernels/ref.py::flash_attention_ref.
    """
    b, h, sq, d = q.shape
    _, hk, skv, _ = k.shape
    _, hv, _, dv = v.shape
    assert h % hk == 0 and h % hv == 0, (q.shape, k.shape, v.shape)
    assert k.shape[2] == v.shape[2], (k.shape, v.shape)
    if key_mask is not None and kv_len is not None:
        raise ValueError("give key_mask or kv_len, not both")
    if key_mask is None:
        key_mask = (jnp.ones((b, skv), jnp.float32) if kv_len is None
                    else prefix_key_mask(kv_len, b, skv))
    assert key_mask.shape == (b, skv), (key_mask.shape, (b, skv))
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = _fit_block(sq, bq)
    bkv = _fit_block(skv, bkv)
    qp = _pad_axis(q, 2, bq)
    kp = _pad_axis(k, 2, bkv)
    vp = _pad_axis(v, 2, bkv)
    maskp = _pad_axis(key_mask.astype(jnp.float32), 1, bkv)
    sqp, skvp = qp.shape[2], kp.shape[2]
    nkv = skvp // bkv
    # per-(batch, kv-block) live-key counts — the block-skip predicate
    nlive = maskp.reshape(b, nkv, bkv).sum(-1).astype(jnp.int32)

    gk, gv = h // hk, h // hv
    qf = qp.reshape(b * h, sqp, d)
    kf = kp.reshape(b * hk, skvp, d)
    vf = vp.reshape(b * hv, skvp, dv)

    grid = (b * h, sqp // bq, nkv)
    kern = functools.partial(flash_attention_masked_kernel, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, qi, ki, h=h: (i // h, ki),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, d), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bkv, d),
                         lambda i, qi, ki, gk=gk: (i // gk, ki, 0)),
            pl.BlockSpec((1, bkv, dv),
                         lambda i, qi, ki, gv=gv: (i // gv, ki, 0)),
            pl.BlockSpec((1, bkv), lambda i, qi, ki, h=h: (i // h, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dv), jnp.float32)],
        interpret=interpret,
    )(nlive, qf, kf, vf, maskp)
    return out.reshape(b, h, sqp, dv)[:, :, :sq]


def flash_attention_masked_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                               key_mask: jax.Array | None = None, *,
                               kv_len: jax.Array | int | None = None,
                               scale: float | None = None) -> jax.Array:
    """XLA lowering of ``flash_attention_masked`` (same shapes/semantics).

    On CPU hosts the Pallas interpreter is a correctness emulator, not a
    perf path (same policy as models/attention.py), so the "flash"
    attention backend lowers here instead. The kernel's block-skip shows
    up as **static packed skip**: a Python-int ``kv_len`` (the bucketed
    serving path — ladder sizes are static by construction) slices the
    dead KV tail away before any score FLOP is spent, the XLA analogue of
    ``pl.when`` skipping fully-pruned KV blocks — at sublane (8)
    granularity, since XLA has no MXU tile constraint. Scattered array
    masks keep the full key set under an additive bias — the same cost as
    the "xla" backend (the per-block skip win for those needs the real
    TPU kernel) — plus the kernel's exact-zero guard for batch rows whose
    every key is pruned.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if key_mask is not None and kv_len is not None:
        raise ValueError("give key_mask or kv_len, not both")
    if kv_len is not None and not hasattr(kv_len, "shape"):
        # static kept-count: drop the dead KV tail before the compute
        lim = min(skv, max(8, -(-int(kv_len) // 8) * 8))
        k, v = k[:, :, :lim], v[:, :, :lim]
        skv = lim
        key_mask = prefix_key_mask(int(kv_len), b, lim)
    elif kv_len is not None:
        key_mask = prefix_key_mask(kv_len, b, skv)
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    qf = q.astype(jnp.float32) * scale
    s = qf @ jnp.swapaxes(expand_kv_heads(k, h).astype(jnp.float32), -1, -2)
    if key_mask is not None:
        s = s + ((key_mask.astype(jnp.float32) - 1.0)
                 * -NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = p @ expand_kv_heads(v, h).astype(jnp.float32)
    if key_mask is not None:
        # batch rows with zero live keys output exactly 0 (kernel contract)
        o = o * (key_mask.sum(-1) > 0)[:, None, None, None]
    return o.astype(q.dtype)


def fused_masked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           key_mask: jax.Array | None = None, *,
                           kv_len: jax.Array | int | None = None,
                           scale: float | None = None,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = True) -> jax.Array:
    """The RoI-masked attention core, lowered for the host it runs on:
    the Pallas kernel when compiling for TPU (``interpret=False``), the
    XLA twin on CPU hosts. Both implement the identical contract
    (tests/test_differential.py pins them against each other)."""
    if interpret:
        return flash_attention_masked_xla(q, k, v, key_mask, kv_len=kv_len,
                                          scale=scale)
    return flash_attention_masked(q, k, v, key_mask, kv_len=kv_len,
                                  scale=scale, bq=bq, bkv=bkv,
                                  interpret=False)
