"""Fault tolerance: auto-restart resume, determinism, straggler flags."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import ImageStream, TokenStream
from repro.distributed.fault_tolerance import StragglerDetector, run_with_restarts


def test_run_with_restarts_resumes_from_checkpoint(tmp_path):
    """A fault at step 7 must restart from the step-5 checkpoint and end
    with the same final state as a fault-free run (state = pure function
    of step count)."""
    mgr = CheckpointManager(str(tmp_path), every=5, keep=3)
    faults = {"armed": True}

    def step_fn(state, step):
        if step == 7 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("injected preemption")
        return {"x": state["x"] + 1.0, "hist": state["hist"] + step}

    init = {"x": jnp.zeros(()), "hist": jnp.zeros(())}
    final, restarts = run_with_restarts(step_fn, init, 10, mgr)
    assert restarts == 1
    assert float(final["x"]) == 10.0
    assert float(final["hist"]) == sum(range(10))


def test_restart_gives_bit_identical_stream(tmp_path):
    """Data pipeline is (seed, step)-indexed: a resumed run consumes
    exactly the batches the lost run would have."""
    s1 = TokenStream(vocab=64, seq_len=8, global_batch=2, seed=3)
    s2 = TokenStream(vocab=64, seq_len=8, global_batch=2, seed=3)
    for step in (0, 5, 17):
        a = s1.batch_at(step)
        b = s2.batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
    img1 = ImageStream(img_size=32, global_batch=2, seed=1)
    img2 = ImageStream(img_size=32, global_batch=2, seed=1)
    np.testing.assert_array_equal(np.asarray(img1.batch_at(9)["images"]),
                                  np.asarray(img2.batch_at(9)["images"]))


def test_max_restarts_exceeded(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=100)

    def step_fn(state, step):
        raise RuntimeError("permafail")

    with pytest.raises(RuntimeError, match="permafail"):
        run_with_restarts(step_fn, {"x": jnp.zeros(())}, 5, mgr,
                          max_restarts=2)


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(k=5.0)
    for step in range(20):
        det.record(step, 0.10 + 0.001 * (step % 3))
    assert det.record(20, 0.5) is True       # 5x median
    assert det.record(21, 0.101) is False
    assert len(det.flags) == 1


def test_straggler_detector_warmup_quiet():
    det = StragglerDetector()
    for step in range(9):                     # < 10 samples: never flags
        assert det.record(step, 100.0 * (step + 1)) is False


def test_straggler_detector_history_is_bounded():
    """A long-lived server's watchdog records forever: the raw history
    must stay trimmed to ``window``, and the windowing must actually
    forget — a regime change ages out instead of skewing the median."""
    det = StragglerDetector(window=20)
    for step in range(10_000):
        det.record(step, 0.1)
    assert len(det._durations) == 20
    # after a slow-regime shift fills the window, the old fast samples
    # are gone: a 0.5s step is no longer an outlier
    for step in range(10_000, 10_040):
        det.record(step, 0.5)
    assert det.record(20_000, 0.5) is False


def test_straggler_detector_all_equal_durations():
    """MAD = 0 on perfectly uniform history: the epsilon floor keeps the
    detector from flagging equal (or infinitesimally slower) steps, while
    a genuine outlier still trips."""
    det = StragglerDetector(k=5.0)
    for step in range(30):
        det.record(step, 0.2)
    assert det.record(30, 0.2) is False
    assert det.record(31, 0.2 + 1e-7) is False   # below k * eps floor
    assert det.record(32, 2.0) is True
    assert len(det.flags) == 1


def test_straggler_detector_short_history_median():
    """Exactly at the 10-sample threshold the median/MAD come from the
    full (short) history — no off-by-one slicing surprises."""
    det = StragglerDetector(k=3.0, window=50)
    for step in range(10):
        det.record(step, 0.1 if step % 2 == 0 else 0.12)
    # 10 samples on record 11: stats live now
    assert det.record(10, 10.0) is True
    assert det.record(11, 0.11) is False
