"""Load-aware fleet front-end: N ``StreamServer`` workers behind a router.

One ``StreamServer`` multiplexes many camera sessions on one host; the
fleet question is the next scale step — given W hosts (or W device
groups on one host), which worker should own each incoming stream so
aggregate frames/s stays near W times one worker? ``FleetRouter``
answers with the control-plane pieces the serving stack already grew:

  * **placement** uses the PR-7 cost model's per-bucket prices: a job of
    ``n_frames`` costs ``n_frames * EncodeCostModel.per_frame_s`` at the
    bucket its operating point routes to, and the router places it on
    the worker with the least *predicted queued seconds* (greedy
    least-loaded; ``placement="rr"`` keeps blind round-robin as the
    baseline the bench gates against);
  * **rebalance()** migrates queued sessions off the hottest worker via
    the PR-9 ``export_session``/``adopt_session`` surfaces (remaining
    predictions are bitwise identical to staying put — micro-batches are
    session-pure);
  * **drain(i)** retires a worker via ``checkpoint``/``restore_checkpoint``
    into a fresh replacement, preserving every queued session.

Workers are in-process by default — they share one prepared int8 weight
cache (``prepare_params`` is idempotent, so only worker 0 pays MR
tuning) and serve sequentially, each on its own measured wall, so
aggregate fps is ``total_frames / max(worker walls)`` — the N-host
model where walls overlap. ``spawn=True`` runs each worker's serve in a
real ``multiprocessing`` spawn process instead (own JAX runtime, own
compiles — the honest multi-host cost model); migration and drain need
shared address space and raise under spawn.

Dead-bucket accounting warnings are aggregated here: workers serve with
per-session warnings muted and the router emits ONE ``UserWarning``
naming every (worker, dead buckets) pair — at fleet scale the
per-session warning degenerates into W x S copies of the same ladder
hint (serving/accounting.py grew ``summary(warn=False)`` for exactly
this caller).

CLI::

    PYTHONPATH=src python -m repro.serving.fleet --workers 4
    PYTHONPATH=src python -m repro.serving.fleet --workers 2 --spawn \
        --streams 6 --frames 32 --placement rr
"""

from __future__ import annotations

import argparse
import itertools
import tempfile
import time
import warnings
from dataclasses import dataclass

from repro.serving.server import ServerConfig, StreamServer

__all__ = ["FleetRouter", "FleetJob"]

# disjoint per-worker sid ranges: migrated sessions keep their sid, so a
# fleet-wide sid space is what makes adopt_session collision-free
_SID_STRIDE = 1_000_000


@dataclass
class FleetJob:
    """One stream the router owns: where it lives and what it still owes."""

    job_id: int
    stream: object                # VideoStream (or any frames_at source)
    n_frames: int
    start: int
    worker: int                   # current owner index
    sid: int                      # session id on that worker (fleet-unique)
    cost_s: float                 # predicted serve seconds (placement units)
    done: bool = False
    result: object = None         # StreamResult after serve()


class FleetRouter:
    """Place, serve, migrate and drain streams across N ``StreamServer``s.

    ``placement``: ``"cost"`` (least predicted queued seconds — the
    load-aware default) or ``"rr"`` (round-robin baseline).
    ``price_per_frame``: override the cost model's per-frame price (tests
    and non-photonic backends; the relative load math only needs a
    consistent unit). Without it the router prices frames with
    ``EncodeCostModel.from_server`` on worker 0 at the ladder bucket the
    configured operating point routes to, falling back to 1.0 s/frame if
    pricing fails (placement then balances raw frame counts).
    """

    def __init__(self, cfg, server_cfg: ServerConfig | None = None,
                 workers: int = 4, placement: str = "cost",
                 n_classes: int = 10, seed: int = 0, spawn: bool = False,
                 price_per_frame: float | None = None):
        if workers < 1:
            raise ValueError("need at least one worker")
        if placement not in ("cost", "rr"):
            raise ValueError(f"placement must be 'cost' or 'rr', "
                             f"got {placement!r}")
        self.cfg = cfg
        self.server_cfg = server_cfg or ServerConfig()
        self.placement = placement
        self.n_classes = n_classes
        self.seed = seed
        self.spawn = spawn
        self.workers: list[StreamServer] = []
        if not spawn:
            first = StreamServer(cfg, self.server_cfg,
                                 n_classes=n_classes, seed=seed)
            self.workers.append(first)
            for _ in range(workers - 1):
                # share the tuned cache: prepare_params is idempotent on
                # QuantizedWeight leaves, so only worker 0 pays MR tuning
                self.workers.append(StreamServer(
                    cfg, self.server_cfg, params=first.params,
                    n_classes=n_classes, seed=seed))
            for i, w in enumerate(self.workers):
                w._next_sid = i * _SID_STRIDE
        self.n_workers = workers
        self.jobs: dict[int, FleetJob] = {}
        self._next_job = 0
        self._rr = itertools.cycle(range(workers))
        self._price = price_per_frame
        self.last_walls: list[float] = []

    # -- pricing -----------------------------------------------------------

    def price_per_frame(self) -> float:
        """Predicted seconds one frame costs a worker — the placement
        unit. Cached after the first call (one bucket compile, worker 0)."""
        if self._price is None:
            self._price = self._price_from_cost_model()
        return self._price

    def _price_from_cost_model(self) -> float:
        if self.spawn or not self.workers:
            return 1.0
        try:
            from repro.serving.control.costmodel import EncodeCostModel
            w0 = self.workers[0]
            ladder = w0.ladder
            frac = self.server_cfg.force_bucket
            bucket = (ladder.route(int(round(frac * w0.n_patches)))
                      if frac else ladder.cap)
            cm = w0.cost_model or EncodeCostModel.from_server(
                w0, buckets=())
            return float(cm.ensure(int(bucket)).per_frame_s)
        except Exception as e:                       # pricing is advisory:
            warnings.warn(f"fleet pricing fell back to 1.0 s/frame "
                          f"(frame-count balancing): {e}")
            return 1.0

    # -- placement ---------------------------------------------------------

    def queued_seconds(self, worker: int) -> float:
        """Predicted seconds of unserved work on ``worker`` — the live
        queue-depth signal cost placement adds prices onto."""
        return sum(j.cost_s for j in self.jobs.values()
                   if j.worker == worker and not j.done)

    def queued_frames(self, worker: int) -> int:
        return sum(j.n_frames for j in self.jobs.values()
                   if j.worker == worker and not j.done)

    def _pick_worker(self, cost_s: float) -> int:
        if self.placement == "rr":
            return next(self._rr)
        loads = [self.queued_seconds(i) for i in range(self.n_workers)]
        return min(range(self.n_workers), key=lambda i: (loads[i], i))

    def add_job(self, stream, n_frames: int = 64, start: int = 0) -> FleetJob:
        """Place one stream: pick a worker, register the session there
        (in-process mode) and record the job. Returns the ``FleetJob``."""
        cost = n_frames * self.price_per_frame()
        widx = self._pick_worker(cost)
        if self.spawn:
            sid = self._next_job + _SID_STRIDE * widx   # assigned in-child
        else:
            s = self.workers[widx].add_session(stream, n_frames=n_frames,
                                               start=start)
            sid = s.sid
        job = FleetJob(self._next_job, stream, int(n_frames), int(start),
                       widx, sid, cost)
        self.jobs[job.job_id] = job
        self._next_job += 1
        return job

    # -- serving -----------------------------------------------------------

    def serve(self, verbose: bool = False) -> dict[int, object]:
        """Serve every queued job to completion; returns
        ``{job_id: StreamResult}``.

        In-process workers run sequentially, each timed on its own wall
        (``last_walls``); the fleet-model aggregate fps is
        ``total frames / max(wall)`` — W hosts would overlap those walls.
        Per-session dead-bucket warnings are muted; the router emits one
        aggregated warning instead.
        """
        if self.spawn:
            return self._serve_spawn(verbose)
        out: dict[int, object] = {}
        self.last_walls = [0.0] * self.n_workers
        for i, w in enumerate(self.workers):
            mine = [j for j in self.jobs.values()
                    if j.worker == i and not j.done]
            if not mine:
                continue
            t0 = time.time()
            results = w.serve(verbose=False)
            self.last_walls[i] = time.time() - t0
            by_sid = {j.sid: j for j in mine}
            for sid, res in results.items():
                j = by_sid.get(sid)
                if j is None:
                    continue       # e.g. adopted sessions served pre-drain
                j.done, j.result = True, res
                out[j.job_id] = res
                if verbose:
                    print(f"[fleet] worker {i} job {j.job_id}:",
                          res.summary())
        self._warn_dead_buckets(out)
        return out

    def _serve_spawn(self, verbose: bool) -> dict[int, object]:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        out: dict[int, object] = {}
        self.last_walls = [0.0] * self.n_workers
        procs = []
        for i in range(self.n_workers):
            mine = [j for j in self.jobs.values()
                    if j.worker == i and not j.done]
            if not mine:
                continue
            parent, child = ctx.Pipe(duplex=False)
            spec = [(j.job_id, j.stream, j.n_frames, j.start) for j in mine]
            p = ctx.Process(target=_spawn_serve,
                            args=(self.cfg, self.server_cfg, self.n_classes,
                                  self.seed + i, spec, child))
            p.start()
            procs.append((i, mine, p, parent))
        for i, mine, p, parent in procs:
            payload = parent.recv()
            p.join()
            if isinstance(payload, BaseException):
                raise RuntimeError(
                    f"spawned fleet worker {i} died") from payload
            wall, results = payload
            self.last_walls[i] = wall
            for job_id, res in results:
                self.jobs[job_id].done = True
                self.jobs[job_id].result = res
                out[job_id] = res
                if verbose:
                    print(f"[fleet] worker {i} job {job_id}:", res.summary())
        self._warn_dead_buckets(out)
        return out

    @property
    def aggregate_fps(self) -> float:
        """Fleet throughput of the last ``serve()``: total frames over the
        slowest worker's wall (walls overlap across hosts)."""
        frames = sum(j.result.frames for j in self.jobs.values()
                     if j.done and j.result is not None)
        wall = max(self.last_walls, default=0.0)
        return frames / wall if wall > 0 else 0.0

    def _warn_dead_buckets(self, results: dict) -> None:
        if self.spawn or not results:
            return
        dead_map = {}
        for i, w in enumerate(self.workers):
            hits: dict[int, int] = {}
            for j in self.jobs.values():
                if j.worker != i or j.result is None:
                    continue
                for k, v in j.result.bucket_hits.items():
                    hits[int(k)] = hits.get(int(k), 0) + int(v)
            if not hits:
                continue           # worker served nothing this round
            dead = [int(k) for k in w.ladder.sizes if not hits.get(int(k))]
            if dead:
                dead_map[i] = dead
        if dead_map:
            pairs = ", ".join(f"worker {i}: {d}"
                              for i, d in sorted(dead_map.items()))
            warnings.warn(
                f"fleet dead buckets ({pairs}): those ladder entries "
                f"constrain routing but served zero frames — consider "
                f"calibrate_trim() or a tighter bucket_fractions",
                stacklevel=2)

    # -- migration / drain -------------------------------------------------

    def _need_inprocess(self, what: str) -> None:
        if self.spawn:
            raise ValueError(f"{what} needs in-process workers "
                             f"(spawn processes share no session state)")

    def migrate(self, job_id: int, to_worker: int) -> FleetJob:
        """Move one queued job between workers via the PR-9 migration
        surfaces; its remaining predictions are unchanged."""
        self._need_inprocess("migrate")
        j = self.jobs[job_id]
        if j.done:
            raise ValueError(f"job {job_id} already served")
        if to_worker == j.worker:
            return j
        snap = self.workers[j.worker].export_session(j.sid)
        self.workers[to_worker].adopt_session(snap, stream=j.stream)
        j.worker = to_worker
        return j

    def rebalance(self, max_moves: int = 0) -> list[int]:
        """Greedy hot->cold migration until predicted queued seconds are
        balanced: repeatedly move the hottest worker's smallest job to the
        coldest worker while that strictly shrinks the hot-cold gap.
        Returns the moved job ids (empty when already balanced)."""
        self._need_inprocess("rebalance")
        moved: list[int] = []
        while not max_moves or len(moved) < max_moves:
            loads = [self.queued_seconds(i) for i in range(self.n_workers)]
            hot = max(range(self.n_workers), key=lambda i: loads[i])
            cold = min(range(self.n_workers), key=lambda i: loads[i])
            gap = loads[hot] - loads[cold]
            cands = [j for j in self.jobs.values()
                     if j.worker == hot and not j.done]
            # smallest job that still improves balance: moving cost c
            # changes the gap to |gap - 2c|, an improvement iff c < gap
            cands = [j for j in sorted(cands, key=lambda j: j.cost_s)
                     if j.cost_s < gap and abs(gap - 2 * j.cost_s) < gap]
            if not cands:
                break
            moved.append(self.migrate(cands[0].job_id, cold).job_id)
        return moved

    def drain(self, worker: int, root: str | None = None) -> StreamServer:
        """Retire worker ``worker``: checkpoint its queued sessions, build
        a fresh replacement server on the shared prepared cache and
        restore into it. Jobs keep their ids and sids; the replacement
        takes the dead worker's slot. Returns the replacement."""
        self._need_inprocess("drain")
        old = self.workers[worker]
        mine = [j for j in self.jobs.values()
                if j.worker == worker and not j.done]
        repl = StreamServer(self.cfg, self.server_cfg,
                            params=self.workers[0].params,
                            n_classes=self.n_classes, seed=self.seed)
        repl._next_sid = worker * _SID_STRIDE
        if mine:
            ckroot = root or tempfile.mkdtemp(prefix="fleet_drain_")
            path = old.checkpoint(root=ckroot)
            repl.restore_checkpoint(path,
                                    streams={j.sid: j.stream for j in mine})
        self.workers[worker] = repl
        return repl


def _spawn_serve(cfg, server_cfg, n_classes, seed, jobs, conn):
    """Top-level spawn target: build a worker, serve its jobs, ship
    ``(wall_s, [(job_id, StreamResult), ...])`` back over the pipe."""
    try:
        srv = StreamServer(cfg, server_cfg, n_classes=n_classes, seed=seed)
        sessions = {job_id: srv.add_session(st, n_frames=nf, start=s0)
                    for job_id, st, nf, s0 in jobs}
        t0 = time.time()
        results = srv.serve()
        wall = time.time() - t0
        conn.send((wall, [(job_id, results[s.sid])
                          for job_id, s in sessions.items()]))
    except BaseException as e:     # surface child failures to the router
        conn.send(e)
        raise


def main(argv=None):
    from repro.configs.opto_vit import get_config
    from repro.data.pipeline import video_fleet
    from repro.serving.session import ServingConfig

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--frames", type=int, default=32,
                    help="base frames/stream; streams get a skewed "
                         "1x..3x mix so load-aware placement matters")
    ap.add_argument("--placement", choices=("cost", "rr"), default="cost")
    ap.add_argument("--spawn", action="store_true",
                    help="one spawn process per worker (own JAX runtime)")
    ap.add_argument("--img", type=int, default=96)
    ap.add_argument("--backend", default="bf16",
                    help="matmul backend (bf16 default: CPU-fast demo)")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="per-worker model-axis shards (needs a forced "
                         "multi-device host; see README 'Scaling out')")
    args = ap.parse_args(argv)

    cfg = get_config("tiny", img_size=args.img, mgnet=True).with_(
        matmul_backend=args.backend)
    sc = ServerConfig.from_serving(
        ServingConfig(microbatch=4, chunk=8, force_bucket=0.5),
        warm_start=True, model_shards=args.model_shards)
    router = FleetRouter(cfg, sc, workers=args.workers,
                         placement=args.placement, spawn=args.spawn)
    fleet = video_fleet(args.streams, img_size=args.img, patch=16,
                        cut_every=32)
    for i, st in enumerate(fleet):
        nf = args.frames * (1 + (2 * i) % 3)      # skewed 1x/2x/3x mix
        j = router.add_job(st, n_frames=nf, start=8 * i)
        print(f"[fleet] job {j.job_id}: {nf} frames -> worker {j.worker} "
              f"(predicted {j.cost_s:.2f}s)")
    res = router.serve(verbose=True)
    walls = ", ".join(f"w{i}={t:.2f}s" for i, t in
                      enumerate(router.last_walls))
    print(f"[fleet] {len(res)} jobs, walls: {walls} -> "
          f"{router.aggregate_fps:.1f} frames/s aggregate "
          f"({args.placement} placement)")


if __name__ == "__main__":
    main()
