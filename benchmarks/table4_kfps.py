"""Paper Table IV: KFPS/W efficiency vs SiPh accelerators + GPU/FPGA.

Our number is computed from the calibrated cross-layer model (Tiny-96x96
reference workload, as the paper's headline). Competitor rows carry the
paper's reported figures (the paper itself reconstructed those designs in
its proprietary simulator; we report its table verbatim as the
comparison baseline and validate OUR number against the model)."""

from __future__ import annotations

from benchmarks.common import frame_report
from repro.core.energy import kfps_per_watt

SERVING_BACKENDS = ("photonic_sim", "photonic_pallas")

PAPER_TABLE = {          # KFPS/W as reported in Table IV
    "LightBulb [34]": 57.75,
    "HolyLight [33]": 3.3,
    "HQNNA [53]": 34.6,
    "Robin [26]": 46.5,
    "CrossLight [28]": 52.59,       # best case
    "Lightator [36]": 188.24,       # best case
    "Xilinx VCK190 (INT8)": 1.42,
    "NVIDIA A100 (INT8 TRT)": 0.86,
}


def _validate_serving_backends() -> None:
    """The KFPS/W headline models the photonic serving path; gate it on the
    two photonic execution backends (oracle + Pallas kernel) agreeing on a
    live forward with the quantize-once weight cache (core/backend.py)."""
    import jax
    import numpy as np

    from repro.configs.base import smoke_variant
    from repro.configs.opto_vit import get_config
    from repro.core.backend import prepare_params
    from repro.models.vit import forward_vit, init_vit

    cfg = smoke_variant(get_config("tiny", img_size=96))
    params = prepare_params(init_vit(jax.random.PRNGKey(0), cfg, n_classes=8))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                     cfg.img_size, 3))
    logits = [forward_vit(params, imgs, cfg.with_(matmul_backend=b))[0]
              for b in SERVING_BACKENDS]
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(logits[1]),
                               rtol=1e-5, atol=1e-5)
    print(f"  serving backends {SERVING_BACKENDS} agree "
          "(cached-weight forward)")


def run() -> list[dict]:
    print("\n== Table IV: KFPS/W comparison ==")
    _validate_serving_backends()
    rep = frame_report("tiny", 96)
    ours = kfps_per_watt(rep)
    rows = [{"design": "Opto-ViT (this work, model)", "kfps_w": ours}]
    print(f"  {'Opto-ViT (reproduced model)':<28} {ours:8.1f} KFPS/W "
          f"(paper: 100.4)")
    for k, v in PAPER_TABLE.items():
        rows.append({"design": k, "kfps_w": v})
        print(f"  {k:<28} {v:8.2f} KFPS/W "
              f"({ours / v:5.1f}x {'better' if ours > v else 'worse'})")
    assert abs(ours - 100.4) / 100.4 < 0.05, \
        f"calibration drifted: {ours} vs paper 100.4"
    # paper's ordering claims: beats everything except Lightator-best
    for k, v in PAPER_TABLE.items():
        if "Lightator" not in k:
            assert ours > v, (k, v)
    return rows
