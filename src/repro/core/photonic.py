"""Behavioural simulator of the Opto-ViT optical processing core.

Architecture (paper Fig. 3b / Fig. 4 / Fig. 6):

  * 32 VCSELs -> 32 WDM wavelength channels; input values are encoded in the
    light amplitude (one input chunk of 32 elements per cycle),
  * 64 waveguide arms; each arm holds a bank of 32 MRs tuned to one column
    chunk of the weight matrix (so a core holds a 32 x 64 weight tile),
  * one balanced photodetector (BPD) per arm accumulates the 32
    per-wavelength products -> 64 MACs per cycle,
  * chunk partial sums are accumulated electronically (adders in the
    electronic processing unit), outputs pass through ADCs (8-bit),
  * weights/inputs are 8-bit (MR resolution limit; see core/noise.py).

``photonic_matmul_sim`` walks a full (M, K) x (K, N) MatMul over this tile
grid exactly as Fig. 6's colour-coded schedule: K is chunked by 32
(wavelength channels), N by 64 (arms); every row of X is streamed over the
chunk grid. It is bit-faithful to w8a8 integer arithmetic and optionally
applies the MR crosstalk/FPV transmission error.

This module is the *oracle / reference*; the TPU-optimized implementation is
``kernels/photonic_matmul.py`` (Pallas, MXU-tiled) whose numerics must match
this simulator (tests/test_kernels_photonic.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.noise import MRConfig, transmission_error

__all__ = [
    "OpticalCoreConfig",
    "PhotonicOpStats",
    "analog_accumulate",
    "photonic_matmul_sim",
    "photonic_matmul_exact",
]


@dataclass(frozen=True)
class OpticalCoreConfig:
    """Geometry of one optical processing core + array-level parallelism."""

    n_wavelengths: int = 32       # K-chunk: inputs applied per cycle (VCSELs)
    n_arms: int = 64              # N-chunk: output columns per cycle (= d_k)
    n_cores: int = 5              # cores in the optical processing block
    bits: int = 8                 # MR/ADC/DAC resolution
    mr: MRConfig = field(default_factory=MRConfig)
    apply_noise: bool = False     # inject crosstalk/FPV transmission error
    fpv_sigma: float = 0.0
    adc_quantize_output: bool = False   # re-quantize the accumulated output
    #                                     to ``bits`` over its own range
    #                                     (models a range-limited ADC; off =
    #                                     ideal ADC, integer-exact readout)


@dataclass
class PhotonicOpStats:
    """Event counts for the energy/latency model (core/energy.py)."""

    mr_tunings: int = 0           # MR tuning events (weight loads)
    vcsel_cycles: int = 0         # VCSEL drive events (input chunk emissions)
    bpd_reads: int = 0            # BPD accumulation events
    adc_conversions: int = 0      # ADC conversions (outputs to digital)
    dac_conversions: int = 0      # DAC conversions (weight tuning + VCSEL drive)
    electronic_adds: int = 0      # partial-sum accumulations in the EPU
    sram_reads: int = 0
    sram_writes: int = 0
    cycles: int = 0               # optical core cycles (chunk steps)

    def __iadd__(self, other: "PhotonicOpStats") -> "PhotonicOpStats":
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self


def _pad_to(x: jnp.ndarray, multiple: int, axis: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def matmul_stats(m: int, k: int, n: int, cfg: OpticalCoreConfig) -> PhotonicOpStats:
    """Analytic event counts for an (M,K)x(K,N) MatMul on the optical block.

    Follows Fig. 6: the weight is split into ceil(K/32) x ceil(N/64) tiles;
    each tile is tuned once (32*64 MR tunings) and every row of X streams
    through it (one VCSEL cycle + 64 BPD reads per row per K-chunk).
    """
    kc = -(-k // cfg.n_wavelengths)       # ceil
    nc = -(-n // cfg.n_arms)
    arms = cfg.n_arms
    waves = cfg.n_wavelengths
    s = PhotonicOpStats()
    s.mr_tunings = kc * nc * arms * waves
    s.dac_conversions = s.mr_tunings + m * kc * waves   # tuning DACs + VCSEL DACs
    s.vcsel_cycles = m * kc * nc * waves
    s.bpd_reads = m * kc * nc * arms
    s.adc_conversions = m * nc * arms                    # one conversion per output elem
    s.electronic_adds = m * (kc - 1) * nc * arms if kc > 1 else 0
    s.sram_writes = m * nc * arms
    s.sram_reads = kc * nc * arms * waves + m * kc * waves
    # cycle count with n_cores-way tile parallelism across the optical block
    s.cycles = -(-(m * kc * nc) // cfg.n_cores)
    return s


def photonic_matmul_exact(x: jnp.ndarray, w: jnp.ndarray,
                          cfg: OpticalCoreConfig | None = None) -> jnp.ndarray:
    """w8a8 integer-exact photonic MatMul (no analog noise).

    Quantizes x (per-tensor) and w (per-output-channel) to ``cfg.bits``,
    performs integer MAC chunk-by-chunk as the optical core would, and
    dequantizes. This is the numerics contract the Pallas kernel must meet.
    """
    cfg = cfg or OpticalCoreConfig()
    sx = quant.absmax_scale(x, bits=cfg.bits)                       # scalar
    sw = quant.absmax_scale(w, bits=cfg.bits, axis=0)               # (1, N)
    xq = quant.quantize(x, sx, bits=cfg.bits).astype(jnp.int32)
    wq = quant.quantize(w, sw, bits=cfg.bits).astype(jnp.int32)
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw


def analog_accumulate(xq: jnp.ndarray, wqf: jnp.ndarray,
                      chunk: int = 32) -> jnp.ndarray:
    """Float-code chunk walk of the Fig. 6 schedule over perturbed weights.

    xq: (M, K) quantized activation codes, wqf: (K, N) *float* weight codes
    (integer codes times an analog transmission multiplier — sub-LSB noise
    cannot ride through the int8 kernel, so noisy execution walks the same
    K-chunk schedule on floats). Shared by ``photonic_matmul_sim``'s noisy
    branch and the noisy backend/kernel dispatch.
    """
    m = xq.shape[0]
    n = wqf.shape[1]
    xqf = _pad_to(xq.astype(jnp.float32), chunk, axis=1)
    wqf = _pad_to(wqf.astype(jnp.float32), chunk, axis=0)
    n_kchunks = xqf.shape[1] // chunk

    # (n_kchunks, M, chunk) input chunks; (n_kchunks, chunk, N) weight tiles.
    x_chunks = xqf.reshape(m, n_kchunks, chunk).transpose(1, 0, 2)
    w_chunks = wqf.reshape(n_kchunks, chunk, n)

    def step(acc, xw):
        xc, wc = xw
        # One optical cycle per (row, K-chunk): the 32 products per arm
        # are summed *optically* by the BPD; arms give all N tile cols.
        acc = acc + xc @ wc
        return acc, None

    acc, _ = jax.lax.scan(step, jnp.zeros((m, n), jnp.float32),
                          (x_chunks, w_chunks))
    return acc


def photonic_matmul_sim(x: jnp.ndarray, w: jnp.ndarray,
                        cfg: OpticalCoreConfig | None = None,
                        noise_key: jax.Array | None = None,
                        drift_nm=None,
                        wander_sigma_nm: float = 0.0) -> jnp.ndarray:
    """Tile-walking simulator of the optical core (Fig. 6 schedule).

    x: (M, K) activations, w: (K, N) weights, returns (M, N) float32.

    The walk is express as a scan over K-chunks of 32 (wavelength dimension)
    with all N-chunks of 64 (arms) evaluated in parallel per step — exactly
    the chunk-accumulate order of the paper. With ``cfg.apply_noise`` the MR
    transmission error (crosstalk floor + FPV, plus Lorentzian drift/wander
    when ``drift_nm`` is given) multiplies the tuned weights; ``noise_key``
    is then REQUIRED. The historical silent ``PRNGKey(0)`` fallback froze
    the error pattern across every call — "drift" that never drifted — so a
    missing key is now an error. Serving derives per-call keys from a
    ``DriftState`` (core/noise.py) frame counter.
    """
    cfg = cfg or OpticalCoreConfig()
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    sx = quant.absmax_scale(x, bits=cfg.bits)
    sw = quant.absmax_scale(w, bits=cfg.bits, axis=0)
    xq = quant.quantize(x, sx, bits=cfg.bits)
    wq = quant.quantize(w, sw, bits=cfg.bits)

    if cfg.apply_noise:
        # Transmission error perturbs the *tuned weight* (the MR bank) —
        # an analog effect, so this walk runs on float-valued codes. The
        # noise-free walk below shares the integer chunk schedule with the
        # photonic_sim backend (core/backend.py).
        if noise_key is None:
            raise ValueError(
                "photonic_matmul_sim(apply_noise=True) requires an explicit "
                "noise_key: pass one derived from a DriftState/frame counter "
                "(repro.core.noise) so successive calls draw fresh error "
                "patterns. The old implicit PRNGKey(0) default made every "
                "noisy call observe one frozen pattern.")
        wqf = wq.astype(jnp.float32) * transmission_error(
            noise_key, wq.shape, cfg.mr, cfg.fpv_sigma,
            drift_nm=drift_nm, wander_sigma_nm=wander_sigma_nm)
        acc = analog_accumulate(xq, wqf, chunk=cfg.n_wavelengths)
    else:
        from repro.core.backend import int_accumulate_sim
        acc = int_accumulate_sim(xq, wq,
                                 chunk=cfg.n_wavelengths).astype(jnp.float32)

    # Dequant epilogue: rescale the integer accumulate back to the float
    # range. By default the ADC is modelled as ideal (the chunk partials are
    # summed digitally after conversion, so the w8a8 accumulate is read out
    # integer-exact — this is what keeps the sim bit-faithful to
    # photonic_matmul_exact). With ``adc_quantize_output`` the readout is
    # instead re-quantized to ``cfg.bits`` over the output's own dynamic
    # range, modelling a range-limited ADC on the analog accumulate.
    out = acc * sx * sw
    if cfg.adc_quantize_output:
        s_out = quant.absmax_scale(out, bits=cfg.bits)
        out = quant.dequantize(quant.quantize(out, s_out, bits=cfg.bits),
                               s_out)
    return out
