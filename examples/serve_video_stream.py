"""Streaming video serving demo: the paper's near-sensor deployment loop.

A synthetic camera stream (moving object, periodic scene cuts) flows through
the serving engine's full pipeline —

    ingest (double-buffered) -> MGNet RoI gate (temporal mask reuse)
    -> token-budget bucket routing -> micro-batched top-k encode
    -> per-flush energy accounting

— and the run reports live frames/s, the accelerator model's KFPS/W
(paper Table IV metric), the bucket-hit histogram and how rarely MGNet
actually had to run (static scenes reuse the cached mask; cuts re-score).

    PYTHONPATH=src python examples/serve_video_stream.py \\
        --frames 128 --backend photonic_sim
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.backend import available_backends
from repro.data.pipeline import VideoStream
from repro.serving.engine import ServingConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=128)
    ap.add_argument("--backend", default="photonic_sim",
                    help=f"matmul backend: {', '.join(available_backends())}")
    ap.add_argument("--attn-backend", default="",
                    choices=["", "xla", "flash"],
                    help="attention core: xla (default) or the fused "
                         "RoI-masked flash dataflow")
    ap.add_argument("--mask-refresh", type=int, default=16)
    ap.add_argument("--cut-every", type=int, default=48)
    args = ap.parse_args()
    if args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")

    cfg = smoke_variant(get_config("tiny")).with_(
        mgnet=True, mgnet_embed=32, mgnet_heads=2,
        matmul_backend=args.backend, attn_backend=args.attn_backend)
    serve_cfg = ServingConfig(bucket_fractions=(0.25, 0.5, 0.75, 1.0),
                              microbatch=4, chunk=8,
                              mask_refresh=args.mask_refresh)
    engine = ServingEngine(cfg, serve_cfg, n_classes=8)
    print(f"[video] backend={engine.policy.resolve_backend()} "
          f"ladder={list(engine.ladder.sizes)} of {engine.n_patches} patches, "
          f"mask refresh every {args.mask_refresh} frames or on scene change")

    stream = VideoStream(img_size=cfg.img_size, patch=cfg.patch,
                         cut_every=args.cut_every)
    res = engine.run(stream, n_frames=args.frames, verbose=True)
    print("[video]", res.summary())
    print(f"[video] MGNet ran on {res.scored_frames} of {res.frames} frames "
          f"({1 - res.scored_frames / res.frames:.0%} mask reuse) — "
          "static scenes make the RoI gate nearly free")


if __name__ == "__main__":
    main()
