"""data substrate."""
