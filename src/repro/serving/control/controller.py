"""Online serving controller: calibrate the cost model, then close the loop.

Two phases, both driven from ``StreamServer``'s scheduling loop:

**Calibration.** The cost model's predicted per-flush seconds are TPU-class
roofline numbers; the host executing the functional simulation is not that
machine. What *does* transfer is the ranking and the rough linearity of
"more FLOPs/bytes -> more wall time", so the controller fits

    observed_s  ~=  a * predicted_s + b

over per-bucket *medians* of the telemetry window (medians, because the
first flush of any lazily-compiled bucket is a compile-time outlier and a
mean would drag the fit toward it; a configurable ``burn_in`` additionally
drops each bucket's leading observations). Buckets with at least
``min_samples`` observations get a further per-bucket multiplicative
correction on top of the global fit. ``median_rel_error`` scores the fit
on *held-out* observations — only flushes recorded after the fit was cut —
so the acceptance number is honest, not training error.

**Re-tuning.** Every ``retune_every`` frames the controller recommends new
values for the re-timing knobs — ``max_wait_chunks`` (deadline pad-flush),
``interleave_depth`` (ready-flush launches per session per round) and a
per-bucket ``flush_threshold`` (pad-flush a queue that reached this many
rows without waiting for the deadline) — from the fitted per-flush cost
plus live queue depths. Three guard rails make a mispredicting model
strictly safe:

  * **hysteresis** — a recommendation is applied only after it has been
    produced ``hysteresis`` times in a row; a flapping signal changes
    nothing;
  * **clamp** — every applied knob is clamped into a static bound box
    around the defaults (``max_wait_bound``, ``interleave_bound``,
    ``min_flush_fraction``); ``clamp_violations`` counts any applied knob
    found outside the box, and CI asserts it stays 0;
  * **fps watchdog** — the first ``step`` pins the fps observed under the
    default knobs as the baseline; if windowed fps later drops below
    ``(1 - safety_margin) x`` that baseline while tuned knobs are live,
    the controller reverts to the defaults and freezes. The tuned server
    can therefore never do persistently worse than the static defaults.

The controller deliberately never re-routes frames or trims the ladder
online: routing changes alter which encode shape a frame hits and would
break the per-stream bitwise-reproducibility contract mid-stream. Ladder
trimming happens once, before serving, in ``autotune_prepare`` (and only
when provably route-invariant).
"""

from __future__ import annotations

import math
import statistics
from dataclasses import dataclass, field

from repro.serving.control.costmodel import EncodeCostModel
from repro.serving.control.telemetry import FlushTelemetry

__all__ = ["ControllerConfig", "TunedKnobs", "Controller"]


@dataclass(frozen=True)
class ControllerConfig:
    """Guard-rail and cadence knobs of the controller itself."""

    retune_every: int = 32        # frames between step() evaluations
    hysteresis: int = 2           # identical consecutive recommendations
    #                               required before one is applied
    min_samples: int = 4          # per-bucket obs before a bucket-specific
    #                               fit correction is trusted
    burn_in: int = 1              # leading obs per bucket dropped from the
    #                               fit (first flush = compile outlier)
    max_wait_bound: int = 8       # clamp: 0 <= max_wait_chunks <= bound
    interleave_bound: int = 4     # clamp: 1 <= interleave_depth <= bound
    min_flush_fraction: float = 0.5   # clamp: flush_threshold >= this
    #                                   fraction of the micro-batch
    safety_margin: float = 0.25   # watchdog: revert + freeze when fps <
    #                               (1 - margin) * default-knob baseline


@dataclass
class TunedKnobs:
    """The mutable knob set the serving loop reads every round."""

    max_wait_chunks: int = 0
    interleave_depth: int = 1
    flush_threshold: dict = field(default_factory=dict)  # bucket -> rows

    def key(self) -> tuple:
        """Hashable identity for hysteresis comparison."""
        return (self.max_wait_chunks, self.interleave_depth,
                tuple(sorted(self.flush_threshold.items())))

    def copy(self) -> "TunedKnobs":
        return TunedKnobs(self.max_wait_chunks, self.interleave_depth,
                          dict(self.flush_threshold))

    def set_to(self, other: "TunedKnobs") -> None:
        """In-place adoption — the serving loop holds a reference to this
        object, so knob changes must mutate, never rebind."""
        self.max_wait_chunks = other.max_wait_chunks
        self.interleave_depth = other.interleave_depth
        self.flush_threshold = dict(other.flush_threshold)


class Controller:
    """Calibrating, self-clamping knob tuner for one ``StreamServer``."""

    def __init__(self, cost_model: EncodeCostModel,
                 telemetry: FlushTelemetry, defaults: TunedKnobs,
                 cc: ControllerConfig | None = None):
        self.cost_model = cost_model
        self.telemetry = telemetry
        self.cc = cc or ControllerConfig()
        self.defaults = defaults.copy()
        self.knobs = defaults.copy()       # the live object the loop reads
        self.clamp_violations = 0          # applied knobs outside the box
        self.clamp_engaged = 0             # recommendations the clamp fixed
        self.frozen = False                # watchdog tripped: defaults, hold
        self.applied_retunes = 0
        self._fit: tuple[float, float] | None = None   # (a, b)
        self._fit_seq = 0                  # telemetry seq at fit time
        self._bucket_scale: dict[int, float] = {}
        self._pending_key: tuple | None = None
        self._pending: TunedKnobs | None = None
        self._pending_count = 0
        self._stable_steps = 0             # consecutive steps rec == live
        self._ever_stable = False          # reached a fixed point at least
        #                                    once (late signal drift — e.g.
        #                                    end-of-stream drain partials —
        #                                    does not un-converge a
        #                                    controller that settled)
        self._baseline_fps: float | None = None
        self._win_frames = 0
        self._win_t = 0.0
        self._backlog_ema = 0.0

    # -- ingest ------------------------------------------------------------

    def record_flush(self, bucket: int, n_real: int, n_streams: int,
                     wall_s: float, rnd: int = 0) -> None:
        self.telemetry.record(bucket, n_real, self.cost_model.microbatch,
                              n_streams, wall_s, rnd)

    # -- calibration -------------------------------------------------------

    def _bucket_medians(self) -> dict[int, tuple[float, int]]:
        """bucket -> (median observed seconds, sample count), burn-in
        dropped per bucket."""
        out = {}
        for k, obs in self.telemetry.by_bucket().items():
            lat = [o.wall_s for o in obs[self.cc.burn_in:]]
            if lat:
                out[k] = (statistics.median(lat), len(lat))
        return out

    def calibrate(self) -> bool:
        """Fit observed = a * predicted + b over per-bucket medians
        (count-weighted); single-bucket telemetry fits through the origin.
        Buckets with >= ``min_samples`` get a multiplicative residual
        correction. Returns True when a fit was (re)cut."""
        meds = self._bucket_medians()
        pts = [(self.cost_model.predicted_flush_s(k), m, n)
               for k, (m, n) in meds.items() if k in self.cost_model.costs
               or k in self.cost_model._builders]
        pts = [(p, m, n) for p, m, n in pts if p > 0]
        if not pts:
            return False
        if len(pts) == 1:
            a, b = pts[0][1] / pts[0][0], 0.0
        else:
            w = sum(n for _, _, n in pts)
            mx = sum(p * n for p, _, n in pts) / w
            my = sum(m * n for _, m, n in pts) / w
            sxx = sum(n * (p - mx) ** 2 for p, _, n in pts)
            sxy = sum(n * (p - mx) * (m - my) for p, m, n in pts)
            if sxx <= 0:
                a, b = my / mx if mx > 0 else 1.0, 0.0
            else:
                a = sxy / sxx
                b = my - a * mx
                if a <= 0:        # degenerate (noise-dominated): fall back
                    a, b = my / mx if mx > 0 else 1.0, 0.0
        self._fit = (a, b)
        self._fit_seq = self.telemetry.seq
        self._bucket_scale = {}
        for k, (m, n) in meds.items():
            if n >= self.cc.min_samples:
                base = a * self.cost_model.predicted_flush_s(k) + b
                if base > 0:
                    self._bucket_scale[k] = m / base
        return True

    @property
    def calibrated(self) -> bool:
        return self._fit is not None

    def predict_flush_s(self, bucket: int) -> float:
        """Calibrated wall-seconds prediction for one flush of ``bucket``
        (raw roofline seconds before any fit exists)."""
        raw = self.cost_model.predicted_flush_s(bucket)
        if self._fit is None:
            return raw
        a, b = self._fit
        return max((a * raw + b), 0.0) * self._bucket_scale.get(bucket, 1.0)

    def median_rel_error(self, holdout: bool = True) -> float | None:
        """Median |predicted - observed| / observed over flushes recorded
        *after* the current fit (``holdout=False``: the whole window).
        None without a fit or matching observations."""
        if self._fit is None:
            return None
        min_seq = self._fit_seq if holdout else 0
        errs = []
        for o in self.telemetry:
            if o.seq < min_seq or o.wall_s <= 0:
                continue
            errs.append(abs(self.predict_flush_s(o.bucket) - o.wall_s)
                        / o.wall_s)
        return statistics.median(errs) if errs else None

    # -- re-tuning ---------------------------------------------------------

    def _clamp(self, rec: TunedKnobs) -> TunedKnobs:
        """Force a recommendation into the safety box; counts engagements."""
        cc, mb = self.cc, self.cost_model.microbatch
        out = rec.copy()
        engaged = False
        if not 0 <= out.max_wait_chunks <= cc.max_wait_bound:
            out.max_wait_chunks = min(max(out.max_wait_chunks, 0),
                                      cc.max_wait_bound)
            engaged = True
        if not 1 <= out.interleave_depth <= cc.interleave_bound:
            out.interleave_depth = min(max(out.interleave_depth, 1),
                                       cc.interleave_bound)
            engaged = True
        floor = max(1, math.ceil(cc.min_flush_fraction * mb))
        for k, thr in list(out.flush_threshold.items()):
            if not floor <= thr <= mb:
                out.flush_threshold[k] = min(max(thr, floor), mb)
                engaged = True
        if engaged:
            self.clamp_engaged += 1
        return out

    def _in_bounds(self, kn: TunedKnobs) -> bool:
        cc, mb = self.cc, self.cost_model.microbatch
        floor = max(1, math.ceil(cc.min_flush_fraction * mb))
        return (0 <= kn.max_wait_chunks <= cc.max_wait_bound
                and 1 <= kn.interleave_depth <= cc.interleave_bound
                and all(floor <= t <= mb
                        for t in kn.flush_threshold.values()))

    def _recommend(self, queue_stats: dict) -> TunedKnobs:
        """Knob recommendation from the fitted model + live queue depths.

        The shape of the policy: when flushes are *cheap* relative to how
        long partial queues sit (low observed occupancy), waiting for a
        full micro-batch buys little — pull the pad-flush deadline in and
        let chronically partial buckets flush at their observed fill. When
        queues fill naturally (occupancy ~1), leave the defaults alone.
        Interleave depth follows the ready backlog: more queued rows than
        one launch per session per round can drain -> go deeper.
        """
        cc, mb = self.cc, self.cost_model.microbatch
        rec = self.defaults.copy()
        # occupancies are quantized to one decimal so the recommendation
        # reaches a fixed point as the windowed estimate converges,
        # instead of flapping on every new observation (hysteresis then
        # has something stable to latch onto)
        occ = round(self.telemetry.occupancy(), 1)
        if occ <= 0:
            return rec
        if occ < 0.95:
            # rounds to fill ~= mb / rows-arriving-per-round; observed
            # occupancy is the fill a queue reaches before being flushed,
            # so ~2x that in rounds is a deadline that lets organic fills
            # finish but stops long waits
            rec.max_wait_chunks = max(1, min(cc.max_wait_bound,
                                             round(2 * occ * mb)))
            for k in self.cost_model.costs:
                bocc = round(self.telemetry.occupancy(k), 1)
                if 0 < bocc < 0.95:
                    thr = max(math.ceil(cc.min_flush_fraction * mb),
                              math.ceil(bocc * mb))
                    if thr < mb:
                        rec.flush_threshold[k] = thr
        # interleave depth follows the *smoothed* ready backlog (EMA, fed
        # in step()): deepen when it exceeds 2 micro-batches per stream,
        # otherwise hold whatever depth is live. The knob ratchets within
        # a run — dropping back when the backlog drains buys nothing
        # (interleaving an empty backlog is free) and would only flap the
        # recommendation out of its fixed point every time ingest pauses
        n_streams = max(1, round(self.telemetry.mean_streams()))
        if self._backlog_ema > 2 * mb * n_streams:
            rec.interleave_depth = min(cc.interleave_bound,
                                       max(2, self.knobs.interleave_depth))
        else:
            rec.interleave_depth = self.knobs.interleave_depth
        return self._clamp(rec)

    def step(self, queue_stats: dict, frames_done: int,
             elapsed_s: float) -> bool:
        """One control evaluation (the server calls this every
        ``retune_every`` frames). Returns True when knobs changed."""
        # windowed fps since the previous step
        dt = elapsed_s - self._win_t
        df = frames_done - self._win_frames
        fps = df / dt if dt > 0 else 0.0
        self._win_t, self._win_frames = elapsed_s, frames_done
        if self.frozen:
            return False
        if self._baseline_fps is None:
            # first step runs under the default knobs: this window IS the
            # static-default performance the watchdog protects
            if fps > 0:
                self._baseline_fps = fps
        elif (self.knobs.key() != self.defaults.key() and fps > 0
                and fps < (1.0 - self.cc.safety_margin) * self._baseline_fps):
            self.knobs.set_to(self.defaults)
            self.frozen = True
            return True
        if not self.calibrated or self.telemetry.seq > self._fit_seq:
            self.calibrate()
        backlog = sum(rows for rows, _ in queue_stats.values())
        self._backlog_ema = 0.7 * self._backlog_ema + 0.3 * backlog
        rec = self._recommend(queue_stats)
        if rec.key() == self.knobs.key():
            self._pending_key, self._pending_count = None, 0
            self._stable_steps += 1
            self._ever_stable = True
            return False
        self._stable_steps = 0
        if rec.key() == self._pending_key:
            self._pending_count += 1
        else:
            self._pending_key, self._pending = rec.key(), rec
            self._pending_count = 1
        if self._pending_count >= self.cc.hysteresis:
            self.knobs.set_to(self._pending)
            self._pending_key, self._pending_count = None, 0
            self.applied_retunes += 1
            # the latest recommendation is now live — that IS the fixed
            # point until the signal moves again
            self._stable_steps = 1
            self._ever_stable = True
            if not self._in_bounds(self.knobs):
                # should be unreachable (_clamp runs on every rec); counted
                # so CI can assert the invariant held
                self.clamp_violations += 1
                self.knobs.set_to(self._clamp(self.knobs))
            return True
        return False

    @property
    def converged(self) -> bool:
        """Calibrated, never watchdog-frozen, and the knob state reached a
        fixed point at least once (a recommendation matched the live
        knobs, or an applied retune made them match). Late signal drift —
        the draining tail of a finite run — does not revoke convergence;
        a watchdog freeze does."""
        return self.calibrated and not self.frozen and self._ever_stable

    def report(self) -> str:
        fit = (f"obs = {self._fit[0]:.3g} * pred + {self._fit[1]:.3g}"
               if self._fit else "uncalibrated")
        err = self.median_rel_error()
        which = "holdout"
        if err is None:            # fit cut on the newest obs: no holdout
            err, which = self.median_rel_error(holdout=False), "in-window"
        err_s = f"{err:.1%}" if err is not None else "n/a"
        kn = self.knobs
        return (f"controller: {fit} | {which} medrelerr {err_s} | "
                f"knobs max_wait={kn.max_wait_chunks} "
                f"depth={kn.interleave_depth} "
                f"thresholds={dict(sorted(kn.flush_threshold.items()))} | "
                f"{self.applied_retunes} retunes, "
                f"{self.clamp_engaged} clamped, "
                f"{self.clamp_violations} violations"
                f"{' [FROZEN: watchdog]' if self.frozen else ''}"
                f"{' [converged]' if self.converged else ''}")
