"""Synthetic sharded data pipelines with deterministic, resumable streams.

Every batch is a pure function of (seed, step) — restart-safe by
construction: after a preemption the pipeline resumes at the checkpointed
step with bit-identical data (fault-tolerance requirement, DESIGN.md §4).

On a multi-host deployment each host generates only its addressable shard
(``jax.make_array_from_callback``); on this single-process host that
degenerates to a device_put with the right NamedSharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingCtx, named_sharding

__all__ = ["TokenStream", "ImageStream", "FrameStream", "VideoStream",
           "video_fleet", "prefetch_to_device", "lm_batch_specs"]


def _host_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


@dataclass
class TokenStream:
    """Synthetic LM batches: {"tokens": (B, S) i32, "labels": (B, S) i32}.

    Markov-ish synthetic text (mixture of n-gram repeats) so that loss
    actually decreases during the example training runs.
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ctx: ShardingCtx | None = None

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        b, s = self.global_batch, self.seq_len
        # repeatable structure: random walk over a small state machine
        base = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int32)
        steps = rng.integers(1, 7, size=(b, s), dtype=np.int32)
        toks = (base + np.cumsum(steps, axis=1)) % self.vocab
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        batch = {"tokens": tokens, "labels": labels}
        return self._put(batch)

    def _put(self, batch: dict) -> dict:
        if self.ctx is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            sh = named_sharding(v.shape, ("batch", "seq"), self.ctx)
            out[k] = jax.device_put(v, sh)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class ImageStream:
    """Synthetic image-classification batches with planted RoI structure:
    one bright object box on a dark background; the label is a function of
    the box quadrant + texture — so MGNet has real signal to learn."""

    img_size: int
    global_batch: int
    n_classes: int = 10
    patch: int = 16
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        b, h = self.global_batch, self.img_size
        imgs = rng.normal(0.0, 0.1, size=(b, h, h, 3)).astype(np.float32)
        g = h // self.patch
        patch_mask = np.zeros((b, g * g), np.float32)
        labels = np.zeros((b,), np.int32)
        for i in range(b):
            bw = rng.integers(h // 4, h // 2)
            bh = rng.integers(h // 4, h // 2)
            y0 = rng.integers(0, h - bh)
            x0 = rng.integers(0, h - bw)
            tex = rng.integers(0, 5)
            imgs[i, y0:y0 + bh, x0:x0 + bw] += 1.0 + 0.2 * tex
            quad = (2 * ((y0 + bh / 2) > h / 2) + ((x0 + bw / 2) > h / 2))
            labels[i] = int(quad) * 5 // 2 + tex % 5 if False else int(quad * 2 + tex % 2)
            # ground-truth patch mask from the box (paper: 1 if any overlap)
            py0, py1 = y0 // self.patch, (y0 + bh - 1) // self.patch
            px0, px1 = x0 // self.patch, (x0 + bw - 1) // self.patch
            m2 = np.zeros((g, g), np.float32)
            m2[py0:py1 + 1, px0:px1 + 1] = 1.0
            patch_mask[i] = m2.reshape(-1)
        return {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels),
                "patch_mask": jnp.asarray(patch_mask)}


@dataclass
class FrameStream:
    """Synthetic precomputed frontend embeddings (whisper/vlm stubs)."""

    n_frames: int
    dim: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = _host_rng(self.seed, step)
        x = rng.normal(size=(self.global_batch, self.n_frames, self.dim))
        return {"frames": jnp.asarray(x.astype(np.float32))}


@dataclass
class VideoStream:
    """Temporally-coherent synthetic video: one bright object drifting over
    a dark background, with a hard scene cut (new object, new trajectory)
    every ``cut_every`` frames.

    This is the near-sensor serving workload: consecutive frames are highly
    correlated (MGNet's RoI mask can be *reused*), while cuts force a
    re-score — exactly the two regimes the serving engine's temporal mask
    cache must handle. Every frame is a pure function of (seed, frame_idx):
    the scene segment ``idx // cut_every`` determines object/trajectory, the
    in-segment offset moves the box, so the stream is deterministic and
    resumable like every other pipeline here.

    ``frames_at(start, count)`` returns a chunk of ``count`` consecutive
    frames {"frames": (count, H, W, 3), "patch_mask": (count, N),
    "frame_idx": (count,)} — patch_mask is the box-derived ground truth
    (serving uses MGNet's predictions; tests use this). Chunks are *host*
    numpy arrays: the serving engine's gating walk is host-side by design,
    so the sensor hands off host memory and the consumer decides what (and
    when) to ship to the device — see ``prefetch_to_device``.
    """

    img_size: int
    patch: int = 16
    seed: int = 0
    cut_every: int = 32
    noise: float = 0.05
    speed: float = 1.5          # pixels / frame box drift

    def _segment(self, seg: int):
        rng = _host_rng(self.seed, seg)
        h = self.img_size
        bw = int(rng.integers(h // 4, h // 2))
        bh = int(rng.integers(h // 4, h // 2))
        y0 = float(rng.integers(0, h - bh))
        x0 = float(rng.integers(0, h - bw))
        ang = float(rng.uniform(0, 2 * np.pi))
        vy, vx = self.speed * np.sin(ang), self.speed * np.cos(ang)
        tex = float(rng.integers(0, 5))
        return bw, bh, y0, x0, vy, vx, tex

    def frame_at(self, idx: int) -> tuple[np.ndarray, np.ndarray]:
        """(frame (H, W, 3) f32, gt patch mask (N,) f32) for one frame."""
        h, p = self.img_size, self.patch
        g = h // p
        seg, off = divmod(idx, self.cut_every)
        bw, bh, y0, x0, vy, vx, tex = self._segment(seg)
        # drift with reflection off the borders (box stays in frame)
        span_y, span_x = max(h - bh, 1), max(h - bw, 1)
        y = int(abs((y0 + vy * off + span_y) % (2 * span_y) - span_y))
        x = int(abs((x0 + vx * off + span_x) % (2 * span_x) - span_x))
        rng = _host_rng(self.seed, idx + (1 << 20))   # per-frame sensor noise
        img = rng.normal(0.0, self.noise, size=(h, h, 3)).astype(np.float32)
        img[y:y + bh, x:x + bw] += 1.0 + 0.2 * tex
        mask2 = np.zeros((g, g), np.float32)
        mask2[y // p:(y + bh - 1) // p + 1, x // p:(x + bw - 1) // p + 1] = 1.0
        return img, mask2.reshape(-1)

    def frames_at(self, start: int, count: int) -> dict:
        frames = np.empty((count, self.img_size, self.img_size, 3), np.float32)
        g = self.img_size // self.patch
        masks = np.empty((count, g * g), np.float32)
        for i in range(count):
            frames[i], masks[i] = self.frame_at(start + i)
        return {"frames": frames, "patch_mask": masks,
                "frame_idx": np.arange(start, start + count, dtype=np.int32)}

    def chunks(self, chunk: int, start: int = 0) -> Iterator[dict]:
        while True:
            yield self.frames_at(start, chunk)
            start += chunk


def video_fleet(n_streams: int, img_size: int, patch: int = 16,
                seed: int = 0, cut_every: int = 32, noise: float = 0.05,
                speed: float = 1.5) -> list[VideoStream]:
    """``n_streams`` independent synthetic cameras for multi-stream serving.

    Stream i draws its scenes from ``seed + i`` (disjoint object
    trajectories, uncorrelated cuts), so a fleet models genuinely
    different sensors — not N copies of one feed. Each stream stays a pure
    function of (its seed, frame_idx): any fleet member is bit-identically
    re-servable solo, which is what the interleaved-vs-sequential parity
    contract in tests/test_multistream.py leans on. Phase-offset serving
    (stream i starting at frame ``i * phase``) is expressed through the
    session's ``start``, not here — the same stream object serves any
    window of itself.
    """
    if n_streams < 1:
        raise ValueError("n_streams must be >= 1")
    return [VideoStream(img_size=img_size, patch=patch, seed=seed + i,
                        cut_every=cut_every, noise=noise, speed=speed)
            for i in range(n_streams)]


def prefetch_to_device(it: Iterator[dict], depth: int = 2,
                       keys: tuple[str, ...] | None = None) -> Iterator[dict]:
    """Double-buffered host->device ingest: keep ``depth`` batches in flight.

    Expects *host* (numpy) batches. ``device_put`` is async, so the H2D
    copy of batch t+1 is already in flight while the consumer computes on
    batch t — the software analogue of the sensor double buffer. The
    yielded order is unchanged. With ``keys``, only those entries are
    shipped and the host array is kept alongside as ``<key>_host`` —
    consumers that walk the data on host (the serving RoI gate) read the
    host view without a device round-trip, device compute reads the
    transferred one.
    """
    def put(item: dict) -> dict:
        if keys is None:
            return {k: jax.device_put(v) for k, v in item.items()}
        out = dict(item)
        for k in keys:
            out[k + "_host"] = item[k]
            out[k] = jax.device_put(item[k])
        return out

    buf: list[dict] = []
    for item in it:
        buf.append(put(item))
        if len(buf) >= depth:
            yield buf.pop(0)
    while buf:
        yield buf.pop(0)


def quadrant_labels(patch_mask: jnp.ndarray) -> jnp.ndarray:
    """4-class labels from the planted-box mask centroid quadrant —
    a strongly learnable target for the QAT mechanism benchmarks."""
    b, n = patch_mask.shape
    g = int(np.sqrt(n))
    m = patch_mask.reshape(b, g, g)
    ys = jnp.arange(g)[None, :, None]
    xs = jnp.arange(g)[None, None, :]
    tot = m.sum((1, 2)) + 1e-6
    cy = (m * ys).sum((1, 2)) / tot
    cx = (m * xs).sum((1, 2)) / tot
    mid = (g - 1) / 2.0
    return ((cy > mid).astype(jnp.int32) * 2 + (cx > mid).astype(jnp.int32))


def lm_batch_specs(shape_cfg, dtype=jnp.int32):
    """ShapeDtypeStructs for an LM batch (dry-run input stand-ins)."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, s), dtype),
            "labels": jax.ShapeDtypeStruct((b, s), dtype)}
