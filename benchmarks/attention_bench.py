"""Fused RoI-masked attention benchmark (the serving hot path's score core).

forward_vit_masked applies the RoI mask *post hoc*: XLA computes the full
(Sq, Skv) score matrix and then bias-masks pruned keys — every pruned patch
still costs its score FLOPs. The fused masked attention op
(kernels/flash_attention.py) moves the mask inside the streaming-softmax
update and skips fully-pruned KV blocks, so pruned patches cost nothing:
``pl.when`` on TPU, static packed-skip slicing in the XLA lowering the CPU
host runs (the bucketed serving layout — kept keys are a prefix of the
shared score order, bucket sizes static by construction).

Both paths are the *registered* attention backends, timed exactly as
``core.backend.attend`` dispatches them — "xla" with the packed prefix as
a key mask (post hoc) vs "flash" with the static kept-count (packed skip,
the one-shape serving mode `repro.serving.engine --one-shape` routes
through per bucket).

Gate (tiny-224, 50% skip, batch = one serving micro-batch): the fused
masked backend must be >= 1.3x the materialized xla backend, wall clock.
Also recorded (no gate): the scattered-mask fused path and the Pallas
kernel under interpret mode — the latter is a correctness emulator, so its
number documents *why* the CPU lowering exists, not a perf claim.

Results merge into BENCH_serving.json under "attention", next to the
serving engine numbers they share a hot path with.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import interleaved_best as _interleaved_best
from repro.configs.opto_vit import get_config
from repro.core.backend import ExecPolicy, attend
from repro.kernels.flash_attention import flash_attention_masked

BATCH = 16                      # serving_bench's tiny-224 micro-batch
SKIP = 0.5
SPEEDUP_GATE = 1.3
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


_XLA = ExecPolicy()                          # attn_backend "" -> "xla"
_FLASH = ExecPolicy(attn_backend="flash")


def run() -> dict:
    print("\n== fused RoI-masked attention vs post-hoc XLA masking ==")
    cfg = get_config("tiny", img_size=224)
    n_tokens = (cfg.img_size // cfg.patch) ** 2 + 1          # 197 incl [cls]
    heads, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    kept = int(round((1.0 - SKIP) * n_tokens))

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (BATCH, heads, n_tokens, dh))
    k = jax.random.normal(ks[1], (BATCH, heads, n_tokens, dh))
    v = jax.random.normal(ks[2], (BATCH, heads, n_tokens, dh))
    # serving layout: kept keys are the prefix of the shared score order
    packed = jnp.broadcast_to(
        (jnp.arange(n_tokens) < kept).astype(jnp.float32)[None],
        (BATCH, n_tokens))
    # scattered RoI (mask-mode dense baseline shape of the same skip rate)
    scattered = (jax.random.uniform(ks[3], (BATCH, n_tokens))
                 < 1.0 - SKIP).astype(jnp.float32).at[:, 0].set(1.0)

    xla = jax.jit(lambda q, k, v, m: attend(q, k, v, _XLA, mask=m))
    fused_packed = jax.jit(
        lambda q, k, v: attend(q, k, v, _FLASH, kv_len=kept))
    fused_scat = jax.jit(
        lambda q, k, v, m: attend(q, k, v, _FLASH, mask=m))

    # numerics first: fused == post-hoc masked reference, documented tols
    np.testing.assert_allclose(
        np.asarray(fused_packed(q, k, v)), np.asarray(xla(q, k, v, packed)),
        rtol=2e-4, atol=2e-4,
        err_msg="fused packed-skip attention drifted off the masked oracle")
    np.testing.assert_allclose(
        np.asarray(fused_scat(q, k, v, scattered)),
        np.asarray(xla(q, k, v, scattered)), rtol=2e-4, atol=2e-4)

    t_xla, t_fused, t_scat = _interleaved_best([
        (xla, (q, k, v, packed)),
        (fused_packed, (q, k, v)),
        (fused_scat, (q, k, v, scattered)),
    ])
    speedup = t_xla / t_fused
    print(f"  tiny-224, {SKIP:.0%} skip, batch {BATCH}: "
          f"XLA masked {t_xla * 1e3:7.2f} ms | fused packed "
          f"{t_fused * 1e3:7.2f} ms -> {speedup:.2f}x")
    print(f"  fused scattered mask: {t_scat * 1e3:7.2f} ms "
          f"({t_xla / t_scat:.2f}x; block skip needs the packed layout)")

    # the TPU kernel through the interpret emulator — correctness-only
    kern = jax.jit(lambda q, k, v: flash_attention_masked(
        q, k, v, kv_len=kept, bq=256, bkv=128, interpret=True))
    np.testing.assert_allclose(np.asarray(kern(q, k, v)),
                               np.asarray(xla(q, k, v, packed)),
                               rtol=2e-4, atol=2e-4)
    (t_kern,) = _interleaved_best([(kern, (q, k, v))])
    print(f"  pallas kernel (interpret emulator, not a perf path): "
          f"{t_kern * 1e3:7.2f} ms")

    payload = {
        "config": "tiny-224", "batch": BATCH, "skip": SKIP,
        "n_tokens": n_tokens, "kept": kept,
        "xla_masked_ms": t_xla * 1e3,
        "fused_packed_ms": t_fused * 1e3,
        "fused_scattered_ms": t_scat * 1e3,
        "pallas_interpret_ms": t_kern * 1e3,
        "speedup": speedup,
    }
    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["attention"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON} [attention]")

    assert speedup >= SPEEDUP_GATE, (
        f"fused RoI-masked attention must beat post-hoc XLA masking by "
        f">= {SPEEDUP_GATE}x at {SKIP:.0%} skip; measured {speedup:.2f}x")
    return payload


if __name__ == "__main__":
    run()
