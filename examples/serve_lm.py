"""Serve an assigned LM architecture with batched decode requests.

The same serve_step the multi-pod dry-run lowers for the production mesh,
exercised for real on the host devices at smoke scale — demonstrating the
framework generalizes the paper's inference pipeline beyond ViTs (token
generation against a KV/recurrent-state cache, any family).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b --gen 24
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_variant
from repro.configs.registry import get_config
from repro.distributed.sharding import use_sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate, init_cache
from repro.models import api as model_api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    if not model_api.supports_decode(cfg):
        raise SystemExit(f"{args.arch}: family has no decode step")

    mesh = make_host_mesh()
    with mesh, use_sharding(mesh):
        params = model_api.init_model(jax.random.PRNGKey(0), cfg)
        cache = init_cache(cfg, args.batch, args.prompt_len + args.gen)
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab, jnp.int32)
        toks, tps = generate(params, cache, prompt, args.gen, cfg,
                             greedy=False)
    print(f"[{args.arch}] generated {args.gen} tokens x {args.batch} "
          f"requests at {tps:.1f} tok/s (smoke-scale {cfg.family})")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {np.asarray(toks[i])[:12]} ...")


if __name__ == "__main__":
    main()
