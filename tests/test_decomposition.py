"""Eq. 2 matrix-decomposition equivalence tests (the paper's dataflow)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # seed container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.decomposed_attention import (attention_scores_decomposed,
                                             attention_scores_standard,
                                             decomposition_flops,
                                             mhsa_decomposed, mhsa_standard)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 24), st.integers(4, 32), st.integers(2, 16),
       st.integers(0, 2**31 - 1))
def test_scores_exact_equivalence(n, dm, dk, seed):
    """(X Wq)(X Wk)^T == ((X Wq)(Wk^T s)) X^T — Eq. 2, up to fp
    reassociation."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (n, dm))
    wq = jax.random.normal(ks[1], (dm, dk))
    wk = jax.random.normal(ks[2], (dm, dk))
    scale = 1.0 / np.sqrt(dk)
    s_std = attention_scores_standard(x, wq, wk, scale)
    s_dec = attention_scores_decomposed(x, wq, wk, scale)
    np.testing.assert_allclose(np.asarray(s_std), np.asarray(s_dec),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("heads", [1, 3, 4])
def test_mhsa_equivalence(heads):
    dm, n = 48, 10
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (2, n, dm))
    params = {"wq": jax.random.normal(ks[1], (dm, dm)) * 0.1,
              "wk": jax.random.normal(ks[2], (dm, dm)) * 0.1,
              "wv": jax.random.normal(ks[3], (dm, dm)) * 0.1,
              "wo": jax.random.normal(ks[4], (dm, dm)) * 0.1}
    a = mhsa_standard(x, params, heads)
    b = mhsa_decomposed(x, params, heads)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_flop_tradeoff_direction():
    """dec - std = 2 n^2 (dm - dk) > 0 always (dm = h*dk > dk): the
    decomposition always costs *extra* matmul FLOPs. Its win is the tuning
    bubble + K-buffer removal, not FLOPs — and the relative overhead
    vanishes as n -> 0 and grows with n."""
    small = decomposition_flops(n=16, dm=192, dk=64)
    large = decomposition_flops(n=4096, dm=192, dk=64)
    assert 1.0 < small["ratio"] < large["ratio"]
    # overhead is exactly 2 n^2 (dm - dk)
    n, dm, dk = 64, 192, 64
    f = decomposition_flops(n, dm, dk)
    assert f["decomposed"] - f["standard"] == 2 * n * n * (dm - dk)


def test_scale_folded_into_weights():
    """The paper folds 1/sqrt(dk) into the tuned W_K^T: applying the scale
    inside the decomposition equals scaling the standard scores."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (6, 16))
    wq = jax.random.normal(ks[1], (16, 8))
    wk = jax.random.normal(ks[2], (16, 8))
    unscaled = attention_scores_standard(x, wq, wk, 1.0)
    folded = attention_scores_decomposed(x, wq, wk, 0.125)
    np.testing.assert_allclose(np.asarray(unscaled) * 0.125,
                               np.asarray(folded), rtol=1e-4, atol=1e-4)
