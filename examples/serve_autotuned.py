"""Self-tuning serving demo: the control plane closing the loop live.

A bursty camera fleet — uneven frame budgets, phase-offset starts — is
served by an autotuned ``StreamServer``:

    prepare:  route-probe -> trim unreachable buckets -> lower + compile
              each hit bucket's encode and price it from its optimized
              HLO (the compiles are reused as the AOT encode set, so
              costing doubles as warm-up)
    serve:    every flush is timed; the controller fits
              ``observed ~= a * predicted + b`` over the telemetry and
              re-tunes max-wait / flush-threshold / interleave-depth
              under hysteresis, a clamp box and an fps watchdog

The demo prints the cost-model table, the knobs before and after the
serve, and the headline: predicted vs measured wall per flush for every
bucket the fleet hit (``StreamResult.flush_wall_ms`` is the measured
side, the calibrated controller the predicted side).

    PYTHONPATH=src python examples/serve_autotuned.py \\
        --streams 4 --backend photonic_sim
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backend import available_backends
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--frames", type=int, default=48,
                    help="largest stream's frame budget (the fleet is "
                         "bursty: stream i gets a shrinking share)")
    ap.add_argument("--backend", default="photonic_sim",
                    help=f"matmul backend: {', '.join(available_backends())}")
    ap.add_argument("--retune-every", type=int, default=8)
    ap.add_argument("--cut-every", type=int, default=48)
    args = ap.parse_args()
    if args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")

    cfg = _smoke_cfg(args.backend)
    server = StreamServer(cfg, ServerConfig(
        microbatch=4, chunk=8, mask_refresh=16, warm_start=False,
        autotune=True, retune_every=args.retune_every), n_classes=8)

    # bursty fleet: stream i serves a shrinking budget with a staggered
    # start, so queue occupancy moves while the controller watches
    budgets = [max(8, args.frames - 12 * i) for i in range(args.streams)]
    for i, st in enumerate(video_fleet(args.streams, img_size=cfg.img_size,
                                       patch=cfg.patch,
                                       cut_every=args.cut_every)):
        server.add_session(st, n_frames=budgets[i], start=16 * i)
    print(f"[autotune] backend={server.policy.resolve_backend()} "
          f"ladder={list(server.ladder.sizes)} of {server.n_patches} "
          f"patches, budgets {budgets}")

    ctl = server.autotune_prepare()
    print(f"[autotune] priced buckets {sorted(server.cost_model.costs)}, "
          f"{len(server._encode_aot)} AOT executables")
    print(server.cost_model.render())
    before = ctl.knobs.copy()
    print(f"[autotune] knobs before: max_wait={before.max_wait_chunks} "
          f"depth={before.interleave_depth} "
          f"thresholds={dict(before.flush_threshold)}")

    results = server.serve(verbose=False)
    total = sum(r.frames for r in results.values())
    wall = max(r.wall_s for r in results.values())
    for sid in sorted(results):
        print(f"[autotune] session {sid}: {results[sid].summary()}")
    print(f"[autotune] aggregate: {total} frames in {wall:.2f}s -> "
          f"{total / wall:.1f} frames/s")
    after = ctl.knobs
    print(f"[autotune] knobs after:  max_wait={after.max_wait_chunks} "
          f"depth={after.interleave_depth} "
          f"thresholds={dict(sorted(after.flush_threshold.items()))} "
          f"({ctl.applied_retunes} retunes)")
    print(f"[autotune] {ctl.report()}")

    # headline: calibrated prediction vs measurement, per bucket the
    # fleet actually hit (measured = mean over every stream's timed
    # flushes, weighted by flush count)
    meas: dict[int, list] = {}
    for r in results.values():
        for k, ms in r.flush_wall_ms.items():
            meas.setdefault(k, []).append(ms)
    print(f"[autotune] {'bucket':>7} {'predicted ms':>13} "
          f"{'median ms':>10} {'mean ms':>8} {'rel err':>8}")
    for k in sorted(meas):
        pred_ms = ctl.predict_flush_s(k) * 1e3
        # median over the telemetry window — the statistic the controller
        # calibrates on (robust to the first flush's one-time warm-up);
        # the per-stream mean from flush_wall_ms shown alongside
        med_s = server.telemetry.median_latency(k)
        med_ms = med_s * 1e3 if med_s is not None else 0.0
        mean_ms = sum(meas[k]) / len(meas[k])
        err = abs(pred_ms - med_ms) / med_ms if med_ms else 0.0
        print(f"[autotune] {k:>7} {pred_ms:>13.2f} {med_ms:>10.2f} "
              f"{mean_ms:>8.2f} {err:>7.1%}")


if __name__ == "__main__":
    main()
