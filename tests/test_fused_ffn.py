"""Fused int8 FFN kernel + FFN backend registry unit tests.

The contract under test (kernels/fused_ffn.py, core/backend.py FFN
registry): the fused FFN — w1-matmul + bias + GELU + requantization +
w2-matmul in one kernel — is **bit-identical** to the composed two-linear
photonic dispatch, in every execution context (eager, jitted, and the
Pallas kernel in interpret mode), and its packed ``live_rows`` skip
matches the composed dispatch applied to the live slice exactly, with
dead rows returning exact zeros.

The differential/fuzz coverage lives in tests/test_differential.py (slow
job); this module is the fast-suite pinned core.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import (ExecPolicy, QuantizedWeight,
                                available_ffn_backends, ffn, get_ffn_backend,
                                prepare_params, quantize_weight)
from repro.kernels.fused_ffn import fused_ffn, fused_ffn_int8, fused_ffn_xla
from repro.models import ffn as ffn_mod

COMPOSED = ExecPolicy(backend="photonic_pallas", quant_bits=8, training=False)
FUSED = ExecPolicy(backend="photonic_pallas", quant_bits=8, training=False,
                   ffn_backend="fused")


def _mlp_params(seed, d, dff, dtype=jnp.float32, scale=0.1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {"w1": jax.random.normal(ks[0], (d, dff), dtype) * scale,
            "b1": jax.random.normal(ks[1], (dff,), dtype) * scale,
            "w2": jax.random.normal(ks[2], (dff, d), dtype) * scale,
            "b2": jax.random.normal(ks[3], (d,), dtype) * scale}


def _prepared(params):
    return {"w1": quantize_weight(params["w1"]), "b1": params["b1"],
            "w2": quantize_weight(params["w2"]), "b2": params["b2"]}


def _x(seed, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


# --------------------------------------------------------------------------
# registry plumbing
# --------------------------------------------------------------------------

def test_registry_exposes_both_backends():
    assert set(available_ffn_backends()) >= {"xla", "fused"}
    assert callable(get_ffn_backend("fused"))
    with pytest.raises(KeyError, match="unknown ffn backend"):
        get_ffn_backend("nope")


def test_policy_resolution_and_fingerprint():
    assert ExecPolicy().resolve_ffn_backend() == "xla"
    assert ExecPolicy(ffn_backend="fused").resolve_ffn_backend() == "fused"
    a = ExecPolicy(ffn_backend="fused")
    b = ExecPolicy(ffn_backend="fused")
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != ExecPolicy().fingerprint()


def test_from_cfg_reads_ffn_backend():
    from repro.configs.opto_vit import get_config
    cfg = get_config("tiny").with_(ffn_backend="fused")
    assert ExecPolicy.from_cfg(cfg).resolve_ffn_backend() == "fused"


def test_fused_backend_falls_back_without_cache():
    """Raw float weights (no quantize-once cache) or a non-Pallas matmul
    backend must silently take the composed dispatch — same auto-fallback
    contract as the fused MHSA hot path."""
    params = _mlp_params(0, 32, 64)
    x = _x(1, (2, 9, 32))
    for pol_pair in [
        (ExecPolicy(training=False), ExecPolicy(training=False,
                                                ffn_backend="fused")),
        # cached weights but a non-pallas backend: still the composed path
        (ExecPolicy(backend="photonic_sim", quant_bits=8, training=False),
         ExecPolicy(backend="photonic_sim", quant_bits=8, training=False,
                    ffn_backend="fused")),
    ]:
        ref = ffn_mod.mlp(params, x, pol_pair[0])
        got = ffn_mod.mlp(params, x, pol_pair[1])
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_fused_backend_stays_fused_on_mixed_bits():
    """Per-weight widths (w1 at 8 bits, w2 at 4) are fused-eligible: the
    kernel takes the (w1.bits, w2.bits) pair as static params — input
    quant at w1's width, hidden requant at w2's — and stays bit-identical
    to the composed two-linear dispatch. Widths defer to the cache
    (quant_bits=0); a uniform quant_bits that *disagrees* with the cache
    is a hard error, covered in tests/test_bitplan.py."""
    from repro.core.backend import _fused_ffn_ineligible_reason
    params = _mlp_params(0, 32, 64)
    mixed = {"w1": quantize_weight(params["w1"], bits=8), "b1": params["b1"],
             "w2": quantize_weight(params["w2"], bits=4), "b2": params["b2"]}
    x = _x(1, (2, 9, 32))
    composed = ExecPolicy(backend="photonic_pallas", quant_bits=0,
                          training=False)
    fused = ExecPolicy(backend="photonic_pallas", quant_bits=0,
                       training=False, ffn_backend="fused")
    assert _fused_ffn_ineligible_reason(mixed["w1"], mixed["w2"],
                                        fused) is None
    ref = ffn_mod.mlp(mixed, x, composed)
    got = ffn_mod.mlp(mixed, x, fused)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# --------------------------------------------------------------------------
# bitwise parity: fused vs composed two-linear dispatch
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape,d,dff", [
    ((2, 37, 48), 48, 160),       # non-128 everything (padding path)
    ((1, 8, 16), 16, 32),         # tiny
    ((4, 17, 64), 64, 128),       # block-multiple dff
])
def test_fused_bitwise_vs_composed(shape, d, dff):
    """Fused == composed bit-for-bit, and the fused path is *context
    stable* (same bits eager and jitted). The composed reference itself
    wobbles by 1 ulp between eager and jit at degenerate tiny M (XLA CPU
    picks different elementwise codegen below the parallel-loop
    threshold), so jit-context equality against it is pinned separately
    at serving-representative shapes (test_fused_bitwise_under_jit)."""
    params = _prepared(_mlp_params(2, d, dff))
    x = _x(3, shape)
    ref = ffn_mod.mlp(params, x, COMPOSED)
    got = ffn_mod.mlp(params, x, FUSED)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    got_j = jax.jit(lambda x: ffn_mod.mlp(params, x, FUSED))(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got_j))


@pytest.mark.parametrize("shape,d,dff", [
    ((2, 37, 48), 48, 160),
    ((16, 197, 192), 192, 768),   # the tiny-224 serving micro-batch
])
def test_fused_bitwise_under_jit(shape, d, dff):
    """Under a shared outer jit (the serving engine's encode context) the
    two dispatches still agree bit-for-bit — the Pallas-epilogue dequant
    pins the reference's dispatch-boundary rounding (see
    kernels/fused_ffn.py::_dequant_epilogue)."""
    params = _prepared(_mlp_params(2, d, dff))
    x = _x(3, shape)
    ref = ffn_mod.mlp(params, x, COMPOSED)
    ref_j = jax.jit(lambda x: ffn_mod.mlp(params, x, COMPOSED))(x)
    got_j = jax.jit(lambda x: ffn_mod.mlp(params, x, FUSED))(x)
    np.testing.assert_array_equal(np.asarray(ref_j), np.asarray(got_j))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ref_j))


def _assert_quant_step_close(a, b, err_msg=""):
    """Kernel-vs-twin tolerance: the kernel body compiles as one unit, so
    the compiler may FMA the dequant+bias chain — a last-ulp GELU-input
    freedom the requantization can turn into a +-1 code flip. Outputs then
    differ by at most ~one hidden quant step through w2 (see
    kernels/fused_ffn.py "Parity contract")."""
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-2, atol=1e-2, err_msg=err_msg)
    if a.size > 1 and np.abs(a).max() > 1e-6:
        assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.9999, err_msg


def test_fused_pallas_kernel_matches_xla_twin():
    """Both lowerings of the fused contract agree to the one-quant-step
    kernel tolerance (interpret mode), including the padding path and the
    multi-row-block absmax scan."""
    d, dff = 48, 160
    params = _prepared(_mlp_params(4, d, dff))
    args = (params["w1"].wq, params["w1"].scale.reshape(-1), params["b1"],
            params["w2"].wq, params["w2"].scale.reshape(-1), params["b2"])
    for shape in [(2, 37, d), (1, 300, d)]:     # 1 and 3 row blocks
        x = _x(5, shape)
        twin = fused_ffn_xla(x, *args)
        kern = fused_ffn_int8(x, *args, interpret=True)
        _assert_quant_step_close(kern, twin, err_msg=str(shape))


def test_fused_dispatcher_lowering_switch():
    d, dff = 16, 32
    params = _prepared(_mlp_params(6, d, dff))
    args = (params["w1"].wq, params["w1"].scale.reshape(-1), params["b1"],
            params["w2"].wq, params["w2"].scale.reshape(-1), params["b2"])
    x = _x(7, (2, 9, d))
    # interpret=True routes to the XLA twin — identical call, not close
    np.testing.assert_array_equal(
        np.asarray(fused_ffn(x, *args, interpret=True)),
        np.asarray(fused_ffn_xla(x, *args)))


def test_fused_bf16_io_roundtrip():
    """bf16 activations keep the composed path's cast points: parity stays
    bitwise and the output dtype follows the input."""
    d, dff = 32, 64
    params = _prepared(_mlp_params(8, d, dff))
    x = _x(9, (2, 11, d), jnp.bfloat16)
    ref = ffn_mod.mlp(params, x, COMPOSED)
    got = ffn_mod.mlp(params, x, FUSED)
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(got, np.float32))


# --------------------------------------------------------------------------
# packed live_rows skip (the one-shape serving layout)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("live", [1, 5, 12, 37])
def test_live_rows_match_composed_on_live_slice(live):
    d, dff = 48, 160
    params = _prepared(_mlp_params(10, d, dff))
    x = _x(11, (3, 37, d))
    got = ffn_mod.mlp(params, x, FUSED, live_rows=live)
    ref = ffn_mod.mlp(params, x[:, :live], COMPOSED)
    np.testing.assert_array_equal(np.asarray(got[:, :live]), np.asarray(ref))
    assert (np.asarray(got[:, live:]) == 0).all()


def test_live_rows_kernel_matches_twin():
    d, dff = 48, 160
    params = _prepared(_mlp_params(12, d, dff))
    args = (params["w1"].wq, params["w1"].scale.reshape(-1), params["b1"],
            params["w2"].wq, params["w2"].scale.reshape(-1), params["b2"])
    x = _x(13, (2, 37, d))
    for live in (1, 9, 37):
        kern = np.asarray(fused_ffn_int8(x, *args, live_rows=live,
                                         interpret=True))
        twin = np.asarray(fused_ffn_xla(x, *args, live_rows=live))
        _assert_quant_step_close(kern[:, :live], twin[:, :live],
                                 err_msg=f"live={live}")
        assert (kern[:, live:] == 0).all() and (twin[:, live:] == 0).all()


def test_live_rows_zero_returns_zeros():
    d, dff = 16, 32
    params = _prepared(_mlp_params(14, d, dff))
    args = (params["w1"].wq, params["w1"].scale.reshape(-1), params["b1"],
            params["w2"].wq, params["w2"].scale.reshape(-1), params["b2"])
    x = _x(15, (2, 5, d))
    for fn in (fused_ffn_xla, lambda *a, **k: fused_ffn_int8(*a, **k)):
        out = np.asarray(fn(x, *args, live_rows=0))
        assert out.shape == (2, 5, d)
        assert (out == 0).all()


def test_live_rows_clamps_past_token_count():
    d, dff = 16, 32
    params = _prepared(_mlp_params(16, d, dff))
    x = _x(17, (2, 5, d))
    np.testing.assert_array_equal(
        np.asarray(ffn_mod.mlp(params, x, FUSED, live_rows=99)),
        np.asarray(ffn_mod.mlp(params, x, FUSED)))


# --------------------------------------------------------------------------
# the fused single-jit encoder route (vit.py)
# --------------------------------------------------------------------------

def _smoke_vit():
    from repro.configs.base import smoke_variant
    from repro.configs.opto_vit import get_config
    from repro.models.vit import init_vit
    cfg = smoke_variant(get_config("tiny")).with_(n_layers=2)
    params = init_vit(jax.random.PRNGKey(1), cfg, n_classes=8)
    return cfg, params, prepare_params(params, bits=8)


def test_fused_encoder_eligibility():
    from repro.models.vit import _fused_encoder_eligible
    cfg, params, prepared = _smoke_vit()
    full = ExecPolicy.from_cfg(cfg.with_(
        matmul_backend="photonic_pallas", quant_bits=8,
        attn_backend="flash", ffn_backend="fused"), training=False)
    assert _fused_encoder_eligible(prepared, cfg, full)
    # raw weights, missing any of the three backend knobs, or the Eq. 2
    # dataflow all fall back to the composed dispatch
    assert not _fused_encoder_eligible(params, cfg, full)
    for pol in (ExecPolicy(backend="photonic_pallas", quant_bits=8,
                           attn_backend="flash"),
                ExecPolicy(backend="photonic_pallas", quant_bits=8,
                           ffn_backend="fused"),
                ExecPolicy(backend="bf16", attn_backend="flash",
                           ffn_backend="fused")):
        assert not _fused_encoder_eligible(prepared, cfg, pol)
    assert not _fused_encoder_eligible(
        prepared, cfg.with_(attn_impl="decomposed"), full)


def test_fused_encoder_single_jit_bitwise_vs_composed():
    """The tentpole's closing contract: the single-jit scanned encoder
    (fused attention + fused FFN + norms/residuals in one jitted per-layer
    step) computes bit-identical logits to the composed dispatch."""
    from repro.models.vit import embed_patches, encode_tokens
    cfg, _, prepared = _smoke_vit()
    cfg_fused = cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                          attn_backend="flash", ffn_backend="fused")
    cfg_comp = cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend="flash")
    images = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))
    toks = embed_patches(prepared, images, cfg_fused)
    lg_fused = encode_tokens(prepared, toks, cfg_fused)
    lg_comp = encode_tokens(prepared, toks, cfg_comp)
    np.testing.assert_array_equal(np.asarray(lg_fused), np.asarray(lg_comp))
    # masked RoI mode rides the same route
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (2, 16)) > 0.5
            ).astype(jnp.float32)
    lg_fm = encode_tokens(prepared, toks, cfg_fused, patch_mask=mask)
    lg_cm = encode_tokens(prepared, toks, cfg_comp, patch_mask=mask)
    np.testing.assert_array_equal(np.asarray(lg_fm), np.asarray(lg_cm))


def test_fused_encoder_jit_cache_reuses_entries():
    from repro.models import vit as vit_mod
    cfg, _, prepared = _smoke_vit()
    cfg_fused = cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                          attn_backend="flash", ffn_backend="fused")
    pol = ExecPolicy.from_cfg(cfg_fused, training=False)
    images = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))
    toks = vit_mod.embed_patches(prepared, images, cfg_fused, pol)
    vit_mod.encode_tokens(prepared, toks, cfg_fused, pol)
    n = len(vit_mod._FUSED_ENCODER_JITS)
    vit_mod.encode_tokens(prepared, toks, cfg_fused, pol)
    assert len(vit_mod._FUSED_ENCODER_JITS) == n      # cache hit, no growth


def test_quantized_weight_slicing_contract():
    """lax.scan slices QuantizedWeight leaves in step — a manual slice of
    the stacked cache is the 2-D pair the fused kernels consume."""
    w = jnp.stack([jnp.eye(4), 2 * jnp.eye(4)])       # (L, K, N)
    qw = quantize_weight(w)
    assert qw.wq.shape == (2, 4, 4) and qw.scale.shape == (2, 1, 4)
    sliced = QuantizedWeight(qw.wq[1], qw.scale[1], qw.bits)
    np.testing.assert_allclose(np.asarray(sliced.dequantize()),
                               np.asarray(2 * jnp.eye(4)), rtol=1e-6)
