"""Multi-stream session-server demo: a camera fleet on one accelerator.

N synthetic cameras (disjoint scenes, phase-offset starts) are multiplexed
over one ``StreamServer``:

    per stream:  ingest -> MGNet RoI gate (own temporal mask cache)
    shared:      prepared weight cache + warm-started per-bucket jit ladder
                 + cross-stream micro-batch scheduler (per-session fairness,
                 max-wait deadline) + optional data-mesh sharded encode

The demo prints each session's stream metrics and the aggregate fleet
throughput, then re-serves stream 0 solo to show the multiplexing is
prediction-transparent: interleaved serving computes exactly what a
dedicated single-stream run would (micro-batches are session-pure by
default, so per-launch w8a8 activation scales never couple streams).

    PYTHONPATH=src python examples/serve_multi_stream.py \\
        --streams 4 --frames 64 --backend photonic_sim
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backend import available_backends
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--backend", default="photonic_sim",
                    help=f"matmul backend: {', '.join(available_backends())}")
    ap.add_argument("--attn-backend", default="",
                    choices=["", "xla", "flash"])
    ap.add_argument("--max-wait", type=int, default=0,
                    help="deadline: pad-flush partial micro-batches after "
                         "this many scheduling rounds (0 = wait for fill; "
                         "a firing deadline changes micro-batch composition, "
                         "so the solo-parity demo below is exact only at 0)")
    ap.add_argument("--cut-every", type=int, default=48)
    args = ap.parse_args()
    if args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")

    cfg = _smoke_cfg(args.backend, args.attn_backend)
    server_cfg = ServerConfig(microbatch=4, chunk=8, mask_refresh=16,
                              max_wait_chunks=args.max_wait,
                              warm_start=False)
    server = StreamServer(cfg, server_cfg, n_classes=8)
    print(f"[fleet] backend={server.policy.resolve_backend()} "
          f"ladder={list(server.ladder.sizes)} of {server.n_patches} patches, "
          f"{args.streams} streams, deadline {args.max_wait} rounds")

    streams = video_fleet(args.streams, img_size=cfg.img_size,
                          patch=cfg.patch, cut_every=args.cut_every)
    sessions = [server.add_session(s, n_frames=args.frames, start=16 * i)
                for i, s in enumerate(streams)]

    warm = server.warm_start()
    print(f"[fleet] jit ladder warmed in {warm:.2f}s — streams start "
          "compile-free")
    results = server.serve(verbose=True)
    total = sum(r.frames for r in results.values())
    wall = results[sessions[0].sid].wall_s
    for s in sessions:
        print(f"[fleet] cam{s.sid}:", results[s.sid].summary())
    print(f"[fleet] aggregate {total} frames in {wall:.2f}s -> "
          f"{total / wall:.1f} frames/s across {args.streams} streams "
          f"({len(server.flush_log)} micro-batch launches)")

    # multiplexing transparency: stream 0 solo computes the same classes
    solo_srv = StreamServer(cfg, ServerConfig(
        microbatch=4, chunk=8, mask_refresh=16, warm_start=False),
        n_classes=8)
    solo_sess = solo_srv.add_session(streams[0], n_frames=args.frames,
                                     start=0)
    solo = solo_srv.serve()[solo_sess.sid]
    agree = sum(solo.predictions[i] == results[sessions[0].sid].predictions[i]
                for i in solo.predictions)
    print(f"[fleet] cam0 interleaved vs solo: {agree}/{len(solo.predictions)}"
          " identical predictions (session-pure micro-batches keep "
          "multiplexing out of the numerics)")


if __name__ == "__main__":
    main()
