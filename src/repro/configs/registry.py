"""Architecture registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = [
    "mamba2-780m",
    "stablelm-12b",
    "qwen2-1.5b",
    "llama3-405b",
    "qwen2.5-3b",
    "llama-3.2-vision-90b",
    "whisper-medium",
    "recurrentgemma-9b",
    "kimi-k2-1t-a32b",
    "qwen3-moe-30b-a3b",
    # the paper's own backbones
    "opto-vit-tiny", "opto-vit-small", "opto-vit-base", "opto-vit-large",
]

_MODULE_FOR = {i: "repro.configs." + i.replace("-", "_").replace(".", "_")
               for i in ARCH_IDS}
for v in ("tiny", "small", "base", "large"):
    _MODULE_FOR[f"opto-vit-{v}"] = "repro.configs.opto_vit"


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULE_FOR[arch_id])
    if arch_id.startswith("opto-vit-"):
        return mod.get_config(arch_id.split("-")[-1])
    return mod.get_config()


def all_lm_archs() -> list[str]:
    """The 10 assigned LM-family architectures (dry-run set)."""
    return ARCH_IDS[:10]
