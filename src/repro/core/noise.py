"""Microring-resonator (MR) device model: crosstalk, resolution, FPV.

Implements the paper's §IV "MR Resolution Analysis" verbatim:

    phi(i, j) = delta^2 / ((lambda_i - lambda_j)^2 + delta^2)
    delta     = lambda / (2 * Q_factor)
    P_noise   = sum_j phi(i, j) * P_in[j]          (j != i)
    Resolution (levels) = 1 / max_i |P_noise(i)|

and the derived claim: >= 8-bit resolution requires Q ~= 5000 for the 32-channel
WDM grid. The model also provides multiplicative transmission-error sampling
used by the photonic matmul simulator (core/photonic.py) to study accuracy
under fabrication-process variation (FPV).

All wavelengths are in nanometres. The paper does not state its channel
spacing; the default grid spreads 32 channels at 4.8 nm centred on 1550 nm —
calibrated (see tests/test_noise.py) so that the paper's claim "8-bit
resolution requires Q ~= 5000" reproduces exactly under the full crosstalk
sum. (At DWDM 0.8 nm spacing the same formula would require Q ~= 28k; the
free parameter is the grid, which the paper leaves open.)
"""

from __future__ import annotations

import functools
import math
import threading
from contextlib import contextmanager
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MRConfig",
    "wavelength_grid",
    "crosstalk_matrix",
    "noise_power",
    "resolution_bits",
    "required_q_factor",
    "transmission_error",
    "mr_detune_gain",
    "drifted_noise_floor",
    "NoiseSpec",
    "DriftState",
    "noise_scope",
    "scoped",
    "scope_salt",
    "current_scope",
    "next_call_keys",
    "shot_key",
    "readout_noise",
]


@dataclass(frozen=True)
class MRConfig:
    """Photonic device constants (paper §IV: Q=5000, 32 channels, C-band)."""

    n_channels: int = 32          # WDM wavelength channels (= VCSEL count)
    q_factor: float = 5000.0      # MR quality factor
    center_nm: float = 1550.0     # C-band centre
    spacing_nm: float = 4.8       # calibrated: Q=5000 <-> 8-bit resolution
    # geometry (paper: 400nm input wg, 760nm ring wg, 5um radius) — recorded
    # for documentation; the behavioural model depends only on Q and the grid.
    ring_radius_um: float = 5.0
    input_wg_nm: float = 400.0
    ring_wg_nm: float = 760.0


def wavelength_grid(cfg: MRConfig) -> jnp.ndarray:
    """Channel wavelengths lambda_i (nm), centred on cfg.center_nm."""
    n = cfg.n_channels
    offsets = (jnp.arange(n) - (n - 1) / 2.0) * cfg.spacing_nm
    return cfg.center_nm + offsets


def crosstalk_matrix(cfg: MRConfig) -> jnp.ndarray:
    """phi[i, j]: fraction of channel j's power leaking into channel i.

    phi(i,j) = delta^2 / ((li - lj)^2 + delta^2), delta = lambda/(2Q).
    Diagonal is zeroed (a channel is not its own noise).
    """
    lam = wavelength_grid(cfg)
    delta = lam / (2.0 * cfg.q_factor)          # per-channel linewidth (nm)
    diff2 = (lam[:, None] - lam[None, :]) ** 2
    phi = (delta[:, None] ** 2) / (diff2 + delta[:, None] ** 2)
    return phi * (1.0 - jnp.eye(cfg.n_channels))


def noise_power(cfg: MRConfig, p_in: jnp.ndarray | None = None) -> jnp.ndarray:
    """P_noise[i] = sum_j phi(i,j) * P_in[j] for input power vector p_in.

    The paper evaluates at P_in = 1 (worst case: all channels at full power).
    """
    phi = crosstalk_matrix(cfg)
    if p_in is None:
        p_in = jnp.ones((cfg.n_channels,))
    return phi @ p_in


@functools.lru_cache(maxsize=None)
def resolution_bits(cfg: MRConfig) -> float:
    """Achievable bit resolution = log2(1 / max|P_noise|).

    Computed host-side (float32 numpy, mirroring the jnp formula) so it
    stays a *static* python constant even when called from inside a jit
    trace — ``transmission_error``'s crosstalk floor must not become a
    tracer. Cached per (hashable, frozen) MRConfig."""
    n = cfg.n_channels
    lam = (cfg.center_nm
           + (np.arange(n, dtype=np.float32) - (n - 1) / 2.0)
           * np.float32(cfg.spacing_nm))
    delta = lam / np.float32(2.0 * cfg.q_factor)
    diff2 = (lam[:, None] - lam[None, :]) ** 2
    phi = (delta[:, None] ** 2) / (diff2 + delta[:, None] ** 2)
    phi = phi * (1.0 - np.eye(n, dtype=np.float32))
    levels = 1.0 / float(np.abs(phi.sum(axis=1)).max())
    return math.log2(levels)


def required_q_factor(target_bits: float = 8.0, cfg: MRConfig | None = None,
                      q_lo: float = 100.0, q_hi: float = 1e6) -> float:
    """Bisect the minimum Q-factor achieving ``target_bits`` resolution.

    Reproduces the paper's finding that 8-bit needs Q ~= 5000. The exact
    crossover depends on the grid spacing, which the paper leaves open: the
    default ``MRConfig`` is the calibrated 4.8 nm / 32-channel grid, on which
    the 8-bit crossover lands just under Q = 5000 — pinned by
    ``tests/test_noise.py::test_paper_claim_8bit_needs_q5000``. (A DWDM
    0.8 nm grid would instead need Q ~= 28k; see the module header.)
    """
    base = cfg or MRConfig()

    def bits_at(q):
        return resolution_bits(MRConfig(
            n_channels=base.n_channels, q_factor=q,
            center_nm=base.center_nm, spacing_nm=base.spacing_nm))

    lo, hi = q_lo, q_hi
    if bits_at(hi) < target_bits:
        raise ValueError("target resolution unreachable within q_hi")
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if bits_at(mid) >= target_bits:
            hi = mid
        else:
            lo = mid
    return hi


# Fold constants deriving the independent per-component subkeys from one call
# key. `fold_in` (rather than `split`) keeps the crosstalk uniform drawn from
# the caller's key unchanged, so the fpv_sigma=0 path stays bitwise identical
# to the pre-fix behaviour while the FPV/wander/shot draws decorrelate.
_FPV_FOLD = 0x46505601    # "FPV"
_WANDER_FOLD = 0x574E4401  # "WND"
_SHOT_FOLD = 0x53484F01    # "SHO"


def mr_detune_gain(cfg: MRConfig, detune_nm) -> jnp.ndarray:
    """Lorentzian through-transmission of an MR bank detuned by ``detune_nm``.

    g(d) = delta^2 / (d^2 + delta^2) with delta = lambda/(2Q): unity on
    resonance, falling off over one linewidth. At the calibrated Q = 5000
    operating point delta ~= 0.155 nm, so 0.05-0.15 nm of thermal drift is
    the regime where accuracy degrades and 0.5 nm is catastrophic.
    """
    delta = cfg.center_nm / (2.0 * cfg.q_factor)
    d = jnp.asarray(detune_nm, jnp.float32)
    return (delta * delta) / (d * d + delta * delta)


def drifted_noise_floor(cfg: MRConfig, drift_nm) -> jnp.ndarray:
    """Worst-channel crosstalk power when every ring drifts by ``drift_nm``.

    Traced analogue of ``2^-resolution_bits(cfg)`` (which it equals at
    drift 0): the ring resonances shift against the fixed laser grid, so the
    inter-channel detunings |lambda_i + drift - lambda_j| shrink on one side
    and the crosstalk sum grows with |drift|.
    """
    lam = wavelength_grid(cfg)
    delta = lam / (2.0 * cfg.q_factor)
    drift = jnp.asarray(drift_nm, jnp.float32)
    diff2 = (lam[:, None] + drift - lam[None, :]) ** 2
    phi = (delta[:, None] ** 2) / (diff2 + delta[:, None] ** 2)
    phi = phi * (1.0 - jnp.eye(cfg.n_channels))
    return jnp.max(phi @ jnp.ones((cfg.n_channels,)))


def transmission_error(key: jax.Array, shape: tuple[int, ...],
                       cfg: MRConfig | None = None,
                       fpv_sigma: float = 0.0, *,
                       fpv_key: jax.Array | None = None,
                       drift_nm=None,
                       wander_sigma_nm: float = 0.0) -> jnp.ndarray:
    """Multiplicative weight-transmission error for the photonic matmul sim.

    Components:
      * deterministic crosstalk floor: worst-case noise power of the WDM grid
        (2^-resolution_bits, or its drift-widened traced analogue) treated as
        a uniform error bound;
      * fabrication-process variation (FPV): gaussian perturbation of the
        effective transmission with std ``fpv_sigma`` (0 disables). Drawn from
        ``fpv_key`` when given (a device-static key, so the FPV pattern is a
        property of the chip, not of time), else from a subkey folded out of
        ``key`` — independent of the crosstalk uniform, which consumes ``key``
        directly (the historical ``split(key)[0]`` derivation reused the
        already-consumed key and correlated the two draws);
      * thermal drift + resonance wander (only when ``drift_nm`` is not None):
        each weight's MR sits at detuning ``drift_nm + wander_sigma_nm * N``,
        and its transmission is scaled by the Lorentzian ``mr_detune_gain``.
        Common-mode drift alone mostly rescales logits (benign for argmax);
        the per-element wander rides the Lorentzian slope, so dispersion —
        the part that flips predictions — grows with |drift|.

    Returns a multiplier M; apply as ``w_effective = w * M``. With
    ``drift_nm=None`` (the default) the floor is the static python constant
    and the fpv_sigma=0 path is bitwise identical to the pre-drift model.
    """
    cfg = cfg or MRConfig()
    if drift_nm is None:
        floor = 2.0 ** (-resolution_bits(cfg))
        m = 1.0 + jax.random.uniform(key, shape, minval=-floor, maxval=floor)
    else:
        floor = drifted_noise_floor(cfg, drift_nm)
        u = jax.random.uniform(key, shape)
        m = 1.0 + (2.0 * u - 1.0) * floor
        detune = jnp.asarray(drift_nm, jnp.float32)
        if wander_sigma_nm > 0.0:
            wkey = jax.random.fold_in(key, _WANDER_FOLD)
            detune = detune + wander_sigma_nm * jax.random.normal(wkey, shape)
        m = m * mr_detune_gain(cfg, detune)
    if fpv_sigma > 0.0:
        if fpv_key is None:
            fpv_key = jax.random.fold_in(key, _FPV_FOLD)
        m = m * (1.0 + fpv_sigma * jax.random.normal(fpv_key, shape))
    return m


# ---------------------------------------------------------------------------
# Calibrated noise-injection layer: NoiseSpec + time-indexed DriftState
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NoiseSpec:
    """Calibrated device-noise operating point (hashable: jit-cache safe).

    The defaults are the paper's Q = 5000 / 8-bit point: the crosstalk floor
    of the calibrated 4.8 nm grid, ~1% fabrication-process variation, and
    0.5% shot noise on the balanced-photodetector readout. Drift, wander and
    recalibration are off unless set — they define the *time-varying* part of
    the model that ``DriftState`` evolves per frame.
    """

    q_factor: float = 5000.0       # MR quality factor (crosstalk floor)
    fpv_sigma: float = 0.01        # device-static fabrication variation
    shot_sigma: float = 0.005      # per-readout shot noise on the BPD
    drift_rate_nm: float = 0.0     # common-mode thermal drift per frame
    wander_sigma_nm: float = 0.0   # per-element fast resonance wander
    recal_bound_nm: float = 0.0    # drift bound triggering MR re-tuning (0=off)
    adc_quantize_output: bool = False  # range-limited ADC on the readout
    noisy_gate: bool = False       # also perturb the MGNet RoI gate matmuls
    seed: int = 0                  # FPV pattern seed (a property of the chip)

    def mr(self) -> MRConfig:
        return MRConfig(q_factor=self.q_factor)


@jax.tree_util.register_pytree_node_class
class DriftState:
    """Time-indexed device state: PRNG lineage + accumulated thermal drift.

    A pytree of scalars, so it passes through jit/AOT boundaries as a traced
    argument (no retrace as it evolves). ``frame`` indexes time — every draw
    folds it into the key, so successive frames see fresh noise while a
    pinned state reproduces bitwise. ``drift_nm`` is the accumulated
    common-mode resonance shift; ``advance`` grows it at the spec's rate and
    recalibration (MR re-tuning) resets it to zero.
    """

    def __init__(self, key: jax.Array, frame, drift_nm):
        self.key = key
        self.frame = frame
        self.drift_nm = drift_nm

    @classmethod
    def init(cls, seed: int = 0) -> "DriftState":
        return cls(jax.random.PRNGKey(seed), jnp.int32(0), jnp.float32(0.0))

    def advance(self, spec: NoiseSpec, frames: int = 1) -> "DriftState":
        return DriftState(self.key, self.frame + jnp.int32(frames),
                          self.drift_nm + jnp.float32(frames * spec.drift_rate_nm))

    def with_drift(self, nm) -> "DriftState":
        return DriftState(self.key, self.frame, jnp.float32(nm))

    def reset_drift(self) -> "DriftState":
        return self.with_drift(0.0)

    def tree_flatten(self):
        return (self.key, self.frame, self.drift_nm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DriftState(frame={self.frame}, drift_nm={self.drift_nm})"


# ---------------------------------------------------------------------------
# Noise scope: per-call-site key threading for the backend dispatch
# ---------------------------------------------------------------------------
#
# The backend dispatch (core/backend.py) has no key parameter — threading one
# through every matmul/linear/attend signature would fork the whole call tree.
# Instead a thread-local *scope* carries the DriftState; each noisy dispatch
# asks `next_call_keys` for its keys, which fold (state.key, state.frame, the
# active salts, a per-scope call counter) into a unique stream per call site.
#
# Install the scope INSIDE the traced entry function (see `scoped`): the scope
# is then created fresh per trace, so the call counter deterministically
# restarts at 0 — retraces and eager replays of the same function body assign
# identical per-site keys, and cached executions reproduce bitwise for equal
# (params, inputs, DriftState).

_scope_tls = threading.local()


class _NoiseScope:
    __slots__ = ("state", "salts", "counter")

    def __init__(self, state: DriftState):
        self.state = state
        self.salts: tuple = ()
        self.counter = 0


@contextmanager
def noise_scope(state: DriftState):
    """Install ``state`` as the active noise scope for the calling thread."""
    prev = getattr(_scope_tls, "scope", None)
    _scope_tls.scope = _NoiseScope(state)
    try:
        yield _scope_tls.scope
    finally:
        _scope_tls.scope = prev


def scoped(state: DriftState, fn):
    """Run ``fn()`` under a fresh noise scope — the jit-lambda entry point."""
    with noise_scope(state):
        return fn()


def current_scope() -> _NoiseScope | None:
    return getattr(_scope_tls, "scope", None)


@contextmanager
def scope_salt(salt):
    """Fold an extra salt (e.g. a scanned layer index) into subsequent keys.

    No-op when no scope is active, so clean paths can share the code. The
    salt may be a traced int32 scalar — `fold_in` accepts tracers, which is
    how every layer of a `lax.scan`-shared encoder body gets its own draws.
    """
    sc = current_scope()
    if sc is None:
        yield
        return
    prev = sc.salts
    sc.salts = prev + (salt,)
    try:
        yield
    finally:
        sc.salts = prev


def next_call_keys(spec: NoiseSpec):
    """Keys for one noisy matmul dispatch: (draw key, FPV key, drift_nm).

    The draw key is unique per (frame, salt chain, call site) — time-varying
    noise. The FPV key folds the same salts/counter into the *spec seed*
    lineage instead, so the fabrication pattern each call site sees is fixed
    across frames: a property of the chip, not of time.
    """
    sc = current_scope()
    if sc is None:
        raise RuntimeError(
            "ExecPolicy.noise is set but no noise scope is active. Noisy "
            "dispatch draws its keys from a DriftState installed via "
            "repro.core.noise.noise_scope(state) / scoped(state, fn) — the "
            "serving entry points do this; direct forward calls must wrap "
            "themselves. (This replaces the old silent PRNGKey(0) fallback "
            "that froze the error pattern.)")
    n = sc.counter
    sc.counter += 1
    k = jax.random.fold_in(sc.state.key, sc.state.frame)
    kf = jax.random.PRNGKey(spec.seed)
    for s in sc.salts:
        k = jax.random.fold_in(k, s)
        kf = jax.random.fold_in(kf, s)
    return jax.random.fold_in(k, n), jax.random.fold_in(kf, n), sc.state.drift_nm


def shot_key(key: jax.Array) -> jax.Array:
    """Readout-noise subkey folded out of a call's draw key."""
    return jax.random.fold_in(key, _SHOT_FOLD)


def readout_noise(y: jnp.ndarray, spec: NoiseSpec, key: jax.Array,
                  bits: int = 8) -> jnp.ndarray:
    """Shot noise on the BPD accumulate + optional range-limited ADC requant."""
    if spec.shot_sigma > 0.0:
        y = y * (1.0 + spec.shot_sigma
                 * jax.random.normal(shot_key(key), y.shape))
    if spec.adc_quantize_output:
        from repro.core import quant
        s = quant.absmax_scale(y, bits=bits)
        y = quant.dequantize(quant.quantize(y, s, bits=bits), s)
    return y
