"""Self-tuning serving control plane (ROADMAP item 1).

Three layers, composed by ``StreamServer.autotune_prepare()``:

  * ``costmodel`` — prices every ladder bucket's encode by compiling it
    and analyzing the optimized HLO (``roofline.hlo_analysis``), combined
    with the photonic accelerator model (``serving.accounting``); the
    compiled executables double as the server's AOT encode path.
  * ``telemetry`` — ring buffer of observed per-flush wall timings and
    occupancy, tagged by (bucket, batch fill, stream count).
  * ``controller`` — calibrates predicted cost against observed seconds
    (per-bucket linear fit), then re-tunes the serving knobs every N
    frames with hysteresis and a safety clamp.
"""

from repro.serving.control.controller import (Controller, ControllerConfig,
                                              TunedKnobs)
from repro.serving.control.costmodel import BucketCost, EncodeCostModel
from repro.serving.control.telemetry import FlushObs, FlushTelemetry

__all__ = ["BucketCost", "EncodeCostModel", "FlushObs", "FlushTelemetry",
           "Controller", "ControllerConfig", "TunedKnobs"]
