"""Chaos gates: serving must survive injected faults without changing math.

The fault layer (serving/faults.py ``FaultSpec`` + ``FaultInjector``) is
only worth having if the recovery paths it exercises are *provably*
transparent: a retry, a quarantine, or a restore that perturbed predictions
would be a silent correctness bug wearing a resilience costume. Every gate
here is therefore bitwise, leaning on the session-pure micro-batch
invariant (each launch's w8a8 absmax scope is one session's frames, so
launch order and co-tenancy never touch per-session numerics):

  A. **Transient faults are free (minus latency)**: with a 10% transient
     flush-fault rate every session still completes, every prediction is
     bitwise identical to the fault-free run, and aggregate fps stays
     >= 0.7x fault-free (retries + backoff are the only cost).
  B. **Quarantine is surgical**: hard-failing one session mid-stream
     leaves every other session's predictions bitwise identical to a run
     where the failed session was *never registered* — its result comes
     back ``poisoned`` with the failure reason, nobody else notices.
  C. **Crash-restore is exact**: a server killed mid-serve (injected
     ``ServerCrash``) and resumed from its round-cadence checkpoint via
     ``serve_with_restarts`` reproduces the uninterrupted run's
     predictions bitwise, for every session.

Gates run clean (no NoiseSpec): under noise the server-owned DriftState
couples sessions through flush order, which is physical (one device, one
thermal history) but breaks the never-registered counterfactual of gate B.

Results merge into BENCH_serving.json under "faults".

    PYTHONPATH=src python -m benchmarks.fault_bench            # full
    PYTHONPATH=src python -m benchmarks.fault_bench --smoke    # CI fast
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import warnings

import numpy as np

from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.faults import FaultSpec, serve_with_restarts
from repro.serving.server import ServerConfig, StreamServer

FPS_RATIO_GATE = 0.7
FLUSH_FAULT_RATE = 0.10
OUT_JSON = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")


def _server(cfg, **kw):
    base = dict(warm_start=True, mesh="off", chunk=8, microbatch=4)
    base.update(kw)
    return StreamServer(cfg, ServerConfig(**base))


def _serve_all(srv, streams, n_frames):
    sessions = [srv.add_session(st, n_frames=n_frames) for st in streams]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        results = srv.serve()
    return {s.sid: results[s.sid] for s in sessions}


def _preds(res, n_frames):
    return np.array([res.predictions[i] for i in range(n_frames)])


def _gate_transient(cfg, streams, n_frames, base) -> dict:
    """Gate A: 10% transient flush faults -> bitwise + fps >= 0.7x."""
    srv = _server(cfg, faults=FaultSpec(flush_fault_rate=FLUSH_FAULT_RATE,
                                        seed=7))
    res = _serve_all(srv, streams, n_frames)
    retries = sum(r.retries for r in res.values())
    assert retries > 0, (
        f"a {FLUSH_FAULT_RATE:.0%} transient flush-fault rate over "
        f"{len(srv.flush_log)} flushes injected nothing — the chaos gate "
        f"is not exercising the retry path")
    for sid, r in res.items():
        assert not r.poisoned, (
            f"session {sid} was quarantined under purely transient faults "
            f"({r.failure}) — retries should have absorbed them")
        np.testing.assert_array_equal(
            _preds(r, n_frames), _preds(base[sid], n_frames),
            err_msg=f"session {sid}: transient-fault retries changed "
                    f"predictions — the retry path is not transparent")
    fps_base = sum(r.frames for r in base.values()) / base[0].wall_s
    fps_fault = sum(r.frames for r in res.values()) / res[0].wall_s
    ratio = fps_fault / fps_base
    print(f"  transient: {retries} retries over {len(srv.flush_log)} "
          f"flushes | {fps_fault:.1f} vs {fps_base:.1f} frames/s "
          f"({ratio:.2f}x) | predictions bitwise identical")
    assert ratio >= FPS_RATIO_GATE, (
        f"aggregate fps under {FLUSH_FAULT_RATE:.0%} transient flush "
        f"faults must stay >= {FPS_RATIO_GATE}x fault-free; measured "
        f"{ratio:.2f}x ({fps_fault:.1f} vs {fps_base:.1f} frames/s)")
    return {"retries": int(retries), "fps_ratio": float(ratio),
            "fps_faulty": float(fps_fault), "fps_clean": float(fps_base)}


def _gate_isolation(cfg, streams, n_frames) -> dict:
    """Gate B: hard-fail one session -> others match never-registered."""
    victim = 1
    srv = _server(cfg, faults=FaultSpec(hard_fail_session=victim,
                                        hard_fail_at_chunk=1, seed=3))
    res = _serve_all(srv, streams, n_frames)
    assert res[victim].poisoned and res[victim].failure, (
        f"session {victim} was hard-failed but its result is not poisoned")
    # counterfactual: the victim's stream never existed. Sids shift, so
    # sessions are matched by *stream*, which is what identifies them.
    survivors = [i for i in range(len(streams)) if i != victim]
    ref = _serve_all(_server(cfg), [streams[i] for i in survivors],
                     n_frames)
    ref_in_order = [ref[sid] for sid in sorted(ref)]  # registration order
    for i, r in zip(survivors, ref_in_order):
        np.testing.assert_array_equal(
            _preds(res[i], n_frames), _preds(r, n_frames),
            err_msg=f"stream {i}: a co-tenant session's hard failure "
                    f"leaked into this session's predictions")
    print(f"  isolation: session {victim} poisoned "
          f"({res[victim].failure!r}), {len(survivors)} survivors bitwise "
          f"identical to never-registered run")
    return {"victim": victim, "failure": res[victim].failure,
            "survivors": len(survivors)}


def _gate_restore(cfg, streams, n_frames, base) -> dict:
    """Gate C: crash mid-serve, resume from checkpoint -> bitwise."""
    with tempfile.TemporaryDirectory() as root:
        def make_server(attempt):
            # attempt 0 carries the crash bomb; the resumed server must
            # not re-arm it (a fresh injector would re-fire every attempt)
            faults = (FaultSpec(crash_at_round=2, seed=5)
                      if attempt == 0 else None)
            return _server(cfg, faults=faults, checkpoint_dir=root,
                           checkpoint_every=1)

        def register(srv):
            for st in streams:
                srv.add_session(st, n_frames=n_frames)

        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res, restarts, _ = serve_with_restarts(
                make_server, register, root,
                streams=dict(enumerate(streams)))
    assert restarts == 1, (
        f"the injected crash must kill exactly the first attempt; "
        f"observed {restarts} restarts")
    for sid, r in base.items():
        assert res[sid].frames == r.frames, (
            f"session {sid} served {res[sid].frames} frames after restore, "
            f"{r.frames} uninterrupted — frames were lost or replayed")
        np.testing.assert_array_equal(
            _preds(res[sid], n_frames), _preds(r, n_frames),
            err_msg=f"session {sid}: crash-restore diverged from the "
                    f"uninterrupted run — the checkpoint is not bitwise")
    print(f"  restore: crashed at round 2, {restarts} restart, "
          f"{len(base)} sessions bitwise identical to uninterrupted run")
    return {"restarts": int(restarts), "sessions": len(base)}


def run(smoke: bool = False) -> dict:
    print("\n== faults: injected chaos vs bitwise serving guarantees ==")
    cfg = _smoke_cfg("")
    n_streams = 2 if smoke else 3
    n_frames = 24 if smoke else 48
    streams = video_fleet(n_streams, img_size=cfg.img_size, patch=cfg.patch)
    base = _serve_all(_server(cfg), streams, n_frames)

    payload = {"streams": n_streams, "frames_per_stream": n_frames,
               "flush_fault_rate": FLUSH_FAULT_RATE}
    payload["transient"] = _gate_transient(cfg, streams, n_frames, base)
    payload["restore"] = _gate_restore(cfg, streams, n_frames, base)
    if smoke:
        print("  (smoke mode: isolation gate + BENCH json skipped)")
        return payload
    payload["isolation"] = _gate_isolation(cfg, streams, n_frames)

    merged = {}
    if os.path.exists(OUT_JSON):
        with open(OUT_JSON) as f:
            merged = json.load(f)
    merged["faults"] = payload
    with open(OUT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"  wrote {OUT_JSON} [faults]")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2 streams x 24 frames, transient + restore gates "
                         "only (fast CI); skips the isolation gate and the "
                         "JSON merge")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
