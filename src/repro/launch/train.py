"""Training driver: real steps on the host devices (CPU here, TPU pods in
production — same code path, bigger mesh).

Wires together: configs -> model init (sharded) -> data pipeline ->
jit train_step (launch/steps.py) -> checkpoint manager + straggler
detection + auto-restart (distributed/fault_tolerance.py).

Usage (examples/ wrap this):
    python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 50
    python -m repro.launch.train --arch opto-vit-tiny --steps 200 \\
        --ckpt-dir /tmp/ckpt --ckpt-every 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ShapeConfig, smoke_variant
from repro.configs.registry import get_config
from repro.data.pipeline import FrameStream, ImageStream, TokenStream
from repro.distributed.fault_tolerance import StragglerDetector
from repro.distributed.sharding import current_ctx, use_sharding
from repro.launch.mesh import batch_shard_count, make_host_mesh
from repro.launch.steps import (abstract_state, make_train_step,
                                state_logical_axes, tree_shardings)
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["init_state", "make_stream", "train_loop", "main"]


def init_state(cfg: ArchConfig, seed: int = 0):
    """Initialize the train state, sharded per the active ctx (if any)."""
    key = jax.random.PRNGKey(seed)
    ocfg = AdamWConfig(low_mem=not cfg.use_fp32_master)

    def init():
        params = model_api.init_model(key, cfg) if cfg.family != "vit" \
            else model_api.init_model(key, cfg)
        return {"params": params, "opt": adamw_init(params, ocfg),
                "step": jnp.zeros((), jnp.int32)}

    ctx = current_ctx()
    if ctx is None:
        return jax.jit(init)()
    st_sh = tree_shardings(state_logical_axes(cfg), abstract_state(cfg), ctx)
    return jax.jit(init, out_shardings=st_sh)()


def make_stream(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    """Deterministic (seed, step)-indexed batch source for the family."""
    fam = cfg.family
    b, s = shape.global_batch, shape.seq_len
    if fam in ("dense", "moe", "ssm", "hybrid"):
        ts = TokenStream(cfg.vocab, s, b, seed=seed, ctx=current_ctx())
        return ts.batch_at
    if fam == "vit":
        ims = ImageStream(cfg.img_size, b, n_classes=8, patch=cfg.patch,
                          seed=seed)
        return lambda step: {k: v for k, v in ims.batch_at(step).items()
                             if k in ("images", "labels")}
    if fam == "encdec":
        ts = TokenStream(cfg.vocab, s, b, seed=seed)
        fs = FrameStream(cfg.enc_frames, cfg.d_frontend or cfg.d_model, b,
                         seed=seed + 1)
        return lambda step: {**ts.batch_at(step),
                             "frames": fs.batch_at(step)["frames"]}
    if fam == "vlm":
        ts = TokenStream(cfg.vocab, s, b, seed=seed)
        fs = FrameStream(cfg.n_img_tokens, cfg.d_frontend or cfg.d_model, b,
                         seed=seed + 1)
        return lambda step: {**ts.batch_at(step),
                             "img_embeds": fs.batch_at(step)["frames"]}
    raise ValueError(fam)


def train_loop(cfg: ArchConfig, shape: ShapeConfig, n_steps: int,
               seed: int = 0, ckpt: CheckpointManager | None = None,
               log_every: int = 10, inject_fault_at: int | None = None):
    """Run n_steps; returns (final_state, losses list, straggler flags)."""
    ctx = current_ctx()
    assert ctx is not None, "train_loop requires use_sharding(mesh)"
    step_fn, _ = make_train_step(cfg, shape, ctx, donate=True)
    batch_at = make_stream(cfg, shape, seed)
    state = init_state(cfg, seed)

    start = 0
    if ckpt is not None:
        st_ax = state_logical_axes(cfg)
        restored, s0 = ckpt.restore_latest(state, ctx, st_ax)
        if restored is not None:
            state, start = restored, s0
            print(f"[train] resumed from step {start}")

    det = StragglerDetector()
    losses = []
    for step in range(start, n_steps):
        if inject_fault_at is not None and step == inject_fault_at:
            inject_fault_at = None
            raise RuntimeError("injected fault (preemption simulation)")
        batch = batch_at(step)
        with det.timer(det, step):
            state, metrics = step_fn(state, batch)
        l = float(metrics["loss"])
        losses.append(l)
        if ckpt is not None:
            ckpt.maybe_save(step + 1, state)
        if step % log_every == 0 or step == n_steps - 1:
            print(f"[train] step {step:5d} loss {l:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f}")
    if ckpt is not None:
        ckpt.maybe_save(n_steps, state, force=True)
        ckpt.wait()
    return state, losses, det.flags


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduce to the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.layers:
        cfg = cfg.with_(n_layers=args.layers)
    if args.d_model:
        cfg = cfg.with_(d_model=args.d_model)

    mesh = make_host_mesh(args.data_par, args.model_par)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ckpt = (CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            if args.ckpt_dir else None)

    with mesh, use_sharding(mesh):
        if cfg.family == "moe":
            cfg = cfg.with_(moe_groups=batch_shard_count(mesh))
        t0 = time.time()
        state, losses, flags = train_loop(cfg, shape, args.steps,
                                          seed=args.seed, ckpt=ckpt)
        dt = time.time() - t0
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1) * 1e3:.0f} ms/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"straggler flags: {len(flags)}")


if __name__ == "__main__":
    main()
