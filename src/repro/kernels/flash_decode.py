"""Pallas TPU kernel: fused flash-decoding (one-token attention vs cache).

The decode roofline (EXPERIMENTS.md §3/§4 cell D) is memory-bound on KV
cache reads; the XLA lowering additionally materializes f32 score rows
per block. This kernel streams the cache through VMEM once per token:

    grid = (B * Hkv, S / bs)

Each step loads a (bs, D) K/V block for one (batch, kv-head), computes
the (G, bs) score tile for the GQA group of G query heads against it,
and maintains the running (max, sum, acc) in VMEM scratch — the
flash-decoding inner loop. Cache positions >= length are masked.

Validated in interpret mode against models/attention.decode_attention
(tests/test_kernels_decode.py). On a real TPU pass interpret=False; the
seq-sharded (flash-decoding) merge across shards composes outside the
kernel exactly as the XLA path does.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode_kernel", "flash_decode"]

NEG_INF = -1e30


def flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, scale: float, bs: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    blk_lo = si * bs

    @pl.when(blk_lo < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0].astype(jnp.float32)                  # (bs, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = blk_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(si == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 length, *, bs: int = 256, interpret: bool = True
                 ) -> jax.Array:
    """q (B, 1, H, D); k/v_cache (B, S, Hkv, D); length scalar int32 count
    of valid cache rows. Returns (B, 1, H, D). S % bs == 0."""
    b, _, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    assert h % hkv == 0 and s % bs == 0, (q.shape, k_cache.shape, bs)
    g = h // hkv
    scale = 1.0 / math.sqrt(d)

    # (B*Hkv, G/ bs, D) layouts: one grid row per (batch, kv head)
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    len_arr = jnp.full((1, 1), length, jnp.int32)

    grid = (b * hkv, s // bs)
    kern = functools.partial(flash_decode_kernel, scale=scale, bs=bs)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, si: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda i, si: (i, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda i, si: (i, si, 0)),
            pl.BlockSpec((1, bs, d), lambda i, si: (i, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda i, si: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
        interpret=interpret,
    )(len_arr, qf, kf, vf)
    return out.reshape(b, 1, h, d)
