"""optim substrate."""
