"""Cross-layer energy/latency model of the Opto-ViT accelerator.

Reproduces the paper's §IV "Performance Estimation" methodology: event counts
from the optical-core mapping (core/photonic.py: matmul_stats) x per-event
energy constants -> energy breakdown (Tuning, VCSEL, BPD, ADC, DAC, memory,
EPU) and latency breakdown (optical incl. ADC/DAC, EPU, memory) per model
variant and image size — Figs 8-11 and the Table IV KFPS/W headline.

Constants are 45 nm-class values from the cited literature (ROBIN [26],
CrossLight [28], Lightator [36] era), chosen so that the paper's two
qualitative anchors reproduce:
  * ADC is the dominant energy component (Fig. 8 pie, Tiny-96x96),
  * the headline efficiency lands at ~100.4 KFPS/W for the reference config.
KFPS/W for a pipelined accelerator equals frames-per-joule/1000, so the
headline pins E_frame ~= 9.96 uJ for the reference (Tiny, 96x96) workload.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields

from repro.core.photonic import OpticalCoreConfig, PhotonicOpStats, matmul_stats

__all__ = ["EnergyConstants", "LatencyConstants", "EnergyReport",
           "energy_of_stats", "latency_of_stats", "accumulate_matmuls",
           "kfps_per_watt", "aggregate_reports", "scale_for_bits"]


@dataclass(frozen=True)
class EnergyConstants:
    """Per-event energies in picojoules (45 nm node).

    Calibrated within the cited literature ranges (ROBIN [26], CrossLight
    [28], Lightator [36], LightBulb [34], SAR-ADC surveys) to the paper's
    two quantitative anchors for the Tiny-96x96 reference workload:
      * ADC is the dominant energy component (Fig. 8 pie), and
      * the headline lands at ~100.4 KFPS/W (E_frame ~= 9.96 uJ).
    With the Tiny-96 event counts (5.75M tunings, 3.32M VCSEL symbols,
    6.65M BPD reads, 0.888M ADC conversions, 6.66M DAC conversions,
    7.55M SRAM accesses, 5.76M EPU adds + 0.39M nonlins) these values give
    E_frame = 9.99 uJ with a 30% ADC share.
    """

    mr_tuning_pj: float = 0.26     # electro-optic MR tuning event [26], [28]
    vcsel_pj: float = 0.21         # VCSEL drive per symbol [36]
    bpd_pj: float = 0.12           # BPD + TIA read [26]
    adc_pj: float = 3.37           # 8-bit SAR ADC conversion [23], [34]
    dac_pj: float = 0.21           # 8-bit DAC conversion [26]
    sram_rd_pj: float = 0.25       # 8-bit SRAM read, 45 nm
    sram_wr_pj: float = 0.30       # 8-bit SRAM write, 45 nm
    epu_add_pj: float = 0.05       # 32-bit electronic accumulate
    epu_nonlin_pj: float = 1.0     # softmax/GELU unit per element [38]


@dataclass(frozen=True)
class LatencyConstants:
    """Stage latencies in nanoseconds.

    Calibrated to the paper's Fig. 9 qualitative ordering for Tiny-96:
    optical (incl. ADC/DAC) > memory > EPU. 8-bit SAR ADC at 500 MS/s
    (2 ns/conversion, 64-lane bank) makes the conversion wall part of the
    "optical processing delay" exactly as the paper groups it.
    """

    optical_cycle_ns: float = 0.2   # 5 GHz symbol rate (modulator bound)
    tuning_ns: float = 2.0          # MR bank tuning per tile (hidden when pipelined)
    adc_ns: float = 2.0             # 8-bit SAR conversion (500 MS/s)
    adc_lanes: int = 64             # one ADC per arm
    sram_ns: float = 1.0            # per access, 256-lane banked array
    sram_lanes: int = 256
    epu_elem_ns: float = 0.05       # nonlinear op per element (vectorized)


@dataclass
class EnergyReport:
    """Per-component energy (uJ) + latency (us) for one forward frame."""

    tuning_uj: float = 0.0
    vcsel_uj: float = 0.0
    bpd_uj: float = 0.0
    adc_uj: float = 0.0
    dac_uj: float = 0.0
    memory_uj: float = 0.0
    epu_uj: float = 0.0
    optical_us: float = 0.0
    epu_us: float = 0.0
    memory_us: float = 0.0

    @property
    def total_uj(self) -> float:
        return (self.tuning_uj + self.vcsel_uj + self.bpd_uj + self.adc_uj
                + self.dac_uj + self.memory_uj + self.epu_uj)

    @property
    def total_us(self) -> float:
        return self.optical_us + self.epu_us + self.memory_us

    def breakdown(self) -> dict:
        t = self.total_uj
        return {k: getattr(self, k) / t for k in
                ("tuning_uj", "vcsel_uj", "bpd_uj", "adc_uj", "dac_uj",
                 "memory_uj", "epu_uj")} if t > 0 else {}

    # -- streaming aggregation (serving engine accounting) -----------------
    @property
    def _FIELDS(self) -> tuple:
        # derived, not hand-listed: a future component field joins the
        # aggregation automatically instead of being silently dropped
        return tuple(f.name for f in fields(self))

    def __add__(self, other: "EnergyReport") -> "EnergyReport":
        return EnergyReport(**{f: getattr(self, f) + getattr(other, f)
                               for f in self._FIELDS})

    def __iadd__(self, other: "EnergyReport") -> "EnergyReport":
        for f in self._FIELDS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self

    def scaled(self, n: float) -> "EnergyReport":
        """Report for ``n`` identical frames (per-batch accounting)."""
        return EnergyReport(**{f: getattr(self, f) * n for f in self._FIELDS})


def energy_of_stats(stats: PhotonicOpStats, nonlin_elems: int = 0,
                    c: EnergyConstants | None = None) -> EnergyReport:
    c = c or EnergyConstants()
    r = EnergyReport()
    pj = 1e-6  # pJ -> uJ
    r.tuning_uj = stats.mr_tunings * c.mr_tuning_pj * pj
    r.vcsel_uj = stats.vcsel_cycles * c.vcsel_pj * pj
    r.bpd_uj = stats.bpd_reads * c.bpd_pj * pj
    r.adc_uj = stats.adc_conversions * c.adc_pj * pj
    r.dac_uj = stats.dac_conversions * c.dac_pj * pj
    r.memory_uj = (stats.sram_reads * c.sram_rd_pj + stats.sram_writes * c.sram_wr_pj) * pj
    r.epu_uj = (stats.electronic_adds * c.epu_add_pj + nonlin_elems * c.epu_nonlin_pj) * pj
    return r


def latency_of_stats(stats: PhotonicOpStats, nonlin_elems: int = 0,
                     lc: LatencyConstants | None = None,
                     pipelined_tuning: bool = True,
                     n_tiles: int = 0,
                     bits: float = 8.0, ref_bits: int = 8,
                     exposed_tunings: int | None = None) -> EnergyReport:
    """Fill the latency fields of an EnergyReport (us).

    With the Eq. 2 decomposition + Fig. 5 pipeline, tuning overlaps compute
    (``pipelined_tuning=True``): only the *first* tile's tuning is exposed.
    Without it, every tile tuning serializes — this is exactly the latency
    delta the decomposition buys.

    ``bits`` scales the width-sensitive stage times: an n-bit SAR
    conversion is n compare cycles and the SRAM code traffic shrinks with
    the stored width, so the ADC wall and the memory stage pay
    ``bits/ref_bits`` of the 8-bit constants. The optical symbol rate and
    the EPU are width-independent. This is the latency view of
    ``scale_for_bits`` — a mixed-precision plan now buys wall time too,
    not just energy (the serving cost model needs width-aware latency to
    rank bit plans honestly).

    ``exposed_tunings`` overrides the pipelined-tuning count — callers
    summing *partial* stats of one pipelined pass (per-layer width-aware
    accounting) pass 0 for all but one part, so the sum stays bit-exact
    to the aggregate call.
    """
    lc = lc or LatencyConstants()
    r = EnergyReport()
    ns = 1e-3  # ns -> us
    w = float(bits) / float(ref_bits)
    optical = stats.cycles * lc.optical_cycle_ns
    if exposed_tunings is None:
        exposed_tunings = 1 if pipelined_tuning else max(n_tiles, 1)
    optical += exposed_tunings * lc.tuning_ns
    optical += stats.adc_conversions * lc.adc_ns * w / lc.adc_lanes
    r.optical_us = optical * ns
    r.epu_us = nonlin_elems * lc.epu_elem_ns * ns
    r.memory_us = ((stats.sram_reads + stats.sram_writes) * w
                   / lc.sram_lanes * lc.sram_ns * ns)
    return r


def accumulate_matmuls(shapes: list[tuple[int, int, int]],
                       cfg: OpticalCoreConfig | None = None) -> tuple[PhotonicOpStats, int]:
    """Sum optical-core event stats over a list of (M, K, N) matmuls.

    Returns (stats, n_tiles_total) where n_tiles is used for the
    non-pipelined latency comparison.
    """
    cfg = cfg or OpticalCoreConfig()
    total = PhotonicOpStats()
    tiles = 0
    for (m, k, n) in shapes:
        total += matmul_stats(m, k, n, cfg)
        tiles += (-(-k // cfg.n_wavelengths)) * (-(-n // cfg.n_arms))
    return total, tiles


def scale_for_bits(rep: EnergyReport, bits: float,
                   ref_bits: int = 8) -> EnergyReport:
    """Energy report for a weight-stationary matmul run at ``bits`` width.

    The width-sensitive events are the ones a SAR-ADC/DAC/SRAM/MR-tuning
    datapath pays per *bit*: an n-bit SAR conversion is n compare cycles,
    the DAC drive and the MR tuning resolution scale with the code width,
    and the int8 SRAM traffic shrinks with the stored code — so
    ``tuning_uj``/``adc_uj``/``dac_uj``/``memory_uj`` scale by
    ``bits/ref_bits`` (the first-order model ENLighten and the LightBulb
    ADC analysis both use; constants above are calibrated at 8 bits).
    VCSEL symbols, BPD reads and EPU adds are per-event, not per-bit.

    Of the latency fields only ``memory_us`` scales here (SRAM code
    traffic is per-bit): ``optical_us`` mixes width-scaled ADC time with
    width-independent symbol cycles and cannot be decomposed after the
    fact — width-aware optical latency comes from
    ``latency_of_stats(..., bits=...)``, which is what the serving
    accounting and the control-plane cost model use.
    """
    s = float(bits) / float(ref_bits)
    out = EnergyReport(**{f: getattr(rep, f) for f in rep._FIELDS})
    out.tuning_uj *= s
    out.adc_uj *= s
    out.dac_uj *= s
    out.memory_uj *= s
    out.memory_us *= s
    return out


def kfps_per_watt(report: EnergyReport) -> float:
    """KFPS/W = frames-per-joule / 1000 = 1 / (E_frame[mJ])."""
    e_mj = report.total_uj / 1000.0
    return 1.0 / e_mj if e_mj > 0 else float("inf")


def aggregate_reports(reports) -> EnergyReport:
    """Sum an iterable of EnergyReports into one aggregate report.

    ``kfps_per_watt(aggregate.scaled(1 / n_frames))`` is then the stream's
    Table-4 metric: KFPS/W of a pipelined accelerator depends only on the
    mean energy per frame, not on host wall time.
    """
    total = EnergyReport()
    for r in reports:
        total += r
    return total
