"""Shared test config. NOTE: XLA_FLAGS must NOT be set here — tests and
benches run against the single real CPU device; only launch/dryrun.py
overrides the device count (and only in its own process)."""

import os

# keep hypothesis fast + deterministic in CI
os.environ.setdefault("JAX_ENABLE_X64", "0")

import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running training/convergence tests")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
