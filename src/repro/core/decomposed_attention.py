"""Matrix-decomposition attention dataflow (paper Eq. 2).

Standard attention computes scores as

    S = Q @ K^T,   Q = X @ W_Q,  K = X @ W_K.

On the photonic core one operand of every MatMul must be *tuned* onto MR
banks — a slow operation — so computing S requires waiting for K, re-tuning a
core with K^T, and buffering K meanwhile. The paper removes the bubble by
re-associating (ReTransformer [21] decomposition):

    Q @ K^T = Q @ (X @ W_K)^T = (Q @ W_K^T) @ X^T            (Eq. 2)

Now everything that must be tuned (W_Q, W_K^T, X^T, later softmax(S) and W_V)
is known at step start, enabling the pipelined 5-core schedule of Fig. 5. The
1/sqrt(d_k) scale is folded into the tuned W_K^T (no extra division pass).

On TPU the decomposition is still meaningful:
  * it removes K from HBM residency (one fewer (n, d_k) intermediate per
    head) — visible in the roofline bytes term;
  * it changes the FLOP profile: standard = 2*n*dm*dk (K proj) + 2*n^2*dk
    (scores); decomposed = 2*n*dk*dm (Q @ W_K^T, a (n,dk)x(dk,dm) matmul)
    + 2*n^2*dm (scores against X^T). Since dm = h*dk > dk the decomposed
    form always spends 2*n^2*(dm - dk) EXTRA score FLOPs; the paper's win
    is the removed tuning bubble + intermediate buffering (a latency/
    memory trade, quantified in benchmarks/fig9_latency.py), not FLOPs.
    Numerics are identical up to fp reassociation (tests assert allclose).

Both orderings are exposed; models pick via ``attn_impl`` config.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import ExecPolicy, QuantizedWeight, linear

__all__ = ["attention_scores_standard", "attention_scores_decomposed",
           "mhsa_standard", "mhsa_decomposed", "decomposition_flops"]


def _as_array(w) -> jnp.ndarray:
    """Raw float weight from either representation. The decomposed path
    re-derives W_K^T slices (a *re-tuning* on hardware), so a cached
    QuantizedWeight is dequantized first."""
    return w.dequantize() if isinstance(w, QuantizedWeight) else w


def attention_scores_standard(x: jnp.ndarray, wq: jnp.ndarray, wk: jnp.ndarray,
                              scale: float) -> jnp.ndarray:
    """S = (X W_Q)(X W_K)^T * scale.  x: (..., n, dm); wq/wk: (dm, dk)."""
    q = x @ wq
    k = x @ wk
    return (q @ jnp.swapaxes(k, -1, -2)) * scale


def attention_scores_decomposed(x: jnp.ndarray, wq: jnp.ndarray, wk: jnp.ndarray,
                                scale: float) -> jnp.ndarray:
    """S = ((X W_Q) (W_K^T * scale)) X^T — Eq. 2 with the scale folded in.

    The fold into W_K^T matches the paper ("our weight MR bank is tuned by
    W_K^T / sqrt(d_k) directly").
    """
    q = x @ wq                                    # (..., n, dk)
    qwk = q @ (jnp.swapaxes(wk, -1, -2) * scale)  # (..., n, dm)
    return qwk @ jnp.swapaxes(x, -1, -2)          # (..., n, n)


def _heads_split(t: jnp.ndarray, h: int) -> jnp.ndarray:
    *lead, n, d = t.shape
    return t.reshape(*lead, n, h, d // h).swapaxes(-2, -3)  # (..., h, n, dh)


def _key_mask_bias(mask: jnp.ndarray | None, dtype) -> jnp.ndarray | None:
    """(..., n) keep-mask {0,1} -> additive key-axis bias (..., 1, 1, n).

    Excluded tokens get a large negative score so softmax assigns them
    exactly-zero probability weight (exp underflows); kept rows then compute
    identical values whether dropped tokens are present (mask mode) or
    physically gathered out (top-k mode) — the serving parity contract.
    """
    if mask is None:
        return None
    return ((mask.astype(jnp.float32) - 1.0) * 1e9
            ).astype(dtype)[..., None, None, :]


def mhsa_standard(x: jnp.ndarray, params: dict, heads: int,
                  policy: ExecPolicy | None = None,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Multi-head self-attention, standard dataflow.

    params: wq/wk/wv (dm, dm), wo (dm, dm) — per-head splits taken
    internally. The four weight projections route through the backend
    dispatch (``linear``); the score and PV matmuls are activation-
    activation (dynamically tuned cores on hardware) and stay in float.
    ``mask`` (..., n) keep-mask removes tokens from the key axis (RoI mask
    mode: shapes stay static, dropped patches contribute nothing).
    """
    dm = x.shape[-1]
    dh = dm // heads
    scale = 1.0 / jnp.sqrt(dh)
    q = _heads_split(linear(x, params["wq"], policy=policy), heads)
    k = _heads_split(linear(x, params["wk"], policy=policy), heads)
    v = _heads_split(linear(x, params["wv"], policy=policy), heads)
    s = (q @ k.swapaxes(-1, -2)) * scale
    bias = _key_mask_bias(mask, s.dtype)
    if bias is not None:
        s = s + bias
    s = jax.nn.softmax(s, axis=-1)
    o = s @ v                                     # (..., h, n, dh)
    o = o.swapaxes(-2, -3).reshape(*x.shape[:-1], dm)
    return linear(o, params["wo"], policy=policy)


def mhsa_decomposed(x: jnp.ndarray, params: dict, heads: int,
                    policy: ExecPolicy | None = None,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Multi-head self-attention with Eq. 2 score dataflow (per head).

    Per head h: S_h = (X Wq_h) (Wk_h^T/sqrt(dh)) X^T. Mathematically equal to
    the standard path; only the association order differs. The Q/V/O
    projections and the per-head (Q_h @ Wk_h^T) weight matmul all route
    through the backend dispatch — W_K^T/sqrt(dh) is tuned as its own weight
    (the paper folds the scale into the MR bank directly), so it is passed
    raw and quantized at that fold point rather than reusing W_K's cache.
    """
    dm = x.shape[-1]
    dh = dm // heads
    scale = 1.0 / jnp.sqrt(dh)
    wk = _as_array(params["wk"]).reshape(dm, heads, dh)
    q = _heads_split(linear(x, params["wq"], policy=policy), heads)
    # (Q_h @ (Wk_h^T * scale)) per head: (..., h, n, dm). On quantizing
    # backends each head's transposed-scaled W_K slice is a distinct tuned
    # weight, so it routes through ``linear`` head-by-head; on the plain
    # float path a single fused einsum is numerically identical and avoids
    # `heads` separate dots.
    if (policy or ExecPolicy()).resolve_backend() == "bf16":
        qwk = jnp.einsum("...hnk,dhk->...hnd", q, wk) * scale
    else:
        qwk = jnp.stack(
            [linear(q[..., h, :, :], wk[:, h, :].T * scale, policy=policy)
             for h in range(heads)], axis=-3)
    s = jnp.einsum("...hnd,...md->...hnm", qwk, x)      # (..., h, n, n)
    bias = _key_mask_bias(mask, s.dtype)
    if bias is not None:
        s = s + bias
    s = jax.nn.softmax(s, axis=-1)
    v = _heads_split(linear(x, params["wv"], policy=policy), heads)
    o = (s @ v).swapaxes(-2, -3).reshape(*x.shape[:-1], dm)
    return linear(o, params["wo"], policy=policy)


def decomposition_flops(n: int, dm: int, dk: int) -> dict:
    """Analytic FLOP comparison of the two score dataflows (per head).

    standard:   K proj 2*n*dm*dk + scores 2*n^2*dk
    decomposed: QWk^T  2*n*dk*dm + scores 2*n^2*dm
    (Q projection and softmax(S)@V are common to both.)
    """
    std = 2 * n * dm * dk + 2 * n * n * dk
    dec = 2 * n * dk * dm + 2 * n * n * dm
    return {"standard": std, "decomposed": dec, "ratio": dec / std}
