"""Re-run the roofline analyzer over saved HLO artifacts and update the
dry-run/perf JSONs in place (used after analyzer model improvements —
no recompilation needed).

    PYTHONPATH=src python experiments/reanalyze.py
"""

import glob
import json
import os
import sys

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.roofline.hlo_analysis import analyze_module
from repro.roofline.report import roofline_terms

BASE = os.path.dirname(os.path.abspath(__file__))


def reanalyze(json_dir: str):
    n = 0
    for fn in sorted(glob.glob(os.path.join(json_dir, "*.json"))):
        r = json.load(open(fn))
        if r.get("status") != "ok":
            continue
        hlo_path = r.get("hlo_path")
        if not hlo_path or not os.path.exists(hlo_path):
            print(f"  no hlo for {os.path.basename(fn)}; skipped")
            continue
        cost = analyze_module(open(hlo_path).read())
        cfg = get_config(r["arch"])
        if r.get("overrides"):
            cfg = cfg.with_(**r["overrides"])
        shape = SHAPES[r["shape"]]
        terms = roofline_terms(cost, cfg, shape, r["n_devices"])
        r["parsed"] = {"flops": cost.flops, "bytes": cost.bytes,
                       "coll_bytes": cost.coll_bytes,
                       "coll_by_op": cost.coll_by_op,
                       "bytes_by_tag": cost.bytes_by_tag,
                       "int8_flops": cost.int8_flops}
        r["roofline"] = terms
        with open(fn, "w") as f:
            json.dump(r, f, indent=1)
        n += 1
    print(f"reanalyzed {n} records in {json_dir}")


if __name__ == "__main__":
    for d in ("dryrun", "perf"):
        reanalyze(os.path.join(BASE, d))
