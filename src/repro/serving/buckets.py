"""Token-budget bucket ladder for shape-static streaming inference.

MGNet gives every frame a different kept-patch count; JIT caches demand a
small set of static shapes. The ladder quantizes the continuum of budgets
into a few compiled bucket sizes (e.g. 25/50/75/100% of N): each frame is
routed to the *smallest* bucket that covers its budget, top-k-gathered to
exactly that size, and micro-batched with other frames in the same bucket —
so every ``forward_vit_tokens`` call hits a warm jit cache. This is the
variable-workload saturation trick dynamically-operated photonic
accelerators rely on (Lightening-Transformer): the optical core never idles
waiting for a recompile, it only ever sees the ladder's shapes.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import numpy as np

__all__ = ["BucketLadder"]


@dataclass(frozen=True)
class BucketLadder:
    """Ascending kept-patch budgets; the last entry is the dense fallback."""

    sizes: tuple[int, ...]

    def __post_init__(self):
        if not self.sizes:
            raise ValueError("empty bucket ladder")
        if list(self.sizes) != sorted(set(self.sizes)):
            raise ValueError(f"ladder must be strictly ascending: {self.sizes}")

    @staticmethod
    def from_fractions(n_patches: int,
                       fractions: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
                       ) -> "BucketLadder":
        sizes = sorted({min(n_patches, max(1, int(round(f * n_patches))))
                        for f in fractions})
        return BucketLadder(tuple(sizes))

    @property
    def cap(self) -> int:
        return self.sizes[-1]

    def route(self, budget: int) -> int:
        """Smallest bucket >= budget (clipped to the ladder cap)."""
        for s in self.sizes:
            if s >= budget:
                return s
        return self.cap

    def route_many(self, budgets) -> np.ndarray:
        """Vectorized ``route`` over an int array of budgets."""
        arr = np.asarray(self.sizes)
        pos = np.searchsorted(arr, np.asarray(budgets), side="left")
        return arr[np.minimum(pos, len(arr) - 1)]

    def trim(self, dead, keep_cap: bool = True) -> "BucketLadder":
        """New ladder without the ``dead`` sizes (``StreamAccounting.
        dead_buckets()``'s output) — every dropped entry is one compiled
        encode shape the warm-start pass no longer has to build. Budgets
        that *would* have routed to a dropped size route up to the next
        surviving bucket. With ``keep_cap`` (default) the ladder cap
        survives even when flagged dead: dropping it would silently
        down-route over-cap budgets, i.e. discard tokens a live frame
        asked for. Unknown sizes in ``dead`` are ignored; trimming every
        bucket away raises."""
        dead = set(int(k) for k in dead)
        if keep_cap:
            dead.discard(self.cap)
        kept = tuple(k for k in self.sizes if k not in dead)
        if not kept:
            raise ValueError(f"trim({sorted(dead)}) would empty the "
                             f"ladder {self.sizes}")
        return BucketLadder(kept)


class BucketHistogram:
    """Frames-per-bucket counter (the bench's bucket-hit histogram)."""

    def __init__(self, ladder: BucketLadder):
        self.ladder = ladder
        self._hits: Counter = Counter({k: 0 for k in ladder.sizes})

    def add(self, bucket: int, n: int = 1) -> None:
        self._hits[bucket] += n

    def as_dict(self) -> dict[int, int]:
        return {int(k): int(self._hits[k]) for k in self.ladder.sizes}

    @property
    def total(self) -> int:
        return sum(self._hits.values())

    def __repr__(self):
        parts = ", ".join(f"k={k}: {v}" for k, v in self.as_dict().items())
        return f"BucketHistogram({parts})"


__all__.append("BucketHistogram")
