"""Perf-knob numerics: bf16 operand paths must stay close to the f32
reference (these knobs are §Perf optimizations — correctness gates)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # seed container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.models.attention import (blockwise_attention, decode_attention,
                                    full_attention)


def _qkv(seed, b, s, h, hkv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (b, s, h, d), dtype),
            jax.random.normal(k2, (b, s, hkv, d), dtype),
            jax.random.normal(k3, (b, s, hkv, d), dtype))


@pytest.mark.parametrize("knob", [dict(p_bf16=True), dict(qk_bf16=True),
                                  dict(p_bf16=True, qk_bf16=True)])
@pytest.mark.parametrize("block_skip", [False, True])
def test_bf16_flash_paths_close_to_f32(knob, block_skip):
    q, k, v = _qkv(0, 1, 256, 4, 2, 32)
    ref = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              block_skip=block_skip)
    out = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              block_skip=block_skip, **knob)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    # correlation essentially 1 (bf16 rounding only)
    c = np.corrcoef(np.asarray(out).ravel(), np.asarray(ref).ravel())[0, 1]
    assert c > 0.999


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 17, 32]))
def test_bf16_decode_close_to_f32(seed, length):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (2, 1, 4, 16))
    kc = jax.random.normal(k2, (2, 32, 2, 16), jnp.bfloat16)
    vc = jax.random.normal(k3, (2, 32, 2, 16), jnp.bfloat16)
    a = decode_attention(q, kc, vc, length)
    b = decode_attention(q, kc, vc, length, bf16_compute=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_bf16_decode_window():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (1, 1, 2, 8))
    kc = jax.random.normal(k2, (1, 64, 2, 8), jnp.bfloat16)
    vc = jax.random.normal(k3, (1, 64, 2, 8), jnp.bfloat16)
    a = decode_attention(q, kc, vc, 50, window=16)
    b = decode_attention(q, kc, vc, 50, window=16, bf16_compute=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=3e-2, atol=3e-2)
