"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The seed container doesn't ship hypothesis (requirements-test.txt installs
it in CI, where the full shrinking/property engine runs). To keep the suite
collectable and *green* without it, this module re-implements the tiny
strategy surface the tests use — integers / floats / lists / sampled_from —
and a ``given`` that runs the test body over a fixed-seed sample sweep.
No shrinking, no database; just deterministic example generation.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 10
_SEED = 0xA11CE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, allow_nan: bool = True,
               allow_infinity: bool = True, width: int = 64) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int | None = None) -> _Strategy:
        def draw(r):
            hi = max_size if max_size is not None else min_size + 10
            return [elements.draw(r) for _ in range(r.randint(min_size, hi))]
        return _Strategy(draw)


def given(*strategies: _Strategy):
    def deco(fn):
        # strategies fill the TRAILING parameters (hypothesis fills
        # positionally from the right so leading fixtures/self pass
        # through); bind them by name because pytest delivers fixtures as
        # keyword arguments.
        params = list(inspect.signature(fn).parameters.values())
        drawn_names = [p.name for p in params[len(params) - len(strategies):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(_SEED)
            for _ in range(wrapper._max_examples):
                drawn = {nm: s.draw(rnd)
                         for nm, s in zip(drawn_names, strategies)}
                fn(*args, **kwargs, **drawn)
        # hide the strategy-filled (trailing) parameters from pytest's
        # fixture resolution — like hypothesis, only leading params (if
        # any) remain visible as fixtures.
        wrapper.__signature__ = inspect.Signature(
            params[: len(params) - len(strategies)])
        del wrapper.__wrapped__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        wrapper._fallback_given = True
        return wrapper
    return deco


def settings(max_examples: int | None = None, **_ignored):
    def deco(fn):
        if max_examples and getattr(fn, "_fallback_given", False):
            fn._max_examples = max_examples
        return fn
    return deco
