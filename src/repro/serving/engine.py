"""Streaming video serving engine: ingest -> gate -> bucket -> encode -> account.

The paper's deployment scenario end to end on the photonic backends:

  1. **ingest** — chunks of consecutive frames from ``data.pipeline``
     (``VideoStream``), double-buffered to the device
     (``prefetch_to_device``) so H2D transfer overlaps compute;
  2. **RoI gate** — MGNet region scores with temporal mask reuse
     (``TemporalMaskCache``): re-score only every ``mask_refresh`` frames or
     when the frame-delta trigger fires, reuse the cached mask otherwise;
  3. **token-budget bucketing** — each frame's kept-patch budget
     (``mask_budget``) routes to the smallest ladder bucket covering it
     (``BucketLadder``); a shared per-chunk stable score order (the
     ``select_topk_patches`` ordering) gathers exactly that many tokens;
     same-bucket frames micro-batch (``MicroBatcher``) so every encode is
     shape-static and jit-cache-warm;
  4. **encode** — ``forward_vit_tokens`` on the gathered tokens (compute
     scales with the bucket, the paper's linear energy lever); with
     ``--attn-backend flash`` the attention core runs the fused RoI-masked
     flash kernel (and, on ``photonic_pallas`` with cached weights, the
     whole MHSA block collapses into one jit entry point —
     ``kernels/ops.py::fused_roi_attention_prequant``);
  5. **account** — per-flush ``EnergyReport`` from
     ``vit_matmul_shapes(kept_patches=k)``, surfaced live as frames/s (host
     wall clock) and KFPS/W (accelerator model, the Table-4 metric).

CLI (streams >= 64 frames on the Pallas kernel path):

    PYTHONPATH=src python -m repro.serving.engine --smoke \\
        --backend photonic_pallas
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, smoke_variant
from repro.core.backend import (ExecPolicy, available_backends,
                                prepare_params)
from repro.core.mgnet import MGNetConfig, mask_budget, mgnet_scores
from repro.data.pipeline import VideoStream, prefetch_to_device
from repro.models.vit import (embed_patches, forward_vit_masked,
                              forward_vit_tokens, init_vit)
from repro.serving.accounting import StreamAccounting
from repro.serving.buckets import BucketHistogram, BucketLadder
from repro.serving.mask_cache import TemporalMaskCache
from repro.serving.scheduler import MicroBatcher

__all__ = ["ServingConfig", "StreamResult", "ServingEngine", "main"]


def _gather_topk_rows(tokens, order, keep: int):
    """(C, N, d) tokens + (C, N) descending score order -> (C, keep, d).

    The top-``keep`` prefix of the shared order is exactly what
    ``select_topk_patches`` would select (same stable argsort), without
    re-sorting per bucket.
    """
    return jnp.take_along_axis(tokens, order[:, :keep, None], axis=1)


@dataclass(frozen=True)
class ServingConfig:
    """Engine knobs (the ladder fractions are quantized to patch counts)."""

    bucket_fractions: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
    microbatch: int = 4
    chunk: int = 8               # frames per ingest transfer
    mask_refresh: int = 8        # re-score MGNet at least every k frames
    delta_threshold: float = 0.15
    prefetch_depth: int = 2
    report_every: int = 4        # live metrics cadence (chunks)
    force_bucket: float = 0.0    # > 0: pin every frame's budget to this
    #                              fraction of N (the paper's fixed
    #                              keep-ratio inference; also the controlled
    #                              operating point for skip-ratio benchmarks)
    one_shape: bool = False      # fixed-sensor-buffer mode: every encode is
    #                              (microbatch, ladder.cap, d) with the
    #                              score-ordered tokens and a static packed
    #                              kept-count (kv_len) per bucket — one
    #                              token shape, |ladder| kv_len-specialized
    #                              jits; the flash attention backend skips
    #                              the pruned tail's score FLOPs


@dataclass
class StreamResult:
    """What one ``run`` streamed, measured two ways: host wall clock
    (functional sim throughput) and accelerator model (KFPS/W)."""

    frames: int = 0
    wall_s: float = 0.0
    scored_frames: int = 0
    reused_frames: int = 0
    bucket_hits: dict = field(default_factory=dict)
    bucket_launches: dict = field(default_factory=dict)  # k -> encode flushes
    kfps_per_watt: float = 0.0
    mean_frame_uj: float = 0.0
    dense_kfps_per_watt: float = 0.0
    predictions: dict = field(default_factory=dict)   # frame_idx -> class

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def energy_saved(self) -> float:
        if self.dense_kfps_per_watt <= 0 or self.kfps_per_watt <= 0:
            return 0.0
        return 1.0 - self.dense_kfps_per_watt / self.kfps_per_watt

    def summary(self) -> str:
        hist = " ".join(f"k={k}:{v}" for k, v in self.bucket_hits.items())
        return (f"{self.frames} frames in {self.wall_s:.2f}s -> "
                f"{self.fps:.1f} frames/s | model {self.kfps_per_watt:.1f} "
                f"KFPS/W ({self.mean_frame_uj:.2f} uJ/frame, "
                f"{self.energy_saved:+.1%} vs dense) | mgnet scored "
                f"{self.scored_frames}/{self.frames} | buckets: {hist}")


class ServingEngine:
    """Single-stream serving engine over one ViT + MGNet parameter set."""

    def __init__(self, cfg: ArchConfig, serve_cfg: ServingConfig | None = None,
                 params: dict | None = None, n_classes: int = 10, seed: int = 0):
        if not cfg.mgnet:
            raise ValueError("serving engine needs cfg.mgnet=True "
                             "(the RoI gate is the pipeline's first stage)")
        self.cfg = cfg
        self.serve_cfg = serve_cfg or ServingConfig()
        self.policy = ExecPolicy.from_cfg(cfg, training=False)
        self.n_patches = (cfg.img_size // cfg.patch) ** 2
        self.ladder = BucketLadder.from_fractions(
            self.n_patches, self.serve_cfg.bucket_fractions)
        self.mcfg = MGNetConfig(patch=cfg.patch, img_size=cfg.img_size,
                                embed=cfg.mgnet_embed, heads=cfg.mgnet_heads)

        if params is None:
            params = init_vit(jax.random.PRNGKey(seed), cfg, n_classes)
        if self.policy.is_photonic():
            # MR tuning happens once, before the stream starts.
            params = prepare_params(params, bits=cfg.quant_bits or 8)
        self.params = params

        pol = self.policy
        self._embed = jax.jit(
            lambda p, f: embed_patches(p, f, cfg, pol))
        self._score = jax.jit(
            lambda p, f: mgnet_scores(p["mgnet"], f, self.mcfg, pol))
        self._encode = jax.jit(
            lambda p, t: forward_vit_tokens(p, t, cfg, pol)[0])
        self._encode_dense = jax.jit(
            lambda p, f, m: forward_vit_masked(p, f, m, cfg, pol)[0])
        # one stable descending argsort per chunk (the ordering
        # select_topk_patches defines), then per-bucket static slices of it
        # — not a fresh full-chunk sort + gather per unique bucket
        self._order = jax.jit(
            lambda s: jnp.argsort(s, axis=-1, stable=True, descending=True))
        self._gather = {
            k: jax.jit(functools.partial(_gather_topk_rows, keep=k))
            for k in self.ladder.sizes}
        self._encode_one = {}
        if self.serve_cfg.one_shape:
            def _one(k: int):
                return jax.jit(lambda p, t: forward_vit_tokens(
                    p, t, cfg, pol, kv_len=k)[0])
            self._encode_one = {k: _one(int(k)) for k in self.ladder.sizes}

    # -- pipeline stages ---------------------------------------------------

    def _ingest(self, stream: VideoStream, n_frames: int, start: int):
        """Chunked host batches with the frames double-buffered to device.

        Each yielded batch carries both views of the frames: ``frames`` is
        the (possibly still in-flight) device copy the embed/encode jits
        consume, ``frames_host`` the sensor-side numpy the gating walk
        reads — one H2D per chunk, no D2H ever.
        """
        sc = self.serve_cfg
        chunks = (n_frames + sc.chunk - 1) // sc.chunk
        it = stream.chunks(sc.chunk, start)
        gen = (next(it) for _ in range(chunks))
        return prefetch_to_device(gen, depth=sc.prefetch_depth,
                                  keys=("frames",))

    def _drive(self, stream: VideoStream, n_frames: int, start: int,
               on_chunk, on_drain=None, verbose: bool = False,
               pending=None, ladder_sizes=None) -> tuple[StreamResult,
                                                         StreamAccounting]:
        """The frame loop shared by ``run`` and ``run_dense``: ingest ->
        RoI-gate (temporal mask reuse) -> per-mode chunk callback ->
        deferred prediction materialization -> common StreamResult fields.

        ``on_chunk(frames, idxs, valid, scores_np, acct, deferred)`` does
        the mode-specific encode work (bucket-route-batch or dense) and
        appends ``(frame_idx_list, logits)`` pairs to ``deferred`` —
        materialized only after the stream so host pre/post work overlaps
        device encodes (async dispatch). ``on_drain(acct, deferred)``
        flushes mode-held state at end of stream; ``pending`` is an
        optional callable for the verbose status line.

        Ingest stays in full ``chunk``-sized transfers (every device shape
        static); when n_frames is not a chunk multiple, the trailing
        frames of the last chunk are gated but never routed, encoded,
        predicted or accounted (``valid``).
        """
        sc = self.serve_cfg
        limit = start + n_frames
        cache = TemporalMaskCache(sc.mask_refresh, sc.delta_threshold)
        acct = StreamAccounting(self.cfg, ladder_sizes=ladder_sizes)
        res = StreamResult()
        score_fn = lambda f: self._score(self.params, f)

        t0 = time.time()
        done = 0
        deferred = []     # (frame_idx list, per-frame argmax device array)
        for ci, batch in enumerate(self._ingest(stream, n_frames, start)):
            frames = batch["frames"]                       # device view
            idxs = batch["frame_idx"]
            valid = idxs < limit
            scores_np, n_scored = cache.gate(batch["frames_host"], idxs,
                                             score_fn, eligible=valid)
            acct.add_mgnet(n_scored)
            on_chunk(frames, idxs, valid, scores_np, acct, deferred)
            done += int(valid.sum())
            if verbose and (ci + 1) % sc.report_every == 0:
                dt = time.time() - t0
                print(f"[serve] {done:>5d} frames  {done / dt:7.1f} frames/s  "
                      f"{acct.kfps_per_watt:7.1f} KFPS/W  "
                      f"(mgnet reuse {cache.reuse_rate:.0%}, "
                      f"pending {pending() if pending else 0})")

        if on_drain is not None:
            on_drain(acct, deferred)
        for fidx, preds in deferred:
            for fi, p in zip(fidx, np.asarray(preds)):
                if int(fi) < limit:
                    res.predictions[int(fi)] = int(p)
        res.wall_s = time.time() - t0
        res.frames = acct.frames
        res.scored_frames = cache.scored_frames
        res.reused_frames = cache.reused_frames
        res.bucket_launches = dict(acct.bucket_launches)
        res.kfps_per_watt = acct.kfps_per_watt
        res.mean_frame_uj = acct.mean_frame.total_uj
        res.dense_kfps_per_watt = acct.dense_baseline_kfps_per_watt()
        return res, acct

    def run(self, stream: VideoStream, n_frames: int = 64, start: int = 0,
            verbose: bool = False) -> StreamResult:
        """Stream exactly ``n_frames`` frames through the bucketed path."""
        sc = self.serve_cfg
        batcher = MicroBatcher(sc.microbatch)
        hist = BucketHistogram(self.ladder)

        def on_chunk(frames, idxs, valid, scores_np, acct, deferred):
            toks = self._embed(self.params, frames)        # (C, N, d)
            # budget decision on host: scores are already host-resident
            # from the mask cache, and mask_budget stays in numpy for them
            if sc.force_bucket > 0:
                pin = self.ladder.route(
                    int(round(sc.force_bucket * self.n_patches)))
                routes = np.full(frames.shape[0], pin)
            else:
                routes = self.ladder.route_many(
                    mask_budget(scores_np, self.mcfg.t_reg))

            order = self._order(jnp.asarray(scores_np))    # (C, N), shared
            permuted = (self._gather[self.ladder.cap](toks, order)
                        if sc.one_shape else None)         # (C, cap, d)
            for k in np.unique(routes[valid]):
                k = int(k)
                sel = np.flatnonzero((routes == k) & valid)
                # one-shape mode ships the shared cap-size permutation and
                # prunes via the static per-bucket kv_len at encode time
                pruned = (permuted if sc.one_shape
                          else self._gather[k](toks, order))   # (C, k, d)
                hist.add(k, len(sel))
                group = pruned if len(sel) == frames.shape[0] else pruned[sel]
                for flush in batcher.push_many(
                        k, group, [int(idxs[i]) for i in sel]):
                    self._finish(flush, acct, deferred)

        def on_drain(acct, deferred):
            for flush in batcher.drain():
                self._finish(flush, acct, deferred)

        res, acct = self._drive(stream, n_frames, start, on_chunk, on_drain,
                                verbose, pending=lambda: batcher.pending,
                                ladder_sizes=self.ladder.sizes)
        res.bucket_hits = hist.as_dict()
        if verbose:
            print("[serve]", acct.summary())
        return res

    def _finish(self, flush, acct: StreamAccounting, deferred: list):
        if self.serve_cfg.one_shape:
            logits = self._encode_one[flush.bucket](self.params, flush.tokens)
        else:
            logits = self._encode(self.params, flush.tokens)
        # one-shape encodes are billed at bucket k, same as gathered mode:
        # the packed prefix is contiguous, so the accelerator's static
        # schedule streams only the k live rows through every core (unlike
        # scattered mask-mode, which cannot pack and is billed at N — see
        # run_dense). The host-side cap-size compute is a functional-sim
        # artifact (and with --ffn-backend fused the FFN drops it too: the
        # packed kv_len prunes dead token rows out of both matmuls).
        acct.add_encode(flush.bucket, flush.n_real)
        deferred.append((flush.frame_idx,
                         jnp.argmax(logits[:flush.n_real], -1)))

    def run_dense(self, stream: VideoStream, n_frames: int = 64,
                  start: int = 0) -> StreamResult:
        """Mask-mode dense baseline: identical gating, but every frame is
        encoded at all N patches with the RoI mask applied on the attention
        key axis — compute is *not* reduced. The bucketed path's frames/s
        win over this is the serving subsystem's raison d'etre."""

        def on_chunk(frames, idxs, valid, scores_np, acct, deferred):
            mask = (jax.nn.sigmoid(jnp.asarray(scores_np))
                    > self.mcfg.t_reg).astype(jnp.float32)
            logits = self._encode_dense(self.params, frames, mask)
            acct.add_encode(self.n_patches, int(valid.sum()))
            deferred.append((idxs, jnp.argmax(logits, -1)))

        res, _ = self._drive(stream, n_frames, start, on_chunk)
        res.bucket_hits = {self.n_patches: res.frames}
        return res


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _smoke_cfg(backend: str, attn_backend: str = "",
               ffn_backend: str = "") -> ArchConfig:
    from repro.configs.opto_vit import get_config
    cfg = smoke_variant(get_config("tiny")).with_(
        mgnet=True, mgnet_keep_ratio=0.5, mgnet_embed=32, mgnet_heads=2)
    if backend:
        cfg = cfg.with_(matmul_backend=backend)
    if attn_backend:
        cfg = cfg.with_(attn_backend=attn_backend)
    if ffn_backend:
        cfg = cfg.with_(ffn_backend=ffn_backend)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU config (32x32 frames, 4 layers)")
    ap.add_argument("--variant", default="tiny")
    ap.add_argument("--img-size", type=int, default=96)
    ap.add_argument("--backend", default="photonic_pallas",
                    help=f"matmul backend ({', '.join(available_backends())})")
    ap.add_argument("--attn-backend", default="", choices=["", "xla", "flash"],
                    help="attention core: xla (materialized scores, default) "
                         "or flash (fused RoI-masked Pallas kernel)")
    ap.add_argument("--ffn-backend", default="", choices=["", "xla", "fused"],
                    help="GELU-MLP core: xla (composed two-linear, default) "
                         "or fused (fused int8 photonic FFN kernel — with "
                         "photonic_pallas + cached weights the hidden state "
                         "never leaves VMEM, and --one-shape prunes dead "
                         "token rows out of both FFN matmuls)")
    ap.add_argument("--frames", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--mask-refresh", type=int, default=8)
    ap.add_argument("--delta-threshold", type=float, default=0.15)
    ap.add_argument("--buckets", default="0.25,0.5,0.75,1.0")
    ap.add_argument("--one-shape", action="store_true",
                    help="fixed-sensor-buffer mode: encode all frames at "
                         "the ladder cap with a static packed kept-count "
                         "per bucket (flash backend skips the dead tail)")
    ap.add_argument("--cut-every", type=int, default=32)
    ap.add_argument("--compare-dense", action="store_true",
                    help="also run the mask-mode dense baseline")
    ap.add_argument("--json", default="",
                    help="write the StreamResult to this path")
    args = ap.parse_args(argv)

    if args.backend and args.backend not in available_backends():
        raise SystemExit(f"unknown backend {args.backend!r}; "
                         f"choose from {available_backends()}")
    if args.smoke:
        cfg = _smoke_cfg(args.backend, args.attn_backend, args.ffn_backend)
    else:
        from repro.configs.opto_vit import get_config
        cfg = get_config(args.variant, img_size=args.img_size,
                         mgnet=True).with_(matmul_backend=args.backend,
                                           attn_backend=args.attn_backend,
                                           ffn_backend=args.ffn_backend)

    serve_cfg = ServingConfig(
        bucket_fractions=tuple(float(f) for f in args.buckets.split(",")),
        microbatch=args.microbatch, chunk=args.chunk,
        mask_refresh=args.mask_refresh,
        delta_threshold=args.delta_threshold, one_shape=args.one_shape)
    engine = ServingEngine(cfg, serve_cfg)
    print(f"[serve] {cfg.name} {cfg.img_size}x{cfg.img_size} "
          f"backend={engine.policy.resolve_backend()} "
          f"attn={engine.policy.resolve_attn_backend()} "
          f"ffn={engine.policy.resolve_ffn_backend()} "
          f"ladder={list(engine.ladder.sizes)} of {engine.n_patches} patches")

    stream = VideoStream(img_size=cfg.img_size, patch=cfg.patch,
                         cut_every=args.cut_every)
    res = engine.run(stream, n_frames=args.frames, verbose=True)
    print("[serve]", res.summary())

    if args.compare_dense:
        dense = engine.run_dense(stream, n_frames=args.frames)
        print("[serve] dense baseline:", dense.summary())
        if dense.fps > 0:
            print(f"[serve] bucketed speedup: {res.fps / dense.fps:.2f}x "
                  "frames/s over mask-mode dense")

    if args.json:
        payload = {
            "frames": res.frames, "fps": res.fps,
            "kfps_per_watt": res.kfps_per_watt,
            "mean_frame_uj": res.mean_frame_uj,
            "bucket_hits": res.bucket_hits,
            "bucket_launches": res.bucket_launches,
            "scored_frames": res.scored_frames,
            "reused_frames": res.reused_frames,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[serve] wrote {args.json}")
    return res


if __name__ == "__main__":
    main()
