"""Shared neural-net building blocks (pure JAX, pytree params).

Every matmul in the framework funnels through ``linear`` so the paper's
execution modes apply uniformly. ``linear``/``ExecPolicy`` live in
core/backend.py (the matmul backend registry + quantize-once weight cache:
bf16 | qat | photonic_sim | photonic_pallas, selected by
``ArchConfig.matmul_backend``) and are re-exported here for the model
layers and all existing importers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import ExecPolicy, QuantizedWeight, linear, matmul
from repro.distributed.sharding import shard

__all__ = ["linear", "matmul", "rmsnorm", "layernorm", "rope", "apply_rope",
           "embedding_lookup", "causal_conv1d", "he_init", "lecun_init",
           "ExecPolicy", "QuantizedWeight"]


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g + b


def rope(positions: jnp.ndarray, head_dim: int,
         theta: float = 500000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rotary embedding tables. positions: (..., seq). Returns cos/sin of
    shape (..., seq, head_dim/2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]   # broadcast over heads
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Gather rows; with a vocab-sharded table XLA turns this into a
    one-hot-free dynamic-gather + collective."""
    return jnp.take(table, ids, axis=0)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: jnp.ndarray | None = None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C).

    Training/prefill: returns (y, final_state) where final_state is the last
    K-1 inputs (for handoff to decode). Decode (S==1 with state): uses the
    rolling state. This is the Mamba/Griffin short conv.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)            # (B, S+K-1, C)
    y = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(k))
    new_state = xp[..., -(k - 1):, :]
    return y.astype(x.dtype), new_state


def he_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def lecun_init(key, shape, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(1.0 / fan_in)).astype(dtype)
