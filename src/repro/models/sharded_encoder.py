"""Model-sharded single-jit encoder: the fused serving hot path under
``shard_map`` over a 2-D ("data", "model") mesh.

The fused encoder in models/vit.py runs the whole trunk as one jit —
fused RoI attention + fused int8 FFN scanned over the stacked layer
weights. This module re-traces exactly that graph *inside* one
``shard_map`` so big ViT variants whose weights (or activations) outgrow
one device keep the single-dispatch serving path:

  * attention heads are embarrassingly parallel: wq/wk/wv **column-shard**
    over "model" (output columns are head-major), each shard runs the
    flash-attention core on its own head group, the merged head outputs
    all-gather (exact data movement) and the wo projection runs whole on
    every shard;
  * the FFN hidden dim column-shards w1 / row-shards w2 with one int32
    psum over the d_ff partial sums (kernels/fused_ffn.fused_ffn_sharded);
  * the encode batch still splits over "data" whenever the flush batch
    divides the axis (otherwise it replicates — both are bitwise-safe).

Bitwise parity with the unsharded fused encoder is a *construction*, not
a tolerance: every per-launch activation absmax scope is restored to the
global tensor via ``collectives.replicated_absmax_scale`` (max is exact),
the FFN's int32 partial-sum reduction is lossless (``exact_int_psum``),
and every dequant runs *where the unsharded twin runs it*. That last
point is load-bearing: the attention projections and the head dequantize
**inside** ``photonic_matmul_int8``'s grid loop (the serving path's
kernel), so the sharded trace mirrors ``ops.photonic_matmul_prequant``
op-for-op with only the absmax scope widened (``_pallas_proj``) — an XLA
int-dot + detached epilogue computes the same math but fuses differently
against the surrounding graph (a 1-ulp FMA-class divergence the
downstream requant amplifies into code flips). The FFN reference is the
XLA twin (``fused_ffn_xla``), so there ``fused_ffn_sharded`` keeps the
int-dot + ``_dequant_epilogue`` construction. wo is *not* row-sharded:
its dequant lives inside the kernel, so a row split would need an int32
psum between accumulate and dequant — unreachable without changing the
reference graph; all-gathering the (small) merged head activations and
replicating the wo matmul keeps the bitwise contract instead. Each shard
therefore computes bit-identical slices of the very arrays the 1-device
path holds, and the assembled logits match bitwise
(tests/test_multistream.py pins this in a forced-4-device subprocess).

Weights enter the shard_map as plain {codes, scale} dicts (QuantizedWeight
is unwrapped inside the jit, raw wo/head leaves are resolved there with
the same quantize-once arithmetic the unsharded dispatch applies), so the
in_specs tree stays a static literal per layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import quant
from repro.core.backend import QuantizedWeight, _resolve_wq, _weight_bits
from repro.distributed.collectives import replicated_absmax_scale
from repro.kernels.flash_attention import fused_masked_attention
from repro.kernels.fused_ffn import fused_ffn_sharded
from repro.kernels.ops import pad_to
from repro.kernels.photonic_matmul import photonic_matmul_int8
from repro.models.layers import ExecPolicy, layernorm

__all__ = ["sharded_encode", "sharded_encode_ineligible_reason",
           "sharded_encoder_cache_size"]

_SCALE_AXES = ("data", "model")


def _pallas_proj(x2: jnp.ndarray, wq: jnp.ndarray, sw: jnp.ndarray, *,
                 bits: int, interpret: bool,
                 scale_axes=_SCALE_AXES) -> jnp.ndarray:
    """``ops.photonic_matmul_prequant`` inlined for use inside
    ``shard_map``: same quantize -> pad -> ``photonic_matmul_int8`` (with
    its in-kernel dequant) dataflow, with the per-launch activation absmax
    scope widened from the local shard to the global tensor
    (``replicated_absmax_scale`` — a pmax, exact). Per-column outputs are
    independent in the kernel, so with ``wq`` holding this shard's column
    slice the result is bitwise the matching column slice of the unsharded
    call; with ``wq`` whole (replicated) it is the whole unsharded result.

    x2 (M, K) f32; wq (K, N) int8 codes; sw (N,) f32. Returns (M, N) f32.
    """
    m, n = x2.shape[0], wq.shape[1]
    sx = replicated_absmax_scale(x2, bits, scale_axes)
    xq = quant.quantize(x2, sx, bits=bits)
    xqp = pad_to(pad_to(xq, 128, 0), 128, 1)
    wqp = pad_to(pad_to(wq, 128, 0), 128, 1)
    swp = pad_to(sw, 128, 0)
    out = photonic_matmul_int8(xqp, wqp, sx.reshape(()), swp,
                               bm=128, bn=128, bk=128, interpret=interpret)
    return out[:m, :n]


def _encoder_bits(params: dict, policy: ExecPolicy) -> dict[str, int]:
    """Static per-weight bit widths for the sharded trace. Raises
    ValueError (the ineligibility reason) when any stacked weight carries
    a per-layer bits tuple — the sharded encoder compiles ONE scan, so a
    mixed plan would need the segmented-scan machinery sliced per run;
    mixed plans fall back to the unsharded fused path instead."""
    blocks = params["blocks"]
    bits = {}
    for name in ("wq", "wk", "wv", "wo"):
        bits[name] = _weight_bits(blocks["attn"][name], policy)
    for name in ("w1", "w2"):
        bits[name] = _weight_bits(blocks["ffn"][name], policy)
    bits["head"] = _weight_bits(params["head"], policy)
    return bits


def sharded_encode_ineligible_reason(params: dict, cfg: ArchConfig,
                                     policy: ExecPolicy, ctx) -> str | None:
    """None when the fused encoder can additionally run model-sharded
    under ``ctx`` (callers check fused eligibility first), else a
    human-readable reason for staying on the unsharded fused jit."""
    if ctx is None:
        return "no sharding context installed"
    mesh = ctx.mesh
    axes = tuple(mesh.axis_names)
    if axes != ("data", "model"):
        return (f"mesh axes {axes!r} are not the 2-D ('data', 'model') "
                f"serving layout (launch.mesh.make_serving_mesh(model=M))")
    m = mesh.shape["model"]
    if m < 2:
        return "model axis has size 1 — nothing to shard"
    if cfg.n_heads % m:
        return (f"n_heads={cfg.n_heads} not divisible by the model axis "
                f"({m}) — heads cannot split evenly")
    if cfg.d_ff % m:
        return (f"d_ff={cfg.d_ff} not divisible by the model axis ({m}) — "
                f"the FFN hidden dim cannot split evenly")
    try:
        _encoder_bits(params, policy)
    except ValueError as e:
        return str(e)
    return None


def _qw_dict(w, bits: int) -> dict:
    """{int8 codes, f32 scale} for a cached or raw weight — the same
    ``_resolve_wq`` arithmetic the unsharded 2-D dispatch applies, run
    inside the jit so raw stacked leaves (wo, head) quantize identically
    to the per-layer slices the reference scan resolves."""
    wq, sw = _resolve_wq(w, bits)
    return {"wq": wq, "scale": sw}


def _enc_tree(params: dict, bits: dict[str, int]) -> dict:
    """The encoder subtree the shard_map consumes: QuantizedWeight leaves
    unwrapped to plain dicts (pytree aux data cannot ride through
    in_specs), cls + its pos row pre-summed (elementwise — bitwise equal
    to broadcasting then adding)."""
    blocks = params["blocks"]
    attn = blocks["attn"]
    ffn = blocks["ffn"]
    return {
        "cls_pos": params["cls"] + params["pos"][:, :1],
        "blocks": {
            "ln1_g": blocks["ln1_g"], "ln1_b": blocks["ln1_b"],
            "attn": {name: _qw_dict(attn[name], bits[name])
                     for name in ("wq", "wk", "wv", "wo")},
            "ln2_g": blocks["ln2_g"], "ln2_b": blocks["ln2_b"],
            "ffn": {"w1": _qw_dict(ffn["w1"], bits["w1"]),
                    "b1": ffn["b1"],
                    "w2": _qw_dict(ffn["w2"], bits["w2"]),
                    "b2": ffn["b2"]},
        },
        "final_ln_g": params["final_ln_g"],
        "final_ln_b": params["final_ln_b"],
        "head": _qw_dict(params["head"], bits["head"]),
    }


def _enc_specs() -> dict:
    """in_specs tree matching ``_enc_tree``: head-major output columns
    (wq/wk/wv, w1) shard over "model", the w2 contraction rows (= d_ff)
    shard over "model" with replicated output scales, and everything else
    — including wo, whose in-kernel dequant forbids a row split (module
    docstring) — replicates. Mirrors what MODEL_RULES + vit_logical_axes
    place on the devices, so the dispatch edge moves no bytes."""
    col = P(None, None, "model")       # stacked codes/scales, cols = heads
    row = P(None, "model", None)       # stacked codes, rows = d_ff
    rep3 = P(None, None, None)
    rep2 = P(None, None)
    return {
        "cls_pos": rep3,
        "blocks": {
            "ln1_g": rep2, "ln1_b": rep2,
            "attn": {"wq": {"wq": col, "scale": col},
                     "wk": {"wq": col, "scale": col},
                     "wv": {"wq": col, "scale": col},
                     "wo": {"wq": rep3, "scale": rep3}},
            "ln2_g": rep2, "ln2_b": rep2,
            "ffn": {"w1": {"wq": col, "scale": col},
                    "b1": P(None, "model"),
                    "w2": {"wq": row, "scale": rep3},
                    "b2": rep2},
        },
        "final_ln_g": P(None), "final_ln_b": P(None),
        "head": {"wq": rep2, "scale": rep2},
    }


# (cfg, policy fingerprint, bits signature, kv_len, has_mask, mesh,
#  batch-sharded?) -> jitted sharded encode entry. Same lifecycle as
# models.vit._FUSED_ENCODER_JITS — a handful of entries per process.
_SHARDED_ENCODER_JITS: dict = {}


def sharded_encoder_cache_size() -> int:
    """How many sharded-encoder jits this process built — benches assert
    it grew to prove the sharded path (not a silent fallback) served."""
    return len(_SHARDED_ENCODER_JITS)


def _build_jit(cfg: ArchConfig, policy: ExecPolicy, bits: dict[str, int],
               kv_len: int | None, has_mask: bool, mesh,
               batch_sharded: bool):
    n_heads, d, eps = cfg.n_heads, cfg.d_model, cfg.norm_eps
    m_shards = mesh.shape["model"]
    h_loc = n_heads // m_shards
    dh = d // n_heads
    d_loc = h_loc * dh
    interpret = policy.interpret
    attn_kv = None if kv_len is None else int(kv_len) + 1   # + live [cls]
    ffn_live = attn_kv

    def body(enc, tokens, mask):
        b, _, _ = tokens.shape
        x = jnp.concatenate(
            [jnp.broadcast_to(enc["cls_pos"], (b, 1, d))
             .astype(tokens.dtype), tokens], axis=1)
        kmask = None
        if mask is not None:
            kmask = jnp.concatenate(
                [jnp.ones((b, 1), mask.dtype), mask], axis=1)

        def step(carry, lp):
            n = carry.shape[1]
            h = layernorm(carry, lp["ln1_g"], lp["ln1_b"], eps)
            x2 = h.astype(jnp.float32).reshape(-1, d)
            qkv = []
            for name in ("wq", "wk", "wv"):
                wd = lp["attn"][name]
                y = _pallas_proj(x2, wd["wq"], wd["scale"].reshape(-1),
                                 bits=bits[name], interpret=interpret)
                qkv.append(y.reshape(b, n, d_loc).astype(h.dtype)
                           .reshape(b, n, h_loc, dh).transpose(0, 2, 1, 3))
            o = fused_masked_attention(qkv[0], qkv[1], qkv[2], kmask,
                                       kv_len=attn_kv, interpret=interpret)
            merged = o.transpose(0, 2, 1, 3).reshape(b, n, d_loc)
            # exact data movement: every shard assembles the full
            # head-major (b, n, d) activation, then runs the whole wo
            # projection (in-kernel dequant — see module docstring)
            full = jax.lax.all_gather(merged, "model", axis=2, tiled=True)
            wd = lp["attn"]["wo"]
            ao = _pallas_proj(full.astype(jnp.float32).reshape(-1, d),
                              wd["wq"], wd["scale"].reshape(-1),
                              bits=bits["wo"], interpret=interpret)
            carry = carry + ao.reshape(b, n, d).astype(h.dtype) \
                              .astype(carry.dtype)
            h2 = layernorm(carry, lp["ln2_g"], lp["ln2_b"], eps)
            f = fused_ffn_sharded(
                h2, lp["ffn"]["w1"]["wq"],
                lp["ffn"]["w1"]["scale"].reshape(-1), lp["ffn"]["b1"],
                lp["ffn"]["w2"]["wq"],
                lp["ffn"]["w2"]["scale"].reshape(-1), lp["ffn"]["b2"],
                bits=(bits["w1"], bits["w2"]), live_rows=ffn_live,
                model_axis="model", scale_axes=_SCALE_AXES)
            return carry + f, None

        fn = jax.checkpoint(step) if cfg.remat else step
        x, _ = jax.lax.scan(fn, x, enc["blocks"])
        x = layernorm(x, enc["final_ln_g"], enc["final_ln_b"], eps)
        logits = _pallas_proj(x[:, 0].astype(jnp.float32),
                              enc["head"]["wq"],
                              enc["head"]["scale"].reshape(-1),
                              bits=bits["head"], interpret=interpret)
        return logits.astype(x.dtype)

    tok_spec = P("data", None, None) if batch_sharded else P(None, None, None)
    out_spec = P("data", None) if batch_sharded else P(None, None)
    mask_spec = P("data", None) if batch_sharded else P(None, None)
    specs = _enc_specs()

    if has_mask:
        smapped = shard_map(body, mesh=mesh,
                            in_specs=(specs, tok_spec, mask_spec),
                            out_specs=out_spec, check_rep=False)

        def run(params, tokens, patch_mask):
            return smapped(_enc_tree(params, bits), tokens, patch_mask)
    else:
        smapped = shard_map(lambda enc, t: body(enc, t, None), mesh=mesh,
                            in_specs=(specs, tok_spec),
                            out_specs=out_spec, check_rep=False)

        def run(params, tokens, patch_mask):
            return smapped(_enc_tree(params, bits), tokens)

    return jax.jit(run)


def sharded_encode(params: dict, tokens: jnp.ndarray, cfg: ArchConfig,
                   policy: ExecPolicy, patch_mask: jnp.ndarray | None,
                   kv_len: int | None, ctx) -> jnp.ndarray:
    """The model-sharded twin of the fused-encoder jit dispatch in
    models/vit.py. Callers (encode_tokens) have already verified fused +
    sharded eligibility; this resolves the static bit widths, picks the
    batch layout (split over "data" when the flush batch divides it,
    replicated otherwise — the same divisibility fallback ``shard`` and
    the server's ``_place`` apply) and dispatches the cached jit."""
    bits = _encoder_bits(params, policy)
    mesh = ctx.mesh
    batch_sharded = tokens.shape[0] % mesh.shape["data"] == 0
    kv = None if kv_len is None else int(kv_len)
    key = (cfg, policy.fingerprint(), tuple(sorted(bits.items())), kv,
           patch_mask is not None, mesh, batch_sharded)
    fn = _SHARDED_ENCODER_JITS.get(key)
    if fn is None:
        fn = _build_jit(cfg, policy, bits, kv, patch_mask is not None,
                        mesh, batch_sharded)
        _SHARDED_ENCODER_JITS[key] = fn
    return fn(params, tokens, patch_mask)
