"""Paper Fig. 9: processing-delay breakdown + Fig. 5 decomposition effect.

Reproduces: (i) optical stage (incl. ADC/DAC) dominates latency,
(ii) memory latency exceeds the EPU, (iii) the Eq. 2 decomposition removes
the serialized K-tuning bubble (5-core pipeline simulation)."""

from __future__ import annotations

from benchmarks.common import IMG_SIZES, VARIANTS, frame_report
from repro.core.schedule import attention_schedule


def run() -> list[dict]:
    rows = []
    print("\n== Fig. 9: latency breakdown (us/frame) ==")
    for v in VARIANTS:
        for img in IMG_SIZES:
            rep = frame_report(v, img)
            rows.append({"variant": v, "img": img,
                         "optical_us": rep.optical_us,
                         "epu_us": rep.epu_us,
                         "memory_us": rep.memory_us,
                         "total_us": rep.total_us})
            print(f"{v:>6}-{img:<4} total={rep.total_us:9.1f}us  "
                  f"optical={rep.optical_us:8.1f} epu={rep.epu_us:7.2f} "
                  f"memory={rep.memory_us:8.1f}")
    tiny = rows[0]
    assert tiny["optical_us"] > tiny["memory_us"] > tiny["epu_us"], \
        "paper Fig. 9 ordering: optical > memory > EPU"
    print("Tiny-96 ordering optical > memory > EPU: MATCHES paper")

    # Fig. 5: tuning bubble removal via Eq. 2 decomposition.
    print("\n== Fig. 5: 5-core pipeline, decomposed vs naive (1 head) ==")
    mk_naive, _ = attention_schedule(compute_us=1.0, tuning_us=2.0,
                                     softmax_us=0.3, decomposed=False)
    mk_dec, _ = attention_schedule(compute_us=1.0, tuning_us=2.0,
                                   softmax_us=0.3, decomposed=True)
    print(f"naive QK^T makespan    : {mk_naive:.2f} us")
    print(f"decomposed (Eq. 2)     : {mk_dec:.2f} us "
          f"({(1 - mk_dec / mk_naive) * 100:.0f}% faster)")
    assert mk_dec < mk_naive
    rows.append({"fig5_naive_us": mk_naive, "fig5_decomposed_us": mk_dec})

    # non-pipelined tuning comparison (what the decomposition buys at the
    # tile level: every tile tuning would serialize without it)
    rep_pipe = frame_report("tiny", 96, pipelined_tuning=True)
    rep_serial = frame_report("tiny", 96, pipelined_tuning=False)
    print(f"\ntile-level: pipelined tuning {rep_pipe.optical_us:.1f}us vs "
          f"serialized {rep_serial.optical_us:.1f}us "
          f"({rep_serial.optical_us / rep_pipe.optical_us:.2f}x)")
    assert rep_serial.optical_us > rep_pipe.optical_us
    return rows
