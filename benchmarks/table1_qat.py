"""Paper Table I (mechanism level): 8-bit QAT accuracy vs full precision.

Full ImageNet/CIFAR fine-tuning is out of scope on CPU; this reproduces
the MECHANISM the table demonstrates — QAT holds accuracy within ~1 point
of full precision — on a synthetic separable vision task (planted-box
ImageStream), plus the RoI-mask variant's controlled degradation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.data.pipeline import ImageStream
from repro.models.vit import forward_vit, init_vit


def _train_eval(cfg, steps=150, seed=0):
    from repro.data.pipeline import quadrant_labels
    stream = ImageStream(img_size=cfg.img_size, global_batch=32,
                         n_classes=8, patch=cfg.patch, seed=seed)
    params = init_vit(jax.random.PRNGKey(seed), cfg, n_classes=4)

    def loss_fn(p, images, labels):
        lg, _ = forward_vit(p, images, cfg)
        lf = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, -1)
        gold = jnp.take_along_axis(lf, labels[:, None], -1)[:, 0]
        return (lse - gold).mean()

    @jax.jit
    def step(p, images, labels):
        l, g = jax.value_and_grad(loss_fn)(p, images, labels)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g), l

    for i in range(steps):
        b = stream.batch_at(i)
        params, _ = step(params, b["images"],
                         quadrant_labels(b["patch_mask"]))

    correct = total = 0
    for j in range(4):
        b = stream.batch_at(2000 + j)
        lg, _ = forward_vit(params, b["images"], cfg)
        correct += int((jnp.argmax(lg, -1)
                        == quadrant_labels(b["patch_mask"])).sum())
        total += int(b["patch_mask"].shape[0])
    return correct / total


def run() -> list[dict]:
    print("\n== Table I (mechanism): QAT + RoI-mask accuracy ==")
    base = smoke_variant(get_config("tiny")).with_(n_layers=2, remat=False)
    cells = [
        ("fp32", base.with_(quant_bits=0)),
        ("w8a8 QAT", base.with_(quant_bits=8)),
        ("w8a8 + mask(keep 2/3)", base.with_(quant_bits=8, mgnet=True,
                                             mgnet_keep_ratio=0.67)),
    ]
    rows = []
    for name, cfg in cells:
        acc = _train_eval(cfg)
        rows.append({"config": name, "acc": acc})
        print(f"  {name:<24} acc = {acc:.3f}")
    fp = rows[0]["acc"]
    q = rows[1]["acc"]
    print(f"QAT drop vs fp: {fp - q:+.3f} "
          f"(paper Table I: <=1.6% across variants)")
    assert fp > 0.55, "task must be learnable"
    assert q > fp - 0.15, (fp, q)
    return rows
