"""Streaming KFPS/W accounting over the cross-layer accelerator model.

Every encode flush of bucket k adds ``n_real`` frames' worth of the
``vit_matmul_shapes(kept_patches=k)`` event counts; every MGNet invocation
adds the mask-generator's own shapes (frames that *reused* a cached mask pay
nothing — the serving engine's energy win over per-frame scoring). The
aggregate divides out to the paper's Table-4 metric: KFPS/W of a pipelined
accelerator is frames-per-joule / 1000, i.e. 1 / mean-E-frame[mJ] —
independent of host wall time, which is reported separately as frames/s of
the functional simulation.

``summary()`` additionally surfaces per-bucket hit/launch counts and warns
on **dead buckets** — ladder entries no stream frame ever routed to. Every
ladder entry costs one compiled encode shape (and, in one-shape mode, one
kv_len-specialized jit), so a bucket with zero hits is pure compile-time
waste and a signal the ladder fractions need retuning for the stream's
budget distribution (see README "Bucket-ladder tuning").
"""

from __future__ import annotations

import warnings
from collections import Counter
from typing import Iterable

from repro.configs.base import ArchConfig
from repro.core.energy import (EnergyReport, accumulate_matmuls,
                               energy_of_stats, kfps_per_watt,
                               latency_of_stats, scale_for_bits)
from repro.models.vit import vit_matmul_shapes

__all__ = ["StreamAccounting"]


def _nonlin_elems(cfg: ArchConfig, n_tokens: int) -> int:
    """Softmax (H * n^2) + GELU (n * d_ff) element count per frame."""
    return cfg.n_layers * (cfg.n_heads * n_tokens * n_tokens
                           + n_tokens * cfg.d_ff)


class StreamAccounting:
    """Accumulates per-frame EnergyReports bucket-by-bucket.

    ``layer_bits`` (one width per encoder layer — a mixed-precision bit
    plan's energy view, ``core.bitalloc.plan_layer_bits``) scales each
    layer's *weight-stationary* matmul energy by its actual width: the MR
    tuning, ADC/DAC conversion and SRAM code traffic of the q/k/v,
    out-projection and both MLP matmuls pay ``bits/8`` of the calibrated
    8-bit constants (``core.energy.scale_for_bits``), while the
    activation-activation score/PV matmuls, the patch embed (always at
    the default width) and every latency term stay unscaled — a lower
    width buys energy per frame, not wall time, in this model."""

    # index layout of one layer's chunk in vit_matmul_shapes: q, k, v,
    # scores, attn@v, out-proj, mlp w1, mlp w2
    _WEIGHT_IDX = (0, 1, 2, 5, 6, 7)
    _ACT_IDX = (3, 4)

    def __init__(self, cfg: ArchConfig,
                 ladder_sizes: Iterable[int] | None = None,
                 layer_bits: Iterable[int] | None = None):
        self.cfg = cfg
        self.total = EnergyReport()
        self.frames = 0
        self.scored_frames = 0
        # per-bucket stream telemetry: frames routed (hits) and encode
        # launches (the first launch of a bucket is its jit compile)
        self.ladder_sizes = (tuple(int(k) for k in ladder_sizes)
                             if ladder_sizes is not None else None)
        self.layer_bits = (tuple(int(b) for b in layer_bits)
                           if layer_bits is not None else None)
        if (self.layer_bits is not None
                and len(self.layer_bits) != cfg.n_layers):
            raise ValueError(f"layer_bits has {len(self.layer_bits)} "
                             f"entries for {cfg.n_layers} layers")
        self.bucket_frames: Counter = Counter()
        self.bucket_launches: Counter = Counter()
        self._per_bucket: dict[int, EnergyReport] = {}
        self._mgnet: EnergyReport | None = None

    def _mixed_bits_energy(self, shapes: list, nl: int) -> EnergyReport:
        """Energy with each layer's weight-stationary matmuls scaled to
        its planned width (see class docstring). Bit-exact to the
        aggregate ``energy_of_stats`` when every layer is at 8 bits."""
        embed_stats, _ = accumulate_matmuls(shapes[:1])
        rep = energy_of_stats(embed_stats, nl)
        for li, bits in enumerate(self.layer_bits):
            chunk = shapes[1 + 8 * li: 1 + 8 * (li + 1)]
            w_stats, _ = accumulate_matmuls([chunk[i]
                                             for i in self._WEIGHT_IDX])
            a_stats, _ = accumulate_matmuls([chunk[i]
                                             for i in self._ACT_IDX])
            rep += scale_for_bits(energy_of_stats(w_stats), bits)
            rep += energy_of_stats(a_stats)
        return rep

    def _bucket_report(self, k: int) -> EnergyReport:
        """Per-frame report for a k-patch encode (backbone only), cached —
        the ladder is small so each bucket's report is computed once."""
        rep = self._per_bucket.get(k)
        if rep is None:
            n_patches = (self.cfg.img_size // self.cfg.patch) ** 2
            kept = None if k >= n_patches else k
            shapes = vit_matmul_shapes(self.cfg, kept_patches=kept)
            stats, tiles = accumulate_matmuls(shapes)
            nl = _nonlin_elems(self.cfg, k + 1)
            if (self.layer_bits is not None
                    and len(shapes) == 1 + 8 * self.cfg.n_layers):
                rep = self._mixed_bits_energy(shapes, nl)
            else:
                rep = energy_of_stats(stats, nl)
            lat = latency_of_stats(stats, nl, n_tiles=tiles)
            rep.optical_us, rep.epu_us, rep.memory_us = (
                lat.optical_us, lat.epu_us, lat.memory_us)
            self._per_bucket[k] = rep
        return rep

    def _mgnet_report(self) -> EnergyReport:
        """Per-invocation MGNet report (the shapes ``include_mgnet`` appends
        after the backbone's)."""
        if self._mgnet is None:
            base = vit_matmul_shapes(self.cfg)
            full = vit_matmul_shapes(self.cfg, include_mgnet=True)
            stats, tiles = accumulate_matmuls(full[len(base):])
            rep = energy_of_stats(stats)
            lat = latency_of_stats(stats, n_tiles=tiles)
            rep.optical_us, rep.epu_us, rep.memory_us = (
                lat.optical_us, lat.epu_us, lat.memory_us)
            self._mgnet = rep
        return self._mgnet

    def add_encode(self, bucket: int, n_frames: int) -> None:
        self.total += self._bucket_report(bucket).scaled(n_frames)
        self.frames += n_frames
        self.bucket_frames[int(bucket)] += n_frames
        self.bucket_launches[int(bucket)] += 1

    def add_mgnet(self, n_invocations: int) -> None:
        self.total += self._mgnet_report().scaled(n_invocations)
        self.scored_frames += n_invocations

    def dead_buckets(self) -> tuple[int, ...]:
        """Ladder entries no frame was ever routed to (empty when no
        ladder was registered)."""
        if self.ladder_sizes is None:
            return ()
        return tuple(k for k in self.ladder_sizes
                     if self.bucket_frames[k] == 0)

    def summary(self) -> str:
        """Per-bucket hit/launch counts, warning on dead buckets.

        A launch is one encode flush; the first launch of a bucket paid
        that bucket's jit compile, so ``launches >= 1`` marks the bucket
        as compiled. Dead buckets compiled nothing *only if* the engine
        never warmed them — but their ladder slot still constrains
        routing, so the warning fires either way.
        """
        sizes = (self.ladder_sizes if self.ladder_sizes is not None
                 else tuple(sorted(self.bucket_frames)))
        parts = []
        for k in sizes:
            hits = self.bucket_frames[k]
            parts.append(f"k={k}: {hits} hits/"
                         f"{self.bucket_launches[k]} launches")
        dead = self.dead_buckets()
        if dead:
            warnings.warn(
                f"dead ladder buckets {list(dead)}: no frame routed to "
                f"them in {self.frames} frames — every ladder entry costs "
                f"a compiled encode shape, retune the bucket fractions "
                f"(README 'Bucket-ladder tuning')", stacklevel=2)
        line = " | ".join(parts) if parts else "no encodes"
        if dead:
            line += f"  [dead: {', '.join(f'k={k}' for k in dead)}]"
        return f"buckets: {line}"

    @property
    def mean_frame(self) -> EnergyReport:
        return self.total.scaled(1.0 / self.frames if self.frames else 0.0)

    @property
    def kfps_per_watt(self) -> float:
        return kfps_per_watt(self.mean_frame) if self.frames else 0.0

    def dense_baseline_kfps_per_watt(self, with_mgnet: bool = True) -> float:
        """KFPS/W if every frame were encoded dense (and scored, if
        ``with_mgnet``) — the no-gating reference for the energy-saved %."""
        n = (self.cfg.img_size // self.cfg.patch) ** 2
        rep = self._bucket_report(n)
        if with_mgnet:
            rep = rep + self._mgnet_report()
        return kfps_per_watt(rep)
