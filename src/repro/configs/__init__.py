"""Architecture configs + registry."""
