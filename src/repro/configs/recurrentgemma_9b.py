"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU recurrence with local attention every 3rd layer
(pattern rec,rec,attn x12 + 2 tail rec), window 2048 (arXiv:2402.19427).
Runs long_500k (sub-quadratic: recurrent state + windowed attention)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, kv_heads=1,
        d_ff=12288, vocab=256000,
        window=2048, attn_every=3, lru_width=4096,
        rope_theta=10000.0,
        microbatch_steps=2,
    )
