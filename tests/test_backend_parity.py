"""Cross-backend parity contract for the unified matmul execution backend.

core/backend.py promises that the three photonic execution paths —
``photonic_matmul_exact`` (one-shot), ``photonic_sim`` (Fig. 6 chunk walk)
and ``photonic_pallas`` (int8 MXU kernel, interpret mode) — produce
bit-identical int32 accumulates, and that the quantize-once weight cache
(``prepare_params``) changes nothing about the numbers, only when weight
quantization happens."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core import backend as be
from repro.core.backend import (ExecPolicy, QuantizedWeight, linear,
                                prepare_params, quantize_weight)
from repro.core.mgnet import mgnet_logical_axes, mgnet_scores, MGNetConfig
from repro.core.photonic import OpticalCoreConfig, photonic_matmul_exact, \
    photonic_matmul_sim
from repro.models.vit import forward_vit, init_vit, vit_logical_axes

TINY96 = get_config("tiny", img_size=96)


def _rand_int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, jnp.int32).astype(
        jnp.int8)


# --------------------------------------------------------------------------
# integer-accumulate contract (acceptance: bit-identical across backends)
# --------------------------------------------------------------------------

def _tiny96_weight_shapes():
    """The distinct (M, K, N) weight matmuls of one Tiny-96 forward:
    patch embed, per-layer q/k/v/o projections, the two FFN matmuls, and
    the classifier head."""
    n = (96 // 16) ** 2 + 1                      # 37 tokens incl. [cls]
    d, dff = TINY96.d_model, TINY96.d_ff
    return [(n - 1, 3 * 16 * 16, d),             # patch embed
            (n, d, d),                           # q/k/v/o projections
            (n, d, dff), (n, dff, d),            # FFN
            (1, d, 1000)]                        # head


@pytest.mark.parametrize("m,k,n", _tiny96_weight_shapes())
def test_int_accumulates_bit_identical_tiny96(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 31 + k * 7 + n))
    xq = _rand_int8(kx, (m, k))
    wq = _rand_int8(kw, (k, n))
    exact = np.asarray(be.int_accumulate_exact(xq, wq))
    sim = np.asarray(be.int_accumulate_sim(xq, wq))
    pallas = np.asarray(be.int_accumulate_pallas(xq, wq))
    np.testing.assert_array_equal(exact, sim)
    np.testing.assert_array_equal(exact, pallas)


def test_linear_matches_photonic_matmul_exact():
    """Every photonic backend's full float path == the exact oracle."""
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (37, 192))
    w = jax.random.normal(kw, (192, 768))
    ref = np.asarray(photonic_matmul_exact(x, w))
    for name in ("photonic_sim", "photonic_pallas"):
        out = linear(x, w, policy=ExecPolicy(backend=name))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6,
                                   atol=1e-6, err_msg=name)


# --------------------------------------------------------------------------
# non-multiple-of-128 padding path through the Pallas kernel (ViT-Tiny
# shapes: none of M=37, K=768, N=192 is a block multiple)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(37, 768, 192), (37, 192, 192),
                                   (1, 192, 1000), (130, 33, 65)])
def test_pallas_padding_path_parity(m, k, n):
    from repro.kernels.ops import photonic_matmul

    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    out = np.asarray(photonic_matmul(x, w))
    ref = np.asarray(photonic_matmul_exact(x, w))
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_prequant_padding_parity():
    """Cached-weight kernel entry point on unaligned ViT-Tiny shapes."""
    from repro.kernels.ops import photonic_matmul_prequant

    kx, kw = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.normal(kx, (37, 768))
    w = jax.random.normal(kw, (768, 192))
    qw = quantize_weight(w)
    out = photonic_matmul_prequant(x, qw.wq, qw.scale.reshape(-1))
    ref = photonic_matmul_exact(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# quantize-once cache
# --------------------------------------------------------------------------

def test_prepare_params_wraps_only_matmul_weights():
    cfg = smoke_variant(TINY96).with_(mgnet=True)
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    prep = prepare_params(params)
    assert isinstance(prep["patch_embed"]["w"], QuantizedWeight)
    assert isinstance(prep["blocks"]["attn"]["wq"], QuantizedWeight)
    assert isinstance(prep["blocks"]["ffn"]["w1"], QuantizedWeight)
    assert isinstance(prep["mgnet"]["block"]["wqkv"], QuantizedWeight)
    # non-matmul leaves stay raw
    for leaf in (prep["cls"], prep["pos"], prep["patch_embed"]["b"],
                 prep["final_ln_g"], prep["mgnet"]["cls_token"],
                 prep["mgnet"]["pos_embed"]):
        assert isinstance(leaf, jax.Array)
    # idempotent
    again = prepare_params(prep)
    assert again["patch_embed"]["w"] is prep["patch_embed"]["w"]


def test_stacked_weight_cache_matches_per_layer_quant():
    """A scan-stacked (L, K, N) weight must carry per-layer scales equal to
    quantizing each (K, N) slice on its own — the bit-parity precondition."""
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 24))
    qw = quantize_weight(w)
    assert qw.wq.shape == (3, 16, 24) and qw.scale.shape == (3, 1, 24)
    for l in range(3):
        per = quantize_weight(w[l])
        np.testing.assert_array_equal(np.asarray(qw.wq[l]),
                                      np.asarray(per.wq))
        np.testing.assert_array_equal(np.asarray(qw.scale[l]),
                                      np.asarray(per.scale))


@pytest.mark.parametrize("backend", ["photonic_sim", "photonic_pallas"])
def test_cached_linear_bit_identical_to_dynamic(backend):
    """Out of jit, the cache changes *when* weight quantization happens,
    not a single bit of what ``linear`` returns."""
    kx, kw = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(kx, (2, 9, 192))
    w = jax.random.normal(kw, (192, 768))
    pol = ExecPolicy(backend=backend)
    y_raw = linear(x, w, policy=pol)
    y_cached = linear(x, quantize_weight(w), policy=pol)
    np.testing.assert_array_equal(np.asarray(y_raw), np.asarray(y_cached))


@pytest.mark.parametrize("backend", ["photonic_sim", "photonic_pallas"])
def test_cached_forward_matches_uncached(backend):
    """Through the whole forward the integer accumulates are unchanged; the
    logits may differ only by XLA's reassociation of the f32 dequant
    epilogue inside the compiled layer scan (the raw graph carries weight-
    quant ops the cached graph doesn't, so fusion choices differ)."""
    cfg = smoke_variant(TINY96).with_(matmul_backend=backend)
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                     cfg.img_size, 3))
    lg_raw, _ = forward_vit(params, imgs, cfg)
    lg_cached, _ = forward_vit(prepare_params(params), imgs, cfg)
    np.testing.assert_allclose(np.asarray(lg_raw), np.asarray(lg_cached),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# cross-backend forward parity (acceptance criterion)
# --------------------------------------------------------------------------

def test_forward_vit_parity_across_photonic_backends():
    """photonic_sim and photonic_pallas agree on the full Tiny-derived
    forward (cached weights); both correlate with bf16 up to 8-bit error."""
    cfg = smoke_variant(TINY96)
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    prepared = prepare_params(params)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                     cfg.img_size, 3))
    lg_sim, _ = forward_vit(prepared, imgs,
                            cfg.with_(matmul_backend="photonic_sim"))
    lg_pal, _ = forward_vit(prepared, imgs,
                            cfg.with_(matmul_backend="photonic_pallas"))
    np.testing.assert_allclose(np.asarray(lg_sim), np.asarray(lg_pal),
                               rtol=1e-5, atol=1e-5)
    lg_fp, _ = forward_vit(params, imgs, cfg.with_(matmul_backend="bf16"))
    corr = np.corrcoef(np.asarray(lg_fp).ravel(),
                       np.asarray(lg_sim).ravel())[0, 1]
    assert corr > 0.99, corr


def test_decomposed_attention_under_photonic_backend():
    cfg = smoke_variant(TINY96).with_(matmul_backend="photonic_sim")
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                     cfg.img_size, 3))
    lg_std, _ = forward_vit(params, imgs, cfg)
    lg_dec, _ = forward_vit(params, imgs,
                            cfg.with_(attn_impl="decomposed"))
    # Eq. 2 changes the association order *and* where quantization applies;
    # agreement is close but not bitwise.
    corr = np.corrcoef(np.asarray(lg_std).ravel(),
                       np.asarray(lg_dec).ravel())[0, 1]
    assert corr > 0.99, corr


def test_backend_registry_contents():
    assert set(be.available_backends()) >= {"bf16", "qat", "photonic_sim",
                                            "photonic_pallas"}
    with pytest.raises(KeyError, match="unknown matmul backend"):
        be.get_backend("does-not-exist")
    assert ExecPolicy(photonic=True).resolve_backend() == "photonic_sim"
    assert ExecPolicy(quant_bits=8).resolve_backend() == "qat"
    assert ExecPolicy().resolve_backend() == "bf16"
    assert ExecPolicy(backend="photonic_pallas",
                      quant_bits=8).resolve_backend() == "photonic_pallas"


# --------------------------------------------------------------------------
# MGNet under the shared dispatch (acceptance: no raw weight matmuls)
# --------------------------------------------------------------------------

def test_mgnet_routes_through_backend_dispatch():
    mcfg = MGNetConfig(patch=8, embed=32, heads=2, img_size=32)
    from repro.core.mgnet import init_mgnet
    params = init_mgnet(jax.random.PRNGKey(0), mcfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    s_fp = mgnet_scores(params, imgs, mcfg)
    s_ph = mgnet_scores(params, imgs, mcfg,
                        ExecPolicy(backend="photonic_sim"))
    # photonic execution quantizes => different bits, same scores overall
    assert not np.array_equal(np.asarray(s_fp), np.asarray(s_ph))
    corr = np.corrcoef(np.asarray(s_fp).ravel(),
                       np.asarray(s_ph).ravel())[0, 1]
    assert corr > 0.99, corr
    # cached MGNet weights are bit-identical to dynamic quantization
    s_cached = mgnet_scores(prepare_params(params), imgs, mcfg,
                            ExecPolicy(backend="photonic_sim"))
    np.testing.assert_array_equal(np.asarray(s_ph), np.asarray(s_cached))


def test_no_raw_weight_matmuls_left_in_mgnet():
    """Source-level guard for the acceptance criterion: the only ``@``
    products left in core/mgnet.py are activation-activation (q.K^T,
    att.V, q_cls.K^T), never against a params[...] weight."""
    import inspect

    from repro.core import mgnet as mgnet_mod
    src = inspect.getsource(mgnet_mod)
    assert "@ params" not in src and "@ blk" not in src
    matmul_lines = [ln.strip() for ln in src.splitlines()
                    if " @ " in ln and not ln.strip().startswith("#")]
    allowed = ("q @ k.transpose", "att @ v", "q_cls @ k_pat.transpose")
    for ln in matmul_lines:
        assert any(a in ln for a in allowed), ln


# --------------------------------------------------------------------------
# satellites: logical axes + ADC model
# --------------------------------------------------------------------------

def test_vit_logical_axes_matches_param_structure_with_mgnet():
    cfg = smoke_variant(TINY96).with_(mgnet=True)
    params = init_vit(jax.random.PRNGKey(0), cfg, n_classes=8)
    axes = vit_logical_axes(cfg)
    # tree_map across (params, axes) must not raise a structure mismatch;
    # every axis entry has one name per tensor dim (stacked layers add one).
    def check(p, ax):
        assert isinstance(ax, tuple), (p.shape, ax)
        assert p.ndim in (len(ax), len(ax) + 1), (p.shape, ax)
        return 0

    jax.tree_util.tree_map(check, params, axes)
    assert "mgnet" in axes
    mg_leaves = jax.tree_util.tree_leaves(
        axes["mgnet"], is_leaf=lambda x: isinstance(x, tuple))
    assert mg_leaves and all(all(a is None for a in t) for t in mg_leaves)
    assert mgnet_logical_axes().keys() == params["mgnet"].keys()


def test_adc_output_quantization_option():
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(kx, (16, 64))
    w = jax.random.normal(kw, (64, 32))
    ideal = photonic_matmul_sim(x, w)
    adc = photonic_matmul_sim(x, w,
                              OpticalCoreConfig(adc_quantize_output=True))
    # ideal ADC == exact integer readout; range-limited ADC perturbs it
    np.testing.assert_allclose(np.asarray(ideal),
                               np.asarray(photonic_matmul_exact(x, w)),
                               rtol=1e-5, atol=1e-5)
    err = np.abs(np.asarray(adc) - np.asarray(ideal)).max()
    assert 0 < err, "ADC quantization should alter the readout"
    # but only by at most one ADC step (absmax/127 of the output range)
    step = np.abs(np.asarray(ideal)).max() / 127
    assert err <= step / 2 + 1e-6, (err, step)
