"""Optimized-HLO analyzer: FLOPs / HBM bytes / collective bytes per device.

Why not ``compiled.cost_analysis()`` alone: on this backend it counts a
``while`` (scan) body ONCE, so any scan-over-layers model under-reports
FLOPs by ~n_layers x (verified empirically — see EXPERIMENTS.md §Dry-run).
We parse ``compiled.as_text()`` instead and apply loop trip-count
multipliers. After SPMD partitioning every shape in the module is already
the per-device shard, so all totals below are per-device numbers.

Model:
  * flops       — 2 * prod(out_dims) * prod(lhs contracting dims) for every
                  ``dot`` (recursing into fusion-called computations);
                  while bodies multiplied by their trip count
                  (backend_config known_trip_count, fallback: the cond's
                  compare constant).
  * bytes       — Σ over *top-level* instructions of operand + result
                  buffer sizes. Fusions count their boundary operands and
                  results only (internals live in registers/cache): the
                  post-fusion HBM-traffic model. parameter/constant/tuple/
                  get-tuple-element/bitcast are excluded (no traffic).
  * collectives — wire bytes *received per device*, per op:
                      all-reduce          2 (g-1)/g * bytes   (ring)
                      all-gather          (g-1)/g * out_bytes
                      reduce-scatter      (g-1)/g * in_bytes
                      all-to-all          (g-1)/g * bytes
                      collective-permute  1.0 * bytes
                  with g = replica-group size parsed from the op.
  * conditional — branch costs are AVERAGED (a 2-branch compute/skip cond,
                  e.g. the causal block-skip optimization, then counts
                  ~50% live — matching the causal triangle's live
                  fraction). Recorded so the block-skip hillclimb is
                  visible in the compute term.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["Cost", "HloModule", "parse_hlo", "analyze_module",
           "compile_and_cost", "collective_summary"]

_ESIZE = {"f64": 8, "s64": 8, "u64": 8, "c64": 8,
          "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1,
          "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
          "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "reshape"}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    bytes_by_tag: dict = field(default_factory=dict)   # named_scope -> bytes
    int8_flops: float = 0.0    # subset of flops on s8xs8 dots (2x MXU peak)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.int8_flops += o.int8_flops
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        for k, v in o.bytes_by_tag.items():
            self.bytes_by_tag[k] = self.bytes_by_tag.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_by_op.items()},
                    {k: v * m for k, v in self.bytes_by_tag.items()},
                    self.int8_flops * m)


# named_scope markers the model code emits; bytes attributed by substring
# match on the instruction's op_name metadata. Used by §Perf to quantify
# what the fused Pallas kernels remove from HBM traffic.
TAGS = ("flash_attn", "decode_attn", "full_attn", "moe_dispatch", "ssd_scan")


# --------------------------------------------------------------------------
# shape / type parsing
# --------------------------------------------------------------------------

def _split_top(s: str) -> list[str]:
    """Split a tuple-type body on top-level commas."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


_SHAPE_RE = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")


def parse_shape(t: str):
    """'f32[4,16,64]{2,1,0}' -> ('f32', (4,16,64)). Tuples -> list of both."""
    t = t.strip()
    if t.startswith("("):
        inner = t[1:t.rindex(")")]
        return [parse_shape(e) for e in _split_top(inner)]
    m = _SHAPE_RE.match(t)
    if not m:
        return ("opaque", ())
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return (dt, shape)


def type_bytes(t: str) -> float:
    p = parse_shape(t)
    items = p if isinstance(p, list) else [p]
    total = 0.0
    for it in items:
        if isinstance(it, list):       # nested tuple
            total += sum(_elem_bytes(x) for x in _flatten(it))
        else:
            total += _elem_bytes(it)
    return total


def _flatten(x):
    for it in x:
        if isinstance(it, list):
            yield from _flatten(it)
        else:
            yield it


def _elem_bytes(p) -> float:
    dt, shape = p
    n = 1
    for d in shape:
        n *= d
    return n * _ESIZE.get(dt, 4)


# --------------------------------------------------------------------------
# module parsing
# --------------------------------------------------------------------------

@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    sig_params: dict = field(default_factory=dict)   # name -> type str
    is_entry: bool = False


@dataclass
class HloModule:
    computations: dict = field(default_factory=dict)
    entry: str = ""


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _parse_instr_rhs(rhs: str):
    """rhs = '<type> <opcode>(<operands>), attrs...'."""
    rhs = rhs.strip()
    if rhs.startswith("("):            # tuple type: find matching paren
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rhs[: i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.index(" ")
        type_str = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return type_str, rest, [], ""
    opcode = m.group(1)
    # operand list: balanced parens from opcode's '('
    start = m.end() - 1
    depth = 0
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    ops_str = rest[start + 1: i]
    attrs = rest[i + 1:]
    operands = [o.strip() for o in _split_top(ops_str)] if ops_str else []
    return type_str, opcode, operands, attrs


def parse_hlo(text: str) -> HloModule:
    mod = HloModule()
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):                    # computation head
            mh = _COMP_HEAD.match(line)
            if mh:
                is_entry = bool(mh.group(1))
                name = mh.group(2)
                cur = Computation(name=name, is_entry=is_entry)
                # signature params: "a: f32[2], b: (s32[], f32[3])"
                for p in _split_top(mh.group(3)):
                    if ":" in p:
                        pn, pt = p.split(":", 1)
                        cur.sig_params[pn.strip().lstrip("%")] = pt.strip()
                mod.computations[name] = cur
                if is_entry:
                    mod.entry = name
                continue
            if line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        try:
            type_str, opcode, operands, attrs = _parse_instr_rhs(rhs)
        except Exception:
            continue
        cur.instrs.append(Instr(name, type_str, opcode, operands, attrs))
    return mod


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACKET = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TOAPPLY = re.compile(r"to_apply=%?([\w.\-]+)")


def _group_size(attrs: str, default: int = 1) -> int:
    m = _GROUPS_BRACKET.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACES.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _symbol_table(comp: Computation) -> dict:
    tab = dict(comp.sig_params)
    for ins in comp.instrs:
        tab[ins.name] = ins.type_str
    return tab


def _operand_type(op: str, tab: dict) -> str | None:
    # operand may be "%name" or "f32[2,3] %name" (older dialect)
    op = op.strip()
    if op.startswith("%"):
        return tab.get(op[1:])
    parts = op.rsplit("%", 1)
    if len(parts) == 2 and parts[0].strip():
        return parts[0].strip()
    return tab.get(op.lstrip("%"))


def _dot_flops(ins: Instr, tab: dict) -> tuple[float, bool]:
    """Returns (flops, is_int8) for a dot/convolution instruction."""
    out = parse_shape(ins.type_str)
    if isinstance(out, list):
        return 0.0, False
    out_elems = 1
    for d in out[1]:
        out_elems *= d
    k = 1
    is_int8 = False
    m = _CDIMS.search(ins.attrs)
    lhs_t = _operand_type(ins.operands[0], tab) if ins.operands else None
    if m and lhs_t:
        lhs = parse_shape(lhs_t)
        if not isinstance(lhs, list):
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs[1]):
                    k *= lhs[1][idx]
            is_int8 = lhs[0] in ("s8", "u8")
    return 2.0 * out_elems * k, is_int8


def _collective_bytes(ins: Instr, tab: dict) -> float:
    g = _group_size(ins.attrs)
    if g <= 1:
        return 0.0
    opcode = ins.opcode.replace("-start", "")
    out_b = type_bytes(ins.type_str)
    in_b = sum(type_bytes(_operand_type(o, tab) or "f32[]")
               for o in ins.operands)
    frac = (g - 1) / g
    if opcode == "all-reduce":
        return 2.0 * frac * out_b
    if opcode == "all-gather":
        return frac * out_b
    if opcode == "reduce-scatter":
        return frac * in_b
    if opcode == "all-to-all":
        return frac * max(in_b, out_b)
    if opcode == "collective-permute":
        return out_b
    return 0.0


def _fusion_flops(comp: Computation, mod: HloModule,
                  memo: dict) -> tuple[float, float]:
    """(flops, int8_flops) inside a fused computation (dots; recursive)."""
    if comp.name in memo:
        return memo[comp.name]
    tab = _symbol_table(comp)
    total = i8 = 0.0
    for ins in comp.instrs:
        if ins.opcode in ("dot", "convolution"):
            f, is8 = _dot_flops(ins, tab)
            total += f
            if is8:
                i8 += f
        elif ins.opcode == "fusion":
            m = _CALLS.search(ins.attrs)
            if m and m.group(1) in mod.computations:
                f, fi8 = _fusion_flops(mod.computations[m.group(1)], mod,
                                       memo)
                total += f
                i8 += fi8
    memo[comp.name] = (total, i8)
    return total, i8


def _operand_name(op: str) -> str:
    """'%c', 's32[] %c' or bare 'c' -> 'c'."""
    return op.strip().rsplit("%", 1)[-1].strip()


def _const_int(ins: Instr | None) -> int | None:
    """Integer literal of a parsed constant: ``%c = s32[] constant(5)``
    parses with the value as the constant's sole *operand* (not in attrs
    or the type string), so that is where the bound lives."""
    if ins is None or ins.opcode != "constant" or not ins.operands:
        return None
    lit = ins.operands[0].strip()
    return int(lit) if lit.lstrip("-").isdigit() else None


def _trip_count(ins: Instr, mod: HloModule) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    # fallback for modules whose backend_config lost known_trip_count: a
    # counted loop's cond computation compares the induction variable
    # against a constant bound — resolve the compare's operands to
    # constant instructions and read the bound from there.
    mc = _COND.search(ins.attrs)
    if mc and mc.group(1) in mod.computations:
        cond = mod.computations[mc.group(1)]
        consts = {ci.name: ci for ci in cond.instrs
                  if ci.opcode == "constant"}
        for ci in cond.instrs:
            if ci.opcode != "compare":
                continue
            for op in ci.operands:
                n = _const_int(consts.get(_operand_name(op)))
                if n is not None and n > 0:
                    return n
        # no compare resolved: any positive int constant in the cond
        for ci in consts.values():
            n = _const_int(ci)
            if n is not None and n > 0:
                return n
    return 1


def _comp_cost(comp: Computation, mod: HloModule, memo: dict,
               fusion_memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()           # cycle guard
    tab = _symbol_table(comp)
    c = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            body = _BODY.search(ins.attrs)
            trip = _trip_count(ins, mod)
            if body and body.group(1) in mod.computations:
                c += _comp_cost(mod.computations[body.group(1)], mod, memo,
                                fusion_memo).scaled(trip)
            continue
        if op == "conditional":
            mb = _BRANCHES.search(ins.attrs)
            names = []
            if mb:
                names = [n.strip().lstrip("%")
                         for n in mb.group(1).split(",")]
            else:
                names = [m for m in re.findall(r"%([\w.\-]+)", ins.attrs)
                         if m in mod.computations]
            branch_costs = [
                _comp_cost(mod.computations[n], mod, memo, fusion_memo)
                for n in names if n in mod.computations]
            if branch_costs:
                avg = Cost()
                for bc in branch_costs:
                    avg += bc
                c += avg.scaled(1.0 / len(branch_costs))
            continue
        if op == "call":
            m = _TOAPPLY.search(ins.attrs)
            if m and m.group(1) in mod.computations:
                c += _comp_cost(mod.computations[m.group(1)], mod, memo,
                                fusion_memo)
            continue
        if op in ("dot", "convolution"):
            f, is8 = _dot_flops(ins, tab)
            c.flops += f
            if is8:
                c.int8_flops += f
        elif op == "fusion":
            m = _CALLS.search(ins.attrs)
            if m and m.group(1) in mod.computations:
                f, fi8 = _fusion_flops(mod.computations[m.group(1)], mod,
                                       fusion_memo)
                c.flops += f
                c.int8_flops += fi8
        elif any(op.startswith(col) for col in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            cb = _collective_bytes(ins, tab)
            c.coll_bytes += cb
            key = op.replace("-start", "")
            c.coll_by_op[key] = c.coll_by_op.get(key, 0.0) + cb
        # HBM bytes: boundary traffic of every top-level op
        if op not in _NO_TRAFFIC and not op.endswith("-done"):
            b = _instr_traffic(ins, tab, mod)
            c.bytes += b
            for tag in TAGS:
                if tag in ins.attrs:      # op_name metadata substring
                    c.bytes_by_tag[tag] = c.bytes_by_tag.get(tag, 0.0) + b
                    break
    memo[comp.name] = c
    return c


_SPARSE_OPS = ("dynamic-update-slice", "dynamic-slice", "gather", "scatter")


def _instr_traffic(ins: Instr, tab: dict, mod: HloModule) -> float:
    """HBM traffic model for one op. Sparse-access ops touch only the
    moved slice, not their full operands (XLA aliases DUS in place inside
    loops; gathers read only the selected rows):
      * dynamic-update-slice — read+write of the inserted slice,
      * dynamic-slice / gather — 2 x result,
      * scatter — 2 x updates operand.
    Fusions wrapping one of these (wrapped_scatter/gather etc.) are
    classified by their called computation's root op. Everything else:
    result + all operands (post-fusion boundary model).
    """
    op = ins.opcode
    if op == "fusion":
        m = _CALLS.search(ins.attrs)
        if m and m.group(1) in mod.computations:
            called = mod.computations[m.group(1)]
            has_sparse = any(i.opcode in _SPARSE_OPS for i in called.instrs)
            if has_sparse:
                # the fusion streams a slice of (or into) its largest
                # buffer; the big buffers alias/loop in place. Count 2x
                # everything well below the largest candidate.
                res_b = type_bytes(ins.type_str)
                cand = [res_b] + [
                    type_bytes(_operand_type(o, tab) or "f32[]")
                    for o in ins.operands]
                big = max(cand)
                small = sum(c for c in cand if c < 0.25 * big)
                return 2.0 * small if small else 2.0 * min(cand)
    if op == "dynamic-update-slice":
        upd = _operand_type(ins.operands[1], tab) if len(ins.operands) > 1 \
            else None
        return 2.0 * type_bytes(upd) if upd else 0.0
    if op in ("dynamic-slice", "gather"):
        return 2.0 * type_bytes(ins.type_str)
    if op == "scatter":
        upd = _operand_type(ins.operands[-1], tab) if ins.operands else None
        return 2.0 * type_bytes(upd) if upd else type_bytes(ins.type_str)
    b = type_bytes(ins.type_str)
    for o in ins.operands:
        t = _operand_type(o, tab)
        if t:
            b += type_bytes(t)
    return b


def analyze_module(hlo_text: str) -> Cost:
    """Per-device Cost for one compiled executable."""
    mod = parse_hlo(hlo_text)
    if not mod.entry:
        return Cost()
    return _comp_cost(mod.computations[mod.entry], mod, {}, {})


def compile_and_cost(fn, *args, **kwargs):
    """Lower + compile ``fn`` on ``args`` and cost the optimized HLO.

    Returns ``(cost, compiled)``. The compiled executable is handed back
    deliberately: the serving control plane's cost model prices every
    ladder bucket by compiling it, and the same executable then *serves*
    that bucket AOT — one compile pays for both costing and warm-up
    instead of a second jit trace of the identical function.

    ``fn`` may be a ``jax.jit`` wrapper (anything with ``.lower``) or a
    plain callable, which is jitted here. jax import is deferred so the
    text parser above stays importable without a jax install.
    """
    import jax

    lowered = (fn.lower(*args, **kwargs) if hasattr(fn, "lower")
               else jax.jit(fn).lower(*args, **kwargs))
    compiled = lowered.compile()
    return analyze_module(compiled.as_text()), compiled


def collective_summary(cost: Cost) -> str:
    if not cost.coll_by_op:
        return "none"
    return ", ".join(f"{k}={v / 1e6:.1f}MB"
                     for k, v in sorted(cost.coll_by_op.items()))
