"""jit'd public wrappers around the Pallas kernels.

``photonic_matmul(x, w)`` is the drop-in float API: it quantizes (absmax,
symmetric — core/quant.py), pads to kernel block multiples, runs the int8
kernel and dequantizes. ``fused_attention`` exposes the flash kernel with
the models/attention.py calling convention (B, S, H, D).

Both take ``interpret=`` so tests run the kernel body on CPU; on a real
TPU deployment set interpret=False (config flag ``use_pallas``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.flash_attention import (flash_attention,
                                           fused_masked_attention)
from repro.kernels.flash_decode import flash_decode
from repro.kernels.fused_ffn import fused_ffn
from repro.kernels.photonic_matmul import photonic_matmul_int8

__all__ = ["photonic_matmul", "photonic_matmul_prequant",
           "photonic_matmul_prequant_noisy", "fused_attention",
           "fused_roi_attention_prequant", "fused_ffn", "flash_decode",
           "pad_to"]


def pad_to(x, mult, axis):
    r = (-x.shape[axis]) % mult
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "interpret"))
def photonic_matmul_prequant(x: jax.Array, wq: jax.Array, sw: jax.Array, *,
                             bits: int = 8, bm: int = 128, bn: int = 128,
                             bk: int = 128, interpret: bool = True
                             ) -> jax.Array:
    """Serving path for the quantize-once cache: the weight arrives already
    tuned (int8 codes + per-out-channel scale from core/backend.py); only
    the activations are quantized per call.

    x (..., K) float; wq (K, N) int8; sw (N,) f32. Returns (..., N) f32.
    Shapes need not be block multiples — callers' M/K/N are padded to the
    128-aligned kernel grid and the result is sliced back.
    """
    lead = x.shape[:-1]
    k, n = wq.shape
    x2 = x.reshape(-1, k).astype(jnp.float32)
    m = x2.shape[0]

    sx = quant.absmax_scale(x2, bits=bits)
    xq = quant.quantize(x2, sx, bits=bits)

    xq = pad_to(pad_to(xq, bm, 0), bk, 1)
    wqp = pad_to(pad_to(wq, bk, 0), bn, 1)
    swp = pad_to(sw, bn, 0)
    out = photonic_matmul_int8(xq, wqp, sx.reshape(()), swp,
                               bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("bits", "shot_sigma",
                                             "adc_bits", "chunk"))
def photonic_matmul_prequant_noisy(x: jax.Array, wq: jax.Array,
                                   sw: jax.Array, mult: jax.Array,
                                   readout_key: jax.Array, *,
                                   bits: int = 8, shot_sigma: float = 0.0,
                                   adc_bits: int = 0, chunk: int = 32
                                   ) -> jax.Array:
    """Noisy companion of ``photonic_matmul_prequant`` for the interpret-mode
    Pallas serving path.

    The int8 kernel is the *clean digital contract* — a sub-LSB analog
    transmission error cannot ride through integer codes — so noisy
    execution walks the same wavelength-chunk schedule on float codes
    (core/photonic.py: ``analog_accumulate``) with the MR multiplier
    ``mult`` (K, N) applied to the tuned bank, then adds shot noise and an
    optional range-limited ADC requant on the readout. ``mult`` and
    ``readout_key`` are explicit traced arguments: this wrapper is itself
    jitted, so the caller's noise draws must cross the boundary as inputs,
    never as closed-over tracers.
    """
    from repro.core.photonic import analog_accumulate
    lead = x.shape[:-1]
    k, n = wq.shape
    x2 = x.reshape(-1, k).astype(jnp.float32)

    sx = quant.absmax_scale(x2, bits=bits)
    xq = quant.quantize(x2, sx, bits=bits)
    acc = analog_accumulate(xq, wq.astype(jnp.float32) * mult, chunk=chunk)
    y = acc * sx * sw[None, :]
    if shot_sigma > 0.0:
        y = y * (1.0 + shot_sigma * jax.random.normal(readout_key, y.shape))
    if adc_bits:
        s = quant.absmax_scale(y, bits=adc_bits)
        y = quant.dequantize(quant.quantize(y, s, bits=adc_bits), s)
    return y.reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "bk",
                                             "interpret"))
def photonic_matmul(x: jax.Array, w: jax.Array, *, bits: int = 8,
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Float API: quantize both operands -> int8 kernel -> dequantize.

    x (..., K) any float dtype; w (K, N). Returns (..., N) f32.
    """
    w32 = w.astype(jnp.float32)
    sw = quant.absmax_scale(w32, bits=bits, axis=0)[0]
    wq = quant.quantize(w32, sw[None], bits=bits)
    return photonic_matmul_prequant(x, wq, sw, bits=bits, bm=bm, bn=bn,
                                    bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("heads", "kv_len", "bits",
                                             "bq", "bkv", "interpret"))
def fused_roi_attention_prequant(x: jax.Array,
                                 wq: jax.Array, sq_: jax.Array,
                                 wk: jax.Array, sk_: jax.Array,
                                 wv: jax.Array, sv_: jax.Array,
                                 key_mask: jax.Array | None = None, *,
                                 heads: int, kv_len: int | None = None,
                                 bits=8,
                                 bq: int = 128, bkv: int = 128,
                                 interpret: bool = True) -> jax.Array:
    """The serving hot path in one jit: int8 cached-weight QKV projections
    (``photonic_matmul_prequant`` x3 — the quantize-once cache's tuned MR
    banks) feeding the fused RoI-masked flash kernel.

    x (B, n, dm) float; wq/wk/wv (dm, dm) int8 codes with per-out-channel
    scales sq_/sk_/sv_ (dm,) f32; key_mask (B, n) keep-mask or None;
    ``kv_len`` the packed static alternative (one-shape serving mode).
    ``bits`` is an int or a static (q, k, v) triple of per-projection
    widths — mixed-precision bit plans may cache the three banks at
    different widths; each projection quantizes its activations at its
    own weight's width, exactly what the composed per-``linear`` dispatch
    does (the flash score core downstream is width-agnostic float).
    Returns the merged head outputs (B, n, dm) in x.dtype — the output
    projection is the caller's ``linear`` (it is just one more cached
    weight). Numerically identical to composing ``linear`` projections
    with ``attend`` under the flash backend; this entry point only removes
    the per-projection dispatch from the per-frame step graph.
    """
    if isinstance(bits, (tuple, list)):
        bits_q, bits_k, bits_v = (int(b_) for b_ in bits)
    else:
        bits_q = bits_k = bits_v = int(bits)
    b, n, dm = x.shape
    dh = dm // heads
    xf = x.astype(jnp.float32)
    q = photonic_matmul_prequant(xf, wq, sq_, bits=bits_q, interpret=interpret)
    k = photonic_matmul_prequant(xf, wk, sk_, bits=bits_k, interpret=interpret)
    v = photonic_matmul_prequant(xf, wv, sv_, bits=bits_v, interpret=interpret)

    def split(t):
        # cast to x.dtype first: bit-identical to the composed path, where
        # ``linear`` hands the attention core x.dtype operands
        return t.astype(x.dtype).reshape(b, n, heads, dh).transpose(0, 2, 1, 3)

    o = fused_masked_attention(split(q), split(k), split(v), key_mask,
                               kv_len=kv_len, bq=bq, bkv=bkv,
                               interpret=interpret)
    return o.transpose(0, 2, 1, 3).reshape(b, n, dm)


def fused_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """models/attention.py layout: q (B, Sq, H, D); k/v (B, Skv, Hkv, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    sq, skv = qt.shape[2], kt.shape[2]
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          bq=bq, bkv=bkv, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
